"""Quickstart: LLMSched end to end in ~1 minute on CPU.

1. Build the six compound-LLM application templates.
2. Train per-application Bayesian-network profiles from execution history.
3. Simulate a mixed workload under LLMSched and the paper's baselines.
4. Print the average-JCT comparison (paper Fig. 7 in miniature).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import LLMSched, ProfileStore, make_baselines
from repro.sim import generate_traces, get_generators, simulate
from repro.sim.simulator import configure_cluster


def main() -> None:
    # 1. application templates (sequence sorting, doc merging, code
    #    generation, web search, task automation, LLMCompiler)
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    print(f"applications: {[a.name for a in apps]}")

    # 2. profile from history: discretized durations -> BN structure+CPDs
    store = ProfileStore().fit(apps, generate_traces("mixed", 300, seed=7))
    prof = store["seq_sort"]
    print(f"seq_sort BN: {len(prof.bn.nodes)} nodes, "
          f"uncertainty-reducing stages: {prof.bn.uncertainty_reducing()}")

    # 3. cluster sized for ~95% load at λ=0.9 (paper §V setup)
    cluster = configure_cluster("mixed", arrival_rate=0.9, target_load=0.95)
    print(f"cluster: {cluster}")

    # 4. compare schedulers
    scheds = dict(make_baselines(store))
    scheds["llmsched"] = LLMSched(store, epsilon=0.2, seed=0)
    print(f"\n{'scheduler':12s} {'avg JCT (s)':>12s} {'overhead (ms)':>14s}")
    rows = []
    for name, s in scheds.items():
        js, ov = [], []
        for seed in (3, 11):
            r = simulate(s, mix="mixed", n_jobs=60, seed=seed, **cluster)
            js.append(r.avg_jct)
            ov.append(r.avg_overhead_ms)
        rows.append((float(np.mean(js)), name, float(np.mean(ov))))
    for jct, name, ov in sorted(rows):
        print(f"{name:12s} {jct:12.2f} {ov:14.2f}")


if __name__ == "__main__":
    main()
