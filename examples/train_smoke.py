"""Train a reduced llama-family model with checkpoint/restart.

Demonstrates the training substrate: synthetic LM data pipeline, AdamW
with int8 states, atomic checkpointing every 10 steps, and crash-restart
resume (kill and re-run: it continues from the last checkpoint).

Run:  PYTHONPATH=src python examples/train_smoke.py
"""

import tempfile

from repro.launch.train import main as train_main


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt:
        # phase 1: 10 steps, checkpoint at step 10
        train_main([
            "--arch", "llama3-405b", "--smoke", "--steps", "10",
            "--batch", "4", "--seq", "64", "--opt-state", "int8",
            "--ckpt-dir", ckpt, "--ckpt-every", "10",
        ])
        print("\n-- simulated restart (picks up from step 10) --\n")
        # phase 2: resumes from the checkpoint and continues to 16
        train_main([
            "--arch", "llama3-405b", "--smoke", "--steps", "16",
            "--batch", "4", "--seq", "64", "--opt-state", "int8",
            "--ckpt-dir", ckpt, "--ckpt-every", "10",
        ])


if __name__ == "__main__":
    main()
