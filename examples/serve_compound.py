"""End-to-end serving driver (the paper's testbed, CPU-scaled).

A REAL continuous-batching engine (jitted JAX decode over a smoke-size
stablelm-family model) serves compound LLM jobs whose admission order is
decided by LLMSched; compare against FCFS on the same workload, with
both the slot-based and the paged KV-cache engine.

Multi-replica mode: ``--replicas N`` spins up N paged engines sharing
one set of weights (replica 0 gets a deliberately small page pool so KV
pressure is visible), and ``--migrate`` turns on Llumnix-style live
migration — watch the ``migrations`` counter replace ``preemptions``.

Run:
  PYTHONPATH=src python examples/serve_compound.py
  PYTHONPATH=src python examples/serve_compound.py --replicas 2 --migrate
"""

import argparse

import jax

from repro.configs import get_smoke_config
from repro.core import FCFS, LLMSched, ProfileStore
from repro.models import init_params
from repro.serving import LLMEngine, PagedLLMEngine, ServingCluster
from repro.sim import generate_traces, generate_workload, get_generators


def build_engines(cfg, engine: str, replicas: int, seed: int = 0):
    """Build the fleet; multi-replica fleets share weights (migratable)."""
    if engine == "paged":
        params = init_params(cfg, jax.random.key(seed))[0]
        # replica 0 slightly starved when there are peers to flee to
        return [
            PagedLLMEngine(cfg, max_seqs=8, max_len=96, page_size=16,
                           num_pages=(13 if (i == 0 and replicas > 1)
                                      else None),
                           params=params)
            for i in range(replicas)
        ]
    return [LLMEngine(cfg, max_batch=4, max_len=96, seed=seed + i)
            for i in range(replicas)]


def run_one(name, sched, wl, cfg, engine="slot", replicas=1, migrate=False):
    engines = build_engines(cfg, engine, replicas)
    cluster = ServingCluster(sched, engines, n_regular=4,
                             token_scale=24.0, time_scale=24.0,
                             migrate=migrate)
    res = cluster.run(wl)
    print(f"{name:10s} engine={engine:5s} replicas={replicas} "
          f"avg_jct={res.avg_jct:6.2f}s jobs={len(res.jcts)} "
          f"tokens={res.tokens_generated} "
          f"sched_overhead={res.avg_overhead_ms:.2f}ms "
          f"preemptions={res.preemptions} migrations={res.migrations}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=1,
                    help="LLM engine replicas (paged, shared weights)")
    ap.add_argument("--migrate", action="store_true",
                    help="live-migrate KV off starved replicas")
    ap.add_argument("--jobs", type=int, default=12)
    args = ap.parse_args()

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("planning", 300, seed=7))
    cfg = get_smoke_config("stablelm_1_6b")
    print(f"engine model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    if args.replicas > 1:
        # multi-replica paged fleet: llmsched vs fcfs, migration per flag
        for name, sched in [
            ("llmsched", LLMSched(store, epsilon=0.2, seed=0)),
            ("fcfs", FCFS()),
        ]:
            wl = generate_workload("planning", args.jobs, arrival_rate=0.9,
                                   seed=11)
            run_one(name, sched, wl, cfg, engine="paged",
                    replicas=args.replicas, migrate=args.migrate)
        return

    for engine in ("slot", "paged"):
        for name, sched in [
            ("llmsched", LLMSched(store, epsilon=0.2, seed=0)),
            ("fcfs", FCFS()),
        ]:
            wl = generate_workload("planning", args.jobs, arrival_rate=0.9,
                                   seed=11)
            run_one(name, sched, wl, cfg, engine=engine)


if __name__ == "__main__":
    main()
