"""End-to-end serving driver (the paper's testbed, CPU-scaled).

A REAL continuous-batching engine (jitted JAX decode over a smoke-size
stablelm-family model) serves compound LLM jobs whose admission order is
decided by LLMSched; compare against FCFS on the same workload, with
both the slot-based and the paged KV-cache engine.

Run:  PYTHONPATH=src python examples/serve_compound.py
"""

from repro.configs import get_smoke_config
from repro.core import FCFS, LLMSched, ProfileStore
from repro.serving import LLMEngine, PagedLLMEngine, ServingCluster
from repro.sim import generate_traces, generate_workload, get_generators


def run_one(name: str, sched, wl, cfg, engine: str = "slot"):
    if engine == "paged":
        engines = [PagedLLMEngine(cfg, max_seqs=8, max_len=96,
                                  page_size=16, seed=0)]
    else:
        engines = [LLMEngine(cfg, max_batch=4, max_len=96, seed=0)]
    cluster = ServingCluster(sched, engines, n_regular=4,
                            token_scale=24.0, time_scale=24.0)
    res = cluster.run(wl)
    print(f"{name:10s} engine={engine:5s} avg_jct={res.avg_jct:6.2f}s "
          f"jobs={len(res.jcts)} tokens={res.tokens_generated} "
          f"sched_overhead={res.avg_overhead_ms:.2f}ms "
          f"preemptions={res.preemptions}")
    return res


def main() -> None:
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("planning", 300, seed=7))
    cfg = get_smoke_config("stablelm_1_6b")
    print(f"engine model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    for engine in ("slot", "paged"):
        for name, sched in [
            ("llmsched", LLMSched(store, epsilon=0.2, seed=0)),
            ("fcfs", FCFS()),
        ]:
            wl = generate_workload("planning", 12, arrival_rate=0.9, seed=11)
            run_one(name, sched, wl, cfg, engine=engine)


if __name__ == "__main__":
    main()
