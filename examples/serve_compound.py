"""End-to-end serving driver (the paper's testbed, CPU-scaled).

A REAL continuous-batching engine (jitted JAX decode over a smoke-size
stablelm-family model) serves compound LLM jobs whose admission order is
decided by LLMSched; compare against FCFS on the same workload, with
both the slot-based and the paged KV-cache engine.

All fleet/runtime knobs travel in one frozen ``repro.serving.ServeConfig``
consumed by ``build_engines`` and ``ServingCluster``.

Multi-replica mode: ``--replicas N`` spins up N paged engines sharing
one set of weights (replica 0 gets a deliberately small page pool so KV
pressure is visible), and ``--migrate`` turns on Llumnix-style live
migration — watch the ``migrations`` counter replace ``preemptions``.

Run:
  PYTHONPATH=src python examples/serve_compound.py
  PYTHONPATH=src python examples/serve_compound.py --replicas 2 --migrate
"""

import argparse

from repro.configs import get_smoke_config
from repro.core import FCFS, LLMSched, ProfileStore
from repro.serving import ServeConfig, ServingCluster, build_engines
from repro.sim import generate_traces, generate_workload, get_generators


def config_for(engine: str, replicas: int, migrate: bool) -> ServeConfig:
    """Fleet shape for this demo; replica 0 of a multi-replica paged
    fleet gets a deliberately small page pool so KV pressure (and the
    value of migration) is visible."""
    kv_pages = None
    if engine == "paged" and replicas > 1:
        # None entries are not expressible in ServeConfig.kv_pages (it
        # pins every pool); starve replica 0, default-size the rest
        kv_pages = tuple([13] + [49] * (replicas - 1))
    return ServeConfig(
        engine=engine,
        replicas=replicas,
        max_batch=8 if engine == "paged" else 4,
        max_len=96,
        page_size=16,
        kv_pages=kv_pages,
        migrate=migrate,
        n_regular=4,
        token_scale=24.0,
        time_scale=24.0,
        seed=0,
    )


def run_one(name, sched, wl, cfg, serve_cfg: ServeConfig):
    engines = build_engines(cfg, serve_cfg)
    cluster = ServingCluster(sched, engines, serve_cfg)
    res = cluster.run(wl)
    print(f"{name:10s} engine={serve_cfg.engine:5s} "
          f"replicas={serve_cfg.replicas} "
          f"avg_jct={res.avg_jct:6.2f}s jobs={len(res.jcts)} "
          f"tokens={res.tokens_generated} "
          f"sched_overhead={res.avg_overhead_ms:.2f}ms "
          f"preemptions={res.preemptions} migrations={res.migrations}")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=1,
                    help="LLM engine replicas (paged, shared weights)")
    ap.add_argument("--migrate", action="store_true",
                    help="live-migrate KV off starved replicas")
    ap.add_argument("--jobs", type=int, default=12)
    args = ap.parse_args()

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("planning", 300, seed=7))
    cfg = get_smoke_config("stablelm_1_6b")
    print(f"engine model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    if args.replicas > 1:
        # multi-replica paged fleet: llmsched vs fcfs, migration per flag
        serve_cfg = config_for("paged", args.replicas, args.migrate)
        for name, sched in [
            ("llmsched", LLMSched(store, epsilon=0.2, seed=0)),
            ("fcfs", FCFS()),
        ]:
            wl = generate_workload("planning", args.jobs, arrival_rate=0.9,
                                   seed=11)
            run_one(name, sched, wl, cfg, serve_cfg)
        return

    for engine in ("slot", "paged"):
        serve_cfg = config_for(engine, 1, migrate=False)
        for name, sched in [
            ("llmsched", LLMSched(store, epsilon=0.2, seed=0)),
            ("fcfs", FCFS()),
        ]:
            wl = generate_workload("planning", args.jobs, arrival_rate=0.9,
                                   seed=11)
            run_one(name, sched, wl, cfg, serve_cfg)


if __name__ == "__main__":
    main()
