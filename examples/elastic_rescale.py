"""Elastic scaling demo: checkpoint on one mesh, restore on another.

Simulates losing half the data-parallel slice mid-training: train on a
(4, 2) mesh, checkpoint, rebuild a (2, 2) mesh (half the "cluster"), and
resume — `restore_checkpoint` repartitions every host array onto the new
mesh's NamedShardings.

Must run as its own process (device count locks at jax init):
  PYTHONPATH=src python examples/elastic_rescale.py
"""

import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.distributed import sharding as shd
from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint
from repro.distributed.optimizer import OptConfig, init_opt_state
from repro.launch.train import synthetic_batch
from repro.models import init_params
from repro.models.zoo import build_train_step


def main() -> None:
    cfg = get_smoke_config("internlm2_20b")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2)
    step_fn = jax.jit(build_train_step(cfg, opt_cfg))
    rng = np.random.default_rng(0)

    params, specs = init_params(cfg, jax.random.key(0))
    opt_state = init_opt_state(params, opt_cfg)

    with tempfile.TemporaryDirectory() as ckpt:
        # phase 1: train on the "full cluster" (data=4, model=2)
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        with shd.use_mesh(mesh1):
            sh1 = shd.tree_shardings(specs, params, mesh1)
            params = jax.device_put(params, sh1)
            for s in range(3):
                batch = synthetic_batch(rng, cfg, 8, 32)
                params, opt_state, m = step_fn(params, opt_state, batch)
                print(f"[mesh 4x2] step={s+1} loss={float(m['loss']):.3f}")
        save_checkpoint(ckpt, 3, (params, opt_state), mesh_desc="4x2")
        print("checkpointed on 4x2")

        # phase 2: "lose" half the data slice -> restore on (2, 2)
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        with shd.use_mesh(mesh2):
            from jax.sharding import NamedSharding, PartitionSpec as P

            sh2 = shd.tree_shardings(specs, params, mesh2)
            opt_sh = jax.tree.map(
                lambda _: NamedSharding(mesh2, P()), opt_state
            )
            (params2, opt2), step = restore_checkpoint(
                ckpt, like=(params, opt_state), shardings=(sh2, opt_sh)
            )
            print(f"restored step {step} onto 2x2 "
                  f"(devices/leaf: {len(jax.tree.leaves(params2)[0].devices())})")
            for s in range(step, step + 3):
                batch = synthetic_batch(rng, cfg, 8, 32)
                params2, opt2, m = step_fn(params2, opt2, batch)
                print(f"[mesh 2x2] step={s+1} loss={float(m['loss']):.3f}")
    print("elastic rescale OK")


if __name__ == "__main__":
    main()
