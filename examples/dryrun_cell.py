"""Lower + compile one (architecture × shape × mesh) cell and print its
roofline decomposition — the multi-pod dry-run in miniature.

NOTE: must run as a fresh process (512 host devices are locked in at jax
init), which is why this example shells out to the dryrun module.

Run:  PYTHONPATH=src python examples/dryrun_cell.py [arch] [shape]
"""

import subprocess
import sys


def main() -> None:
    arch = sys.argv[1] if len(sys.argv) > 1 else "internlm2-20b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"
    for extra in ([], ["--multi-pod"]):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape] + extra
        print("$", " ".join(cmd))
        subprocess.run(cmd, check=False)


if __name__ == "__main__":
    main()
