"""Fig. 1 + Fig. 5 — workload characterization: duration uncertainty,
structural uncertainty, inter-stage duration correlations."""

from __future__ import annotations

import numpy as np

from repro.sim import generate_workload, get_generators

from .common import emit_csv


def main(n_jobs: int = 400, seed: int = 1) -> dict:
    wl = generate_workload("mixed", n_jobs, seed=seed)
    results = {}

    # (a) job-duration distributions (Obs. 1)
    rows = []
    by_app = {}
    for gj in wl:
        tot = sum(v for k, v in gj.durations.items() if "." not in k)
        by_app.setdefault(gj.job.app.name, []).append(tot)
    for app, v in sorted(by_app.items()):
        a = np.array(v)
        rows.append([app, len(a), round(a.min(), 1), round(float(np.median(a)), 1),
                     round(a.max(), 1), round(a.std() / a.mean(), 2)])
        results[("duration", app)] = (a.min(), a.max())
    emit_csv("fig1a_duration_uncertainty",
             ["app", "n", "min_s", "median_s", "max_s", "cv"], rows)

    # (b) chain-length distribution (Obs. 2, code generation)
    lens = {}
    for gj in wl:
        if gj.job.app.name == "code_gen":
            L = sum(1 for n, s in gj.job.stages.items()
                    if s.will_execute and s.tasks)
            lens[L] = lens.get(L, 0) + 1
    emit_csv("fig1b_chain_length", ["n_stages", "count"],
             [[k, v] for k, v in sorted(lens.items())])
    results["chain_lengths"] = lens

    # (c) generated-stage distribution (Obs. 2, task automation)
    counts = {}
    for gj in wl:
        if gj.job.app.name == "task_auto":
            k = len(gj.job.dynamic_realization["auto_tools"][0])
            counts[k] = counts.get(k, 0) + 1
    emit_csv("fig1c_generated_stages", ["n_generated", "count"],
             [[k, v] for k, v in sorted(counts.items())])
    results["generated"] = counts

    # (d) Fig. 5 — inter-stage duration correlation (seq_sort)
    gens = get_generators()
    names = gens["seq_sort"].template.topo_order()
    mat = []
    for gj in wl:
        if gj.job.app.name == "seq_sort":
            mat.append([gj.durations[n] for n in names])
    mat = np.array(mat)
    corr = np.corrcoef(mat.T)
    rows = []
    for i, a in enumerate(names):
        for j, b in enumerate(names):
            if i < j and abs(corr[i, j]) > 0.5:
                rows.append([a, b, round(float(corr[i, j]), 2)])
    emit_csv("fig5_interstage_correlation (|r|>0.5, seq_sort)",
             ["stage_u", "stage_v", "pearson_r"], rows)
    results["max_corr"] = float(np.nanmax(np.abs(corr - np.eye(len(names)))))
    return results


if __name__ == "__main__":
    main()
