"""Fig. 7 (SLO) — goodput under tiered deadlines, simulator.

Every job in a seeded tiered trace carries an SLO
(``interactive`` / ``batch`` / ``best_effort`` with an absolute
deadline); schedulers serve the *identical* job stream and are scored
on **goodput** (fraction of jobs finishing by their deadline, per tier)
alongside avg/p95 JCT.

Compared policies:
- ``fcfs`` / ``sjf``          — deadline-blind baselines;
- ``llmsched_blind``          — LLMSched with ``slo_aware=False``
  (uncertainty-aware but deadline-blind ablation);
- ``llmsched_slo``            — full plan-ahead + demotion + retraction.

Acceptance target: ``llmsched_slo`` strictly improves interactive-tier
goodput over at least two deadline-blind baselines on the seeded trace.
Artifact: ``benchmarks/out/fig7_slo_goodput.json``.

CLI::

    PYTHONPATH=src python -m benchmarks.fig7_slo
    PYTHONPATH=src python -m benchmarks.fig7_slo --jobs 60 --tightness 1.5
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict

import numpy as np

from repro.core import LLMSched, make_baselines
from repro.core.dag import SLO_TIERS
from repro.sim.simulator import ClusterSim
from repro.sim.workloads import generate_tiered_workload

from .common import emit_csv, store_for

OUT_DIR = Path(__file__).parent / "out"

# trace/cluster shape: heavy-ish arrivals on a small fleet so queueing
# (and therefore deadline pressure) is visible at benchmark job counts
MIX = "mixed"
ARRIVAL_RATE = 1.2
SEEDS = (3, 11, 29)
CLUSTER = dict(n_regular=4, n_llm=2, max_batch=8)
PLAN_AHEAD_S = 30.0


def schedulers(mix: str = MIX) -> Dict[str, object]:
    """The compared policies, rebuilt fresh (schedulers carry state)."""
    store = store_for(mix)
    base = make_baselines(store)
    return {
        "fcfs": base["fcfs"],
        "sjf": base["sjf"],
        "llmsched_blind": LLMSched(store, epsilon=0.2, seed=0,
                                   slo_aware=False),
        "llmsched_slo": LLMSched(store, epsilon=0.2, seed=0,
                                 plan_ahead_s=PLAN_AHEAD_S),
    }


def run(jobs: int = 60, tightness: float = 1.5, seeds=SEEDS,
        mix: str = MIX) -> dict:
    """Run the tiered-trace sweep and write the goodput artifact."""
    out: dict = {
        "mix": mix,
        "jobs_per_seed": jobs,
        "arrival_rate": ARRIVAL_RATE,
        "tightness": tightness,
        "seeds": list(seeds),
        "cluster": dict(CLUSTER),
        "plan_ahead_s": PLAN_AHEAD_S,
        "schedulers": {},
    }
    rows = []
    for name in schedulers(mix):
        per_seed = {"avg_jct": [], "p95_jct": [],
                    "goodput": [], "retractions": [], "demotions": []}
        tier_goodput: Dict[str, list] = {t: [] for t in SLO_TIERS}
        for seed in seeds:
            sched = schedulers(mix)[name]  # fresh state per run
            wl = generate_tiered_workload(
                mix, jobs, arrival_rate=ARRIVAL_RATE, seed=seed,
                tightness=tightness,
            )
            sim = ClusterSim(sched, seed=seed, **CLUSTER)
            r = sim.run(wl)
            per_seed["avg_jct"].append(r.avg_jct)
            per_seed["p95_jct"].append(r.p95_jct)
            per_seed["goodput"].append(r.goodput() or 0.0)
            per_seed["retractions"].append(r.retractions)
            per_seed["demotions"].append(int(getattr(sched, "demotions", 0)))
            for t, g in r.goodput_by_tier().items():
                tier_goodput[t].append(g)
        entry = {
            "avg_jct_s": round(float(np.mean(per_seed["avg_jct"])), 3),
            "p95_jct_s": round(float(np.mean(per_seed["p95_jct"])), 3),
            "goodput": round(float(np.mean(per_seed["goodput"])), 4),
            "goodput_by_tier": {
                t: round(float(np.mean(v)), 4)
                for t, v in tier_goodput.items() if v
            },
            "retractions": int(np.sum(per_seed["retractions"])),
            "demotions": int(np.sum(per_seed["demotions"])),
        }
        out["schedulers"][name] = entry
        gbt = entry["goodput_by_tier"]
        rows.append([name, entry["avg_jct_s"], entry["p95_jct_s"],
                     entry["goodput"],
                     gbt.get("interactive", "-"), gbt.get("batch", "-"),
                     gbt.get("best_effort", "-"),
                     entry["retractions"], entry["demotions"]])
    slo_g = out["schedulers"]["llmsched_slo"]["goodput_by_tier"].get(
        "interactive", 0.0
    )
    beaten = [
        n for n in ("fcfs", "sjf", "llmsched_blind")
        if slo_g > out["schedulers"][n]["goodput_by_tier"].get(
            "interactive", 0.0
        )
    ]
    out["interactive_goodput_strictly_beats"] = beaten
    emit_csv(
        f"fig7_slo_goodput (tiered {mix} trace, tightness={tightness}, "
        f"{len(seeds)} seeds)",
        ["scheduler", "avg_jct_s", "p95_jct_s", "goodput", "g_interactive",
         "g_batch", "g_best_effort", "retractions", "demotions"],
        rows,
    )
    print(f"# llmsched_slo interactive goodput strictly beats: {beaten}\n")
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig7_slo_goodput.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=60)
    ap.add_argument("--tightness", type=float, default=1.5)
    args = ap.parse_args()
    run(jobs=args.jobs, tightness=args.tightness)
