"""Table I — average scheduling overhead (ms) per method × workload.

Paper: LLMSched < 3 ms everywhere (incl. BN inference + entropy calc),
simple heuristics < 1 ms, Decima/Carbyne higher.

``--sweep`` additionally measures per-round scheduling latency at
increasing concurrent-job counts (50/200/1000), comparing the incremental
scheduler (cross-round caches keyed on ``Job.evidence_version``) against
the from-scratch baseline, and records the result as a JSON artifact in
``benchmarks/out/``.  Decision sequences are checked to be identical
between the two modes on every round.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import LLMSched
from repro.core.dag import TaskState
from repro.core.scheduler import ClusterView
from repro.sim import generate_workload, get_generators, simulate
from repro.sim.simulator import default_latency_profile
from repro.sim.workloads import reveal_after_stage

from .common import SEEDS, cluster_for, emit_csv, schedulers_for

MIXES = ("mixed", "predefined", "chain", "planning")


def main(n_jobs: int = 60) -> dict:
    rows = []
    results = {}
    for mix in MIXES:
        scheds = schedulers_for(mix)
        cfg = cluster_for(mix)
        for name, s in scheds.items():
            ovs = []
            for seed in SEEDS[:2]:
                r = simulate(s, mix=mix, n_jobs=n_jobs, seed=seed, **cfg)
                ovs.append(r.avg_overhead_ms)
            results[(mix, name)] = float(np.mean(ovs))
            rows.append([name, mix, round(float(np.mean(ovs)), 3)])
    emit_csv(
        "table1_overhead (avg scheduling overhead, ms)",
        ["scheduler", "workload", "overhead_ms"],
        rows,
    )
    ours = [v for (m, n), v in results.items() if n == "llmsched"]
    print(f"# LLMSched overhead across workloads: "
          f"{min(ours):.2f}–{max(ours):.2f} ms (paper: <3 ms)\n")
    return results


# ---------------------------------------------------------------------------
# Job-count sweep: per-round latency, incremental vs from-scratch
# ---------------------------------------------------------------------------
def _complete_one_stage(job, gens) -> bool:
    """Deterministically complete the job's first ready stage (an
    'evidence event': new durations, chain reveals, dynamic expansion)."""
    ready = job.ready_stages()
    if not ready:
        return False
    stage = ready[0]
    for t in stage.tasks:
        t.state = TaskState.DONE
        t.start_time = 0.0
        t.finish_time = max(t.true_duration, 1e-3)
    reveal_after_stage(job, stage, gens)
    return True


def _measure_rounds(n_jobs: int, incremental: bool, rounds: int,
                    event_frac: float, seed: int = 17):
    """Per-round schedule() latency over a large active-job set, with a
    deterministic trickle of stage-completion events between rounds."""
    from repro.core import ProfileStore
    from repro.sim import generate_traces

    # a FRESH store per measurement: the input-keyed posterior caches
    # inside AppProfile must not leak warm entries across the
    # fresh/incremental comparison (that would bias the speedup)
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 400, seed=7))
    wl = generate_workload("mixed", n_jobs, seed=seed)
    jobs = [gj.job for gj in wl]
    pos = {j.job_id: i for i, j in enumerate(jobs)}
    sched = LLMSched(store, epsilon=0.2, seed=1, incremental=incremental)
    profile = default_latency_profile(8)
    step = max(1, int(round(1.0 / max(event_frac, 1e-9))))

    lats, sigs = [], []
    for r in range(rounds):
        view = ClusterView(
            now=float(r),
            free_regular=8,
            llm_loads=[(2, 8)] * 4,
            latency_profile=profile,
        )
        t0 = time.perf_counter()
        dec = sched.schedule(jobs, view)
        lats.append(time.perf_counter() - t0)
        sigs.append(tuple(
            (pos[t.job_id], t.stage_name, t.index, t.is_llm)
            for t in dec.regular + dec.llm
        ))
        # evidence events on ~event_frac of jobs (round-robin offset)
        for i in range(r % step, n_jobs, step):
            _complete_one_stage(jobs[i], gens)
    return lats, sigs


def sweep(job_counts=(50, 200, 1000), rounds: int = 6,
          event_frac: float = 0.02,
          out_path: str = os.path.join("benchmarks", "out",
                                       "table1_scale.json")) -> dict:
    """Per-round scheduling latency vs concurrent-job count.

    Warm-round latency (rounds after the first, i.e. once the incremental
    caches exist) is what a production scheduler pays at steady state.
    """
    results = {}
    rows = []
    for n in job_counts:
        fresh_lats, fresh_sigs = _measure_rounds(n, False, rounds, event_frac)
        inc_lats, inc_sigs = _measure_rounds(n, True, rounds, event_frac)
        match = fresh_sigs == inc_sigs
        fresh_ms = 1e3 * float(np.median(fresh_lats[1:]))
        inc_ms = 1e3 * float(np.median(inc_lats[1:]))
        speedup = fresh_ms / max(inc_ms, 1e-9)
        results[n] = {
            "fresh_ms_per_round": round(fresh_ms, 3),
            "incremental_ms_per_round": round(inc_ms, 3),
            "speedup": round(speedup, 2),
            "decisions_match": bool(match),
        }
        rows.append([n, round(fresh_ms, 3), round(inc_ms, 3),
                     round(speedup, 2), match])
    emit_csv(
        "table1_scale (per-round scheduling latency, ms)",
        ["n_jobs", "fresh_ms", "incremental_ms", "speedup", "decisions_match"],
        rows,
    )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(
            {"rounds": rounds, "event_frac": event_frac, "results": results},
            f, indent=2,
        )
    print(f"# wrote {out_path}")
    return results


if __name__ == "__main__":
    import sys

    if "--sweep" in sys.argv:
        sweep()
    else:
        main()
