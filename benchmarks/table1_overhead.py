"""Table I — average scheduling overhead (ms) per method × workload.

Paper: LLMSched < 3 ms everywhere (incl. BN inference + entropy calc),
simple heuristics < 1 ms, Decima/Carbyne higher.
"""

from __future__ import annotations

import numpy as np

from repro.sim import simulate

from .common import SEEDS, cluster_for, emit_csv, schedulers_for

MIXES = ("mixed", "predefined", "chain", "planning")


def main(n_jobs: int = 60) -> dict:
    rows = []
    results = {}
    for mix in MIXES:
        scheds = schedulers_for(mix)
        cfg = cluster_for(mix)
        for name, s in scheds.items():
            ovs = []
            for seed in SEEDS[:2]:
                r = simulate(s, mix=mix, n_jobs=n_jobs, seed=seed, **cfg)
                ovs.append(r.avg_overhead_ms)
            results[(mix, name)] = float(np.mean(ovs))
            rows.append([name, mix, round(float(np.mean(ovs)), 3)])
    emit_csv(
        "table1_overhead (avg scheduling overhead, ms)",
        ["scheduler", "workload", "overhead_ms"],
        rows,
    )
    ours = [v for (m, n), v in results.items() if n == "llmsched"]
    print(f"# LLMSched overhead across workloads: "
          f"{min(ours):.2f}–{max(ours):.2f} ms (paper: <3 ms)\n")
    return results


if __name__ == "__main__":
    main()
