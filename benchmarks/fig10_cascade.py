"""Fig. 10 (cascade) — cost-efficiency of cascade routing, simulator.

Four policies serve the identical seeded trace on a 3-replica LLM
fleet, all behind the same deterministic quality gate, and are scored
on **cost-efficiency** (quality-accepted finished jobs per unit of
serving cost) alongside avg JCT:

- ``single_cheap``      — homogeneous cheapest pool; rejections have
  nowhere to escalate, so out-of-depth stages ship rejected output;
- ``single_large``      — homogeneous top-tier pool; everything is
  accepted at the top-tier price;
- ``cost_blind``        — heterogeneous ladder with cascade retries
  but a cost-blind scheduler (``w_model = 0`` ablation);
- ``llmsched_cascade``  — full cost-aware routing
  (uncertainty-reduction-per-cost) plus cascade retries.

Acceptance target: ``llmsched_cascade`` strictly beats both
single-tier pools (and at least matches the cost-blind router) on
cost-efficiency while keeping avg JCT within ``JCT_SLACK`` of the
quality-matched ``single_large`` pool.
Artifact: ``benchmarks/out/fig10_cascade.json``.

CLI::

    PYTHONPATH=src python -m benchmarks.fig10_cascade
    PYTHONPATH=src python -m benchmarks.fig10_cascade --jobs 60 --strictness 0.8
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Tuple

import numpy as np

from repro.core import DeterministicGate, LLMSched
from repro.models.zoo import tier_spec
from repro.sim import TIER_POOLS
from repro.sim.simulator import ClusterSim
from repro.sim.workloads import generate_workload

from .common import emit_csv, store_for

OUT_DIR = Path(__file__).parent / "out"

# trace/cluster shape: the fig7 fleet with one extra LLM replica so the
# 3-tier ladder is fully populated
MIX = "mixed"
ARRIVAL_RATE = 1.2
SEEDS = (3, 11)
CLUSTER = dict(n_regular=4, n_llm=3, max_batch=8)
STRICTNESS = 1.0  # fully deterministic gate: out-of-depth stages always
                  # escalate, so "merely got lucky" runs can't blur the
                  # frontier
JCT_SLACK = 1.5   # cascade avg JCT must stay within this factor of the
                  # quality-matched single-tier pool (single_large — the
                  # cheap pool's JCT prices in shipping rejected output,
                  # so it is not a meaningful latency reference)

# policy name -> (tier pool, cost-aware?)
POLICIES: Dict[str, Tuple[Tuple[str, ...], bool]] = {
    "single_cheap": (TIER_POOLS["cheap3"], True),
    "single_large": (TIER_POOLS["large3"], True),
    "cost_blind": (TIER_POOLS["ladder3"], False),
    "llmsched_cascade": (TIER_POOLS["ladder3"], True),
}


def _sched(mix: str, cost_aware: bool) -> LLMSched:
    s = LLMSched(store_for(mix), epsilon=0.2, seed=0)
    if not cost_aware:
        s.w_model = 0.0
    return s


def run(jobs: int = 60, strictness: float = STRICTNESS, seeds=SEEDS,
        mix: str = MIX) -> dict:
    """Run the cascade frontier sweep and write the cost artifact."""
    out: dict = {
        "mix": mix,
        "jobs_per_seed": jobs,
        "arrival_rate": ARRIVAL_RATE,
        "strictness": strictness,
        "seeds": list(seeds),
        "cluster": dict(CLUSTER),
        "pools": {n: list(p) for n, (p, _) in POLICIES.items()},
        "tier_prices_usd_per_mtok": {
            n: tier_spec(n).usd_per_mtok
            for n in sorted(set(TIER_POOLS["ladder3"]))
        },
        "policies": {},
    }
    rows = []
    for name, (pool, cost_aware) in POLICIES.items():
        per_seed = {"avg_jct": [], "cost": [], "accepted": [],
                    "efficiency": [], "escalations": []}
        for seed in seeds:
            wl = generate_workload(mix, jobs, arrival_rate=ARRIVAL_RATE,
                                   seed=seed)
            sim = ClusterSim(
                _sched(mix, cost_aware), seed=seed, **CLUSTER,
                model_tiers=pool, cascade=True,
                gate=DeterministicGate(strictness=strictness, seed=seed),
            )
            r = sim.run(wl)
            accepted = sum(
                1 for j in r.jct_by_job
                if r.quality_by_job.get(j, True)
            )
            per_seed["avg_jct"].append(r.avg_jct)
            per_seed["cost"].append(r.total_cost)
            per_seed["accepted"].append(accepted)
            per_seed["efficiency"].append(r.cost_efficiency() or 0.0)
            per_seed["escalations"].append(r.escalations)
        entry = {
            "avg_jct_s": round(float(np.mean(per_seed["avg_jct"])), 3),
            "total_cost_usd": float(np.sum(per_seed["cost"])),
            "accepted_jobs": int(np.sum(per_seed["accepted"])),
            "jobs": jobs * len(seeds),
            "cost_efficiency": round(
                float(np.mean(per_seed["efficiency"])), 3
            ),
            "escalations": int(np.sum(per_seed["escalations"])),
        }
        out["policies"][name] = entry
        rows.append([
            name, entry["avg_jct_s"], f"{entry['total_cost_usd']:.3e}",
            f"{entry['accepted_jobs']}/{entry['jobs']}",
            entry["cost_efficiency"], entry["escalations"],
        ])
    casc = out["policies"]["llmsched_cascade"]
    singles = ("single_cheap", "single_large")
    beaten = [
        n for n in singles
        if casc["cost_efficiency"] > out["policies"][n]["cost_efficiency"]
    ]
    out["cost_efficiency_strictly_beats"] = beaten
    out["beats_cost_blind"] = (
        casc["cost_efficiency"]
        >= out["policies"]["cost_blind"]["cost_efficiency"]
    )
    out["jct_vs_quality_matched_single"] = round(
        casc["avg_jct_s"]
        / max(out["policies"]["single_large"]["avg_jct_s"], 1e-9), 3
    )
    out["jct_comparable"] = out["jct_vs_quality_matched_single"] <= JCT_SLACK
    emit_csv(
        f"fig10_cascade ({mix} trace, strictness={strictness}, "
        f"{len(seeds)} seeds)",
        ["policy", "avg_jct_s", "total_cost_usd", "accepted",
         "cost_efficiency", "escalations"],
        rows,
    )
    print(f"# llmsched_cascade cost-efficiency strictly beats: {beaten} "
          f"(>= cost_blind: {out['beats_cost_blind']})")
    print(f"# avg JCT vs quality-matched single-tier pool: "
          f"{out['jct_vs_quality_matched_single']}x "
          f"(comparable={out['jct_comparable']})\n")
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig10_cascade.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=60)
    ap.add_argument("--strictness", type=float, default=STRICTNESS)
    args = ap.parse_args()
    run(jobs=args.jobs, strictness=args.strictness)
