"""Fig. 8 — testbed: real engines (jitted decode, continuous batching)
with the scheduler in the loop.

Scaled to CPU: a smoke-size model serves compressed token budgets; the
relative JCT ordering across schedulers is the reproduction target.

``paged_vs_slot`` additionally benchmarks the paged KV-cache engine
against the slot engine at an *equal KV memory budget*: the slot engine
reserves ``max_len`` tokens per slot up front (concurrency = #slots),
while the paged engine admits by actual page usage, so the same pool
serves far more concurrent requests.  Artifact:
``benchmarks/out/fig8_paged_vs_slot.json``.
"""

from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path

from repro.configs import get_smoke_config
from repro.core import LLMSched
from repro.serving import LLMEngine, PagedLLMEngine, Request, ServingCluster
from repro.sim import generate_workload

from .common import emit_csv, schedulers_for, store_for

OUT_DIR = Path(__file__).parent / "out"


def _drive_engine(eng, n_requests: int, prompt_len: int, new_tokens: int):
    """Offer n_requests at once; drain; return (tokens, wall_s, jcts)."""
    pending = deque(
        Request(rid=i, prompt=[1 + i % 7] * prompt_len,
                max_new_tokens=new_tokens)
        for i in range(n_requests)
    )
    finished = []
    t0 = time.perf_counter()
    while pending or eng.batch_size or getattr(eng, "waiting", ()):
        while pending and eng.can_admit() and eng.admit(pending[0]):
            pending.popleft()
        finished.extend(eng.step())
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in finished)
    jcts = [r.finished_at - t0 for r in finished]
    return tokens, wall, jcts


def paged_vs_slot(
    n_requests: int = 32,
    prompt_len: int = 4,
    new_tokens: int = 20,
    max_len: int = 96,
    slot_batch: int = 8,
    # page_size 8: a 24-token request is exactly 3 pages, so the equal-
    # memory pool (768 tokens = 96 pages) holds all 32 requests evict-free
    page_size: int = 8,
    seed: int = 0,
    warmup: bool = True,
) -> dict:
    """Slot vs paged engine at an equal KV token budget.

    Budget = slot_batch × max_len token-slots.  The slot engine's
    concurrency is capped at ``slot_batch`` by its dense reservation;
    the paged engine spends the *same* pool on actual usage
    (prompt+decode ≈ prompt_len+new_tokens tokens per request), so ≥
    ``n_requests`` run concurrently and decode batches are much larger.
    """
    import numpy as np

    cfg = get_smoke_config("stablelm_1_6b")
    kv_budget_tokens = slot_batch * max_len
    num_pages = 1 + kv_budget_tokens // page_size
    engines = {
        "slot": LLMEngine(cfg, max_batch=slot_batch, max_len=max_len,
                          seed=seed),
        "paged": PagedLLMEngine(cfg, max_seqs=n_requests, max_len=max_len,
                                page_size=page_size, num_pages=num_pages,
                                seed=seed),
    }
    out = {
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "kv_budget_tokens": kv_budget_tokens,
        "model": cfg.name,
    }
    rows = []
    for name, eng in engines.items():
        if warmup:  # populate JIT caches so compile time is not measured
            _drive_engine(eng, n_requests, prompt_len, new_tokens)
            if hasattr(eng, "preemptions"):
                eng.preemptions = 0  # report the measured run only
        tokens, wall, jcts = _drive_engine(
            eng, n_requests, prompt_len, new_tokens
        )
        out[name] = {
            "tokens": tokens,
            "wall_s": round(wall, 3),
            "decode_throughput_tok_s": round(tokens / wall, 1),
            "avg_jct_s": round(float(np.mean(jcts)), 3),
            "p95_jct_s": round(float(np.percentile(jcts, 95)), 3),
            "max_concurrency": eng.max_batch,
            "preemptions": getattr(eng, "preemptions", 0),
        }
        rows.append([name, tokens, out[name]["wall_s"],
                     out[name]["decode_throughput_tok_s"],
                     out[name]["avg_jct_s"], out[name]["p95_jct_s"],
                     eng.max_batch, out[name]["preemptions"]])
    out["throughput_speedup"] = round(
        out["paged"]["decode_throughput_tok_s"]
        / out["slot"]["decode_throughput_tok_s"], 2
    )
    emit_csv(
        f"fig8_paged_vs_slot ({n_requests} concurrent requests, equal KV budget)",
        ["engine", "tokens", "wall_s", "decode_tok_s", "avg_jct_s",
         "p95_jct_s", "max_conc", "preemptions"],
        rows,
    )
    print(f"# paged/slot decode throughput: {out['throughput_speedup']}x\n")
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig8_paged_vs_slot.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


def main(mixes=("planning", "chain"), jobs: int = 14, seed: int = 11) -> dict:
    t0 = time.time()
    cfg = get_smoke_config("stablelm_1_6b")
    rows = []
    results = {}
    for mix in mixes:
        store = store_for(mix)
        scheds = {
            "fcfs": schedulers_for(mix, train_decima=False)["fcfs"],
            "sjf": schedulers_for(mix, train_decima=False)["sjf"],
            "llmsched": LLMSched(store, epsilon=0.2, seed=0),
        }
        for name, sched in scheds.items():
            engines = [LLMEngine(cfg, max_batch=4, max_len=96, seed=0)]
            cluster = ServingCluster(sched, engines, n_regular=4,
                                     token_scale=24.0, time_scale=24.0)
            wl = generate_workload(mix, jobs, arrival_rate=0.9, seed=seed)
            r = cluster.run(wl)
            results[(mix, name)] = r
            rows.append([mix, name, round(r.avg_jct, 2), len(r.jcts),
                         r.tokens_generated, round(r.avg_overhead_ms, 2)])
    emit_csv(
        "fig8_testbed (real engines; scaled tokens)",
        ["workload", "scheduler", "avg_jct_s", "jobs", "tokens",
         "sched_overhead_ms"],
        rows,
    )
    results["paged_vs_slot"] = paged_vs_slot()
    print(f"# fig8 wall time: {time.time()-t0:.0f}s\n")
    return results


if __name__ == "__main__":
    main()
