"""Fig. 8 — testbed: real engines (jitted decode, continuous batching)
with the scheduler in the loop.

Scaled to CPU: a smoke-size model serves compressed token budgets; the
relative JCT ordering across schedulers is the reproduction target.

``paged_vs_slot`` additionally benchmarks the paged KV-cache engine
against the slot engine at an *equal KV memory budget*: the slot engine
reserves ``max_len`` tokens per slot up front (concurrency = #slots),
while the paged engine admits by actual page usage, so the same pool
serves far more concurrent requests.  Artifact:
``benchmarks/out/fig8_paged_vs_slot.json``.

``multi_replica`` serves the same seeded request trace on a fleet of
equal-budget paged replicas twice — live migration off, then on — and
records JCT plus migration/preemption counts (pass ``small_pages`` to
starve replica 0 for the heterogeneous variant).  Artifact:
``benchmarks/out/fig8_multi_replica.json``.

``prefix_cache`` serves a seeded shared-system-prompt compound trace
(every request = one 32-token system prompt + a small per-request
suffix) through the same paged engine twice — radix prefix cache off,
then on — at an *equal KV page budget*, and records prefill tokens
actually processed, JCT in engine steps, and cache hit/CoW/eviction
counters.  Acceptance target: ≥ 30 % prefill-token reduction with no
avg-JCT regression.  Artifact: ``benchmarks/out/fig8_prefix_cache.json``.

CLI::

    PYTHONPATH=src python -m benchmarks.fig8_testbed            # everything
    PYTHONPATH=src python -m benchmarks.fig8_testbed multi_replica
    PYTHONPATH=src python -m benchmarks.fig8_testbed paged_vs_slot
    PYTHONPATH=src python -m benchmarks.fig8_testbed prefix_cache --seed 3
"""

from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path

import jax

from repro.configs import get_smoke_config
from repro.core import LLMSched
from repro.models import init_params
from repro.serving import (
    LLMEngine,
    PagedLLMEngine,
    Request,
    ServeConfig,
    ServingCluster,
    build_engines,
)
from repro.sim import generate_workload
from repro.sim.workloads import generate_tiered_workload

from .common import emit_csv, schedulers_for, store_for

OUT_DIR = Path(__file__).parent / "out"


def _drive_engine(eng, n_requests: int, prompt_len: int, new_tokens: int):
    """Offer n_requests at once; drain; return (tokens, wall_s, jcts)."""
    pending = deque(
        Request(rid=i, prompt=[1 + i % 7] * prompt_len,
                max_new_tokens=new_tokens)
        for i in range(n_requests)
    )
    finished = []
    t0 = time.perf_counter()
    while pending or eng.batch_size or getattr(eng, "waiting", ()):
        while pending and eng.can_admit() and eng.admit(pending[0]):
            pending.popleft()
        finished.extend(eng.step())
    wall = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in finished)
    jcts = [r.finished_at - t0 for r in finished]
    return tokens, wall, jcts


def paged_vs_slot(
    n_requests: int = 32,
    prompt_len: int = 4,
    new_tokens: int = 20,
    max_len: int = 96,
    slot_batch: int = 8,
    # page_size 8: a 24-token request is exactly 3 pages, so the equal-
    # memory pool (768 tokens = 96 pages) holds all 32 requests evict-free
    page_size: int = 8,
    seed: int = 0,
    warmup: bool = True,
) -> dict:
    """Slot vs paged engine at an equal KV token budget.

    Budget = slot_batch × max_len token-slots.  The slot engine's
    concurrency is capped at ``slot_batch`` by its dense reservation;
    the paged engine spends the *same* pool on actual usage
    (prompt+decode ≈ prompt_len+new_tokens tokens per request), so ≥
    ``n_requests`` run concurrently and decode batches are much larger.
    """
    import numpy as np

    cfg = get_smoke_config("stablelm_1_6b")
    kv_budget_tokens = slot_batch * max_len
    num_pages = 1 + kv_budget_tokens // page_size
    engines = {
        "slot": LLMEngine(cfg, max_batch=slot_batch, max_len=max_len,
                          seed=seed),
        "paged": PagedLLMEngine(cfg, max_seqs=n_requests, max_len=max_len,
                                page_size=page_size, num_pages=num_pages,
                                seed=seed),
    }
    out = {
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "kv_budget_tokens": kv_budget_tokens,
        "model": cfg.name,
    }
    rows = []
    for name, eng in engines.items():
        if warmup:  # populate JIT caches so compile time is not measured
            _drive_engine(eng, n_requests, prompt_len, new_tokens)
            if hasattr(eng, "preemptions"):
                eng.preemptions = 0  # report the measured run only
        tokens, wall, jcts = _drive_engine(
            eng, n_requests, prompt_len, new_tokens
        )
        out[name] = {
            "tokens": tokens,
            "wall_s": round(wall, 3),
            "decode_throughput_tok_s": round(tokens / wall, 1),
            "avg_jct_s": round(float(np.mean(jcts)), 3),
            "p95_jct_s": round(float(np.percentile(jcts, 95)), 3),
            "max_concurrency": eng.max_batch,
            "preemptions": getattr(eng, "preemptions", 0),
        }
        rows.append([name, tokens, out[name]["wall_s"],
                     out[name]["decode_throughput_tok_s"],
                     out[name]["avg_jct_s"], out[name]["p95_jct_s"],
                     eng.max_batch, out[name]["preemptions"]])
    out["throughput_speedup"] = round(
        out["paged"]["decode_throughput_tok_s"]
        / out["slot"]["decode_throughput_tok_s"], 2
    )
    emit_csv(
        f"fig8_paged_vs_slot ({n_requests} concurrent requests, equal KV budget)",
        ["engine", "tokens", "wall_s", "decode_tok_s", "avg_jct_s",
         "p95_jct_s", "max_conc", "preemptions"],
        rows,
    )
    print(f"# paged/slot decode throughput: {out['throughput_speedup']}x\n")
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig8_paged_vs_slot.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


def multi_replica(
    n_replicas: int = 2,
    n_requests: int = 24,
    seed: int = 3,
    max_len: int = 64,
    page_size: int = 8,
    pages: int = 17,
    small_pages: int = None,
    max_batch: int = 6,
) -> dict:
    """Equal-budget replicas, live migration off vs on, same workload.

    The Llumnix scenario: requests are placed least-loaded (blind to
    future growth), but decode lengths vary 8–56 tokens, so one replica
    ends up KV-saturated — eviction/recompute churn — while its peer has
    headroom (its requests happened to finish early).  With migration
    on, the rebalancer moves the starved replica's youngest request to
    the peer instead of letting it churn.  All replicas share one set
    of weights, so the move is token-for-token lossless.  Pass
    ``small_pages`` to make replica 0 smaller (heterogeneous budgets).

    The fleet is driven step-deterministically (one decode iteration per
    tick for every replica) over a seeded request trace, and JCT is
    measured in *engine steps* — each step costs the same decode compute
    in both modes, so the comparison is exact and reproducible, not
    subject to wall-clock jitter.  Wall time is reported as a secondary
    metric.

    Writes ``benchmarks/out/fig8_multi_replica.json`` with per-mode
    avg/p95 JCT (steps), migration/preemption counts, and the JCT delta.
    """
    import numpy as np

    from repro.serving import Rebalancer

    cfg = get_smoke_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.key(0))[0]
    rng = np.random.default_rng(seed)
    dec_lens = rng.integers(8, 56, n_requests).tolist()
    arrivals = np.sort(rng.integers(0, 20, n_requests)).tolist()

    def build_engines():
        return [
            PagedLLMEngine(
                cfg, max_seqs=max_batch, max_len=max_len,
                page_size=page_size,
                num_pages=small_pages if (i == 0 and small_pages) else pages,
                params=params,
            )
            for i in range(n_replicas)
        ]

    out = {
        "n_replicas": n_replicas,
        "n_requests": n_requests,
        "seed": seed,
        "page_size": page_size,
        "pages_per_replica": pages,
        "small_pages": small_pages,
        "model": cfg.name,
    }
    rows = []
    for mode, migrate in (("no_migration", False), ("migration", True)):
        engines = build_engines()
        rb = Rebalancer(engines) if migrate else None
        cur_step = [0]
        finish_step = {}

        def _done(req, _fs=finish_step, _cs=cur_step):
            _fs[req.rid] = _cs[0]

        pending = deque(
            (arrivals[i],
             Request(rid=i, prompt=[1 + i % 7, 2, 3],
                     max_new_tokens=dec_lens[i], on_finish=_done))
            for i in range(n_requests)
        )
        t0 = time.perf_counter()
        while pending or any(
            e.batch_size or e.waiting for e in engines
        ):
            # admit due arrivals least-loaded (same policy both modes —
            # blind to future KV growth, as real admission must be)
            while pending and pending[0][0] <= cur_step[0]:
                _, req = pending[0]
                cands = sorted(
                    (e for e in engines if e.can_admit()),
                    key=lambda e: (e.batch_size, -e.free_token_capacity),
                )
                if not any(e.admit(req) for e in cands):
                    break  # no capacity this tick; retry next
                pending.popleft()
            if rb is not None:
                rb.step()
            for e in engines:
                if e.batch_size or e.waiting:
                    e.step()
            cur_step[0] += 1
        wall = time.perf_counter() - t0
        jcts = [finish_step[i] - arrivals[i] for i in range(n_requests)]
        out[mode] = {
            "avg_jct_steps": round(float(np.mean(jcts)), 2),
            "p95_jct_steps": round(float(np.percentile(jcts, 95)), 2),
            "makespan_steps": cur_step[0],
            "wall_s": round(wall, 3),
            "preemptions": sum(e.preemptions for e in engines),
            "migrations": rb.migrations if rb else 0,
        }
        rows.append([mode, out[mode]["avg_jct_steps"],
                     out[mode]["p95_jct_steps"], out[mode]["makespan_steps"],
                     out[mode]["preemptions"], out[mode]["migrations"]])
    out["jct_delta_pct"] = round(
        100.0
        * (out["no_migration"]["avg_jct_steps"]
           - out["migration"]["avg_jct_steps"])
        / max(out["no_migration"]["avg_jct_steps"], 1e-9),
        1,
    )
    emit_csv(
        f"fig8_multi_replica ({n_replicas} replicas, live migration "
        "off/on; same seeded trace; JCT in engine steps)",
        ["mode", "avg_jct_steps", "p95_jct_steps", "makespan_steps",
         "preemptions", "migrations"],
        rows,
    )
    print(f"# migration JCT reduction: {out['jct_delta_pct']}%\n")
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig8_multi_replica.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


def prefix_cache(
    n_requests: int = 24,
    shared_len: int = 32,
    suffix_len: int = 4,
    new_tokens: int = 12,
    max_len: int = 96,
    page_size: int = 8,
    num_pages: int = 49,
    max_seqs: int = 6,
    prefill_chunk: int = 8,
    seed: int = 3,
) -> dict:
    """Shared-system-prompt trace, radix prefix cache off vs on.

    The compound-app pattern (PAPER.md §III): every request re-feeds
    the same ``shared_len``-token system prompt followed by a short
    request-specific suffix, so without reuse the fleet prefers to
    redundantly prefill the identical prefix ``n_requests`` times.
    Both modes run the *same* seeded trace on the *same* page budget
    and are driven step-deterministically (JCT measured in engine
    steps, wall time reported as a secondary metric); greedy decode
    makes the per-request outputs identical across modes, so the
    comparison isolates exactly the prefill work and its knock-on
    queueing effects.

    Writes ``benchmarks/out/fig8_prefix_cache.json`` with per-mode
    prefill token totals, JCT (steps), cache counters, and the
    headline ``prefill_reduction_pct`` (target: ≥ 30).
    """
    import numpy as np

    cfg = get_smoke_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.key(0))[0]
    rng = np.random.default_rng(seed)
    shared = [3 + int(x) for x in rng.integers(0, 29, shared_len)]
    suffixes = [
        [40 + int(x) for x in rng.integers(0, 29, suffix_len)]
        for _ in range(n_requests)
    ]
    arrivals = np.sort(rng.integers(0, 3 * n_requests, n_requests)).tolist()

    out = {
        "n_requests": n_requests,
        "shared_prompt_tokens": shared_len,
        "suffix_tokens": suffix_len,
        "new_tokens": new_tokens,
        "page_size": page_size,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "seed": seed,
        "model": cfg.name,
    }
    rows = []
    outputs = {}
    for mode, cached in (("no_cache", False), ("cache", True)):
        eng = PagedLLMEngine(
            cfg, max_seqs=max_seqs, max_len=max_len, page_size=page_size,
            num_pages=num_pages, params=params, prefill_chunk=prefill_chunk,
            prefix_cache=cached,
        )
        cur_step = [0]
        finish_step = {}
        toks = {}

        def _done(req, _fs=finish_step, _tk=toks, _cs=cur_step):
            _fs[req.rid] = _cs[0]
            _tk[req.rid] = list(req.out_tokens)

        pending = deque(
            (arrivals[i],
             Request(rid=i, prompt=shared + suffixes[i],
                     max_new_tokens=new_tokens, on_finish=_done))
            for i in range(n_requests)
        )
        reqs = [r for _, r in pending]
        t0 = time.perf_counter()
        while pending or eng.batch_size or eng.waiting:
            while pending and pending[0][0] <= cur_step[0]:
                _, req = pending[0]
                if not (eng.can_admit() and eng.admit(req)):
                    break
                pending.popleft()
            if eng.batch_size or eng.waiting:
                eng.step()
            cur_step[0] += 1
        wall = time.perf_counter() - t0
        eng.allocator.check_no_leaks()
        outputs[mode] = toks
        jcts = [finish_step[i] - arrivals[i] for i in range(n_requests)]
        prefill = sum(r.prefill_tokens for r in reqs)
        idx = eng.prefix_index
        out[mode] = {
            "prefill_tokens": prefill,
            "prefill_skipped_tokens": eng.prefill_skipped_tokens,
            "avg_jct_steps": round(float(np.mean(jcts)), 2),
            "p95_jct_steps": round(float(np.percentile(jcts, 95)), 2),
            "makespan_steps": cur_step[0],
            "wall_s": round(wall, 3),
            "preemptions": eng.preemptions,
            "cow_copies": eng.cow_copies,
            "prefix_hits": idx.hits if idx else 0,
            "prefix_evictions": idx.evictions if idx else 0,
        }
        rows.append([mode, prefill, eng.prefill_skipped_tokens,
                     out[mode]["avg_jct_steps"], out[mode]["p95_jct_steps"],
                     eng.preemptions, out[mode]["prefix_hits"],
                     eng.cow_copies])
    assert outputs["cache"] == outputs["no_cache"], (
        "prefix cache changed greedy decode outputs"
    )
    out["outputs_identical"] = True
    base = out["no_cache"]["prefill_tokens"]
    out["prefill_reduction_pct"] = round(
        100.0 * (base - out["cache"]["prefill_tokens"]) / max(base, 1), 1
    )
    out["jct_delta_pct"] = round(
        100.0
        * (out["no_cache"]["avg_jct_steps"] - out["cache"]["avg_jct_steps"])
        / max(out["no_cache"]["avg_jct_steps"], 1e-9),
        1,
    )
    emit_csv(
        f"fig8_prefix_cache ({n_requests} shared-prompt requests, equal KV "
        "budget; JCT in engine steps)",
        ["mode", "prefill_tok", "skipped_tok", "avg_jct_steps",
         "p95_jct_steps", "preemptions", "hits", "cow"],
        rows,
    )
    print(
        f"# prefill-token reduction: {out['prefill_reduction_pct']}% "
        f"(avg-JCT delta: {out['jct_delta_pct']}%)\n"
    )
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig8_prefix_cache.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


def main(mixes=("planning", "chain"), jobs: int = 14, seed: int = 11,
         include_artifacts: bool = True, slo: bool = False) -> dict:
    t0 = time.perf_counter()
    cfg = get_smoke_config("stablelm_1_6b")
    serve_cfg = ServeConfig(engine="slot", replicas=1, max_batch=4,
                            max_len=96, n_regular=4,
                            token_scale=24.0, time_scale=24.0, seed=0)
    rows = []
    results = {}
    for mix in mixes:
        store = store_for(mix)
        scheds = {
            "fcfs": schedulers_for(mix, train_decima=False)["fcfs"],
            "sjf": schedulers_for(mix, train_decima=False)["sjf"],
            "llmsched": LLMSched(store, epsilon=0.2, seed=0),
        }
        for name, sched in scheds.items():
            engines = build_engines(cfg, serve_cfg)
            cluster = ServingCluster(sched, engines, serve_cfg)
            if slo:
                wl = generate_tiered_workload(mix, jobs, arrival_rate=0.9,
                                              seed=seed)
            else:
                wl = generate_workload(mix, jobs, arrival_rate=0.9, seed=seed)
            r = cluster.run(wl)
            results[(mix, name)] = r
            g = r.goodput()
            rows.append([mix, name, round(r.avg_jct, 2), len(r.jcts),
                         r.tokens_generated, round(r.avg_overhead_ms, 2),
                         "-" if g is None else round(g, 3)])
    emit_csv(
        "fig8_testbed (real engines; scaled tokens)",
        ["workload", "scheduler", "avg_jct_s", "jobs", "tokens",
         "sched_overhead_ms", "goodput"],
        rows,
    )
    if include_artifacts:
        results["paged_vs_slot"] = paged_vs_slot()
        results["multi_replica"] = multi_replica()
        results["prefix_cache"] = prefix_cache()
    print(f"# fig8 wall time: {time.perf_counter()-t0:.0f}s\n")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "mode", nargs="?", default="all",
        choices=["all", "schedulers", "paged_vs_slot", "multi_replica",
                 "prefix_cache"],
    )
    ap.add_argument("--seed", type=int, default=None,
                    help="trace seed (defaults to each mode's seeded value)")
    ap.add_argument("--slo", action="store_true",
                    help="attach tiered SLOs to the scheduler-table "
                         "workloads and report goodput")
    args = ap.parse_args()
    seed_kw = {} if args.seed is None else {"seed": args.seed}
    if args.mode == "multi_replica":
        multi_replica(**seed_kw)
    elif args.mode == "paged_vs_slot":
        paged_vs_slot(**seed_kw)
    elif args.mode == "prefix_cache":
        prefix_cache(**seed_kw)
    elif args.mode == "schedulers":
        main(include_artifacts=False, slo=args.slo, **seed_kw)
    else:
        main(slo=args.slo, **seed_kw)
