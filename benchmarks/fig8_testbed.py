"""Fig. 8 — testbed: real engines (jitted decode, continuous batching)
with the scheduler in the loop.

Scaled to CPU: a smoke-size model serves compressed token budgets; the
relative JCT ordering across schedulers is the reproduction target.
"""

from __future__ import annotations

import time

from repro.configs import get_smoke_config
from repro.core import LLMSched
from repro.serving import LLMEngine, ServingCluster
from repro.sim import generate_workload

from .common import emit_csv, schedulers_for, store_for


def main(mixes=("planning", "chain"), jobs: int = 14, seed: int = 11) -> dict:
    t0 = time.time()
    cfg = get_smoke_config("stablelm_1_6b")
    rows = []
    results = {}
    for mix in mixes:
        store = store_for(mix)
        scheds = {
            "fcfs": schedulers_for(mix, train_decima=False)["fcfs"],
            "sjf": schedulers_for(mix, train_decima=False)["sjf"],
            "llmsched": LLMSched(store, epsilon=0.2, seed=0),
        }
        for name, sched in scheds.items():
            engines = [LLMEngine(cfg, max_batch=4, max_len=96, seed=0)]
            cluster = ServingCluster(sched, engines, n_regular=4,
                                     token_scale=24.0, time_scale=24.0)
            wl = generate_workload(mix, jobs, arrival_rate=0.9, seed=seed)
            r = cluster.run(wl)
            results[(mix, name)] = r
            rows.append([mix, name, round(r.avg_jct, 2), len(r.jcts),
                         r.tokens_generated, round(r.avg_overhead_ms, 2)])
    emit_csv(
        "fig8_testbed (real engines; scaled tokens)",
        ["workload", "scheduler", "avg_jct_s", "jobs", "tokens",
         "sched_overhead_ms"],
        rows,
    )
    print(f"# fig8 wall time: {time.time()-t0:.0f}s\n")
    return results


if __name__ == "__main__":
    main()
