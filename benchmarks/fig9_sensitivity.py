"""Fig. 9 — sensitivity: exploration probability ε, task sampling ratio r,
job arrival rate λ, and replica count × live migration (normalized
average JCT)."""

from __future__ import annotations

import numpy as np

from repro.core import LLMSched
from repro.sim import simulate

from .common import SEEDS, cluster_for, emit_csv, store_for


def main(mix_eps: str = "mixed", n_jobs: int = 80) -> dict:
    results = {}
    rows = []

    # (a) exploration probability ε
    store = store_for(mix_eps)
    cfg = cluster_for(mix_eps)
    base = None
    for eps in (0.0, 0.1, 0.2, 0.3, 0.5, 0.7):
        js = [
            simulate(LLMSched(store, epsilon=eps, seed=0), mix=mix_eps,
                     n_jobs=n_jobs, seed=s, **cfg).avg_jct
            for s in SEEDS[:2]
        ]
        jct = float(np.mean(js))
        base = base or jct
        results[("eps", eps)] = jct
        rows.append(["epsilon", eps, round(jct, 2), round(jct / base, 3)])

    # (b) task sampling ratio r
    base = None
    for r in (0.1, 0.3, 0.5, 0.8, 1.0):
        js = [
            simulate(LLMSched(store, epsilon=0.2, sampling_ratio=r, seed=0),
                     mix=mix_eps, n_jobs=n_jobs, seed=s, **cfg).avg_jct
            for s in SEEDS[:2]
        ]
        jct = float(np.mean(js))
        base = base or jct
        results[("r", r)] = jct
        rows.append(["sampling_ratio", r, round(jct, 2), round(jct / base, 3)])

    # (c) arrival rate λ (lightly / moderately / heavily loaded)
    for mix in ("mixed", "predefined", "chain", "planning"):
        st = store_for(mix)
        base = None
        for lam in (0.6, 0.9, 1.2):
            c = cluster_for(mix)  # resources fixed at the λ=0.9 design point
            js = [
                simulate(LLMSched(st, epsilon=0.2, seed=0), mix=mix,
                         n_jobs=n_jobs, seed=s, arrival_rate=lam, **c).avg_jct
                for s in SEEDS[:2]
            ]
            jct = float(np.mean(js))
            base = base or jct
            results[("lambda", mix, lam)] = jct
            rows.append([f"lambda({mix})", lam, round(jct, 2),
                         round(jct / base, 3)])

    # (d) replica count × live migration: fixed total LLM slots split
    # over 1/2/4 KV-budgeted replicas (the multi-replica tentpole knob).
    # More, smaller replicas fragment the KV pool — migration recovers
    # most of the loss by moving requests off saturated replicas.
    st = store_for(mix_eps)
    base = None
    for n, mb, kv in ((1, 16, 12000), (2, 8, 6000), (4, 4, 3000)):
        for mig in ((False,) if n == 1 else (False, True)):
            js = [
                simulate(LLMSched(st, epsilon=0.2, seed=0), mix=mix_eps,
                         n_jobs=n_jobs, seed=s, n_regular=4, n_llm=n,
                         max_batch=mb, kv_budget_tokens=kv,
                         migrate=mig).avg_jct
                for s in SEEDS[:2]
            ]
            jct = float(np.mean(js))
            base = base or jct
            label = f"{n}x{mb}" + ("+migrate" if mig else "")
            results[("replicas", label)] = jct
            rows.append(["replicas", label, round(jct, 2),
                         round(jct / base, 3)])

    emit_csv(
        "fig9_sensitivity (normalized avg JCT)",
        ["knob", "value", "avg_jct_s", "normalized"],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
