"""Fig. 7 — simulation: average JCT per scheduler × workload × #jobs.

Paper claim: LLMSched reduces average JCT by 36–79% (mixed), 14–46%
(predefined), 36–67% (chain-like), 24–52% (planning) vs the baselines,
with the advantage growing with job count.
"""

from __future__ import annotations

import time

from .common import emit_csv, run_grid, schedulers_for

JOB_COUNTS = (50, 100, 200)
MIXES = ("mixed", "predefined", "chain", "planning")


def main(job_counts=JOB_COUNTS, mixes=MIXES) -> dict:
    t0 = time.perf_counter()
    rows = []
    results = {}
    for mix in mixes:
        scheds = schedulers_for(mix)
        for n in job_counts:
            res = run_grid(mix, n, schedulers=scheds)
            results[(mix, n)] = res
            ours = res["llmsched"]
            for name, jct in sorted(res.items()):
                red = 100.0 * (1 - ours / jct) if name != "llmsched" and jct > 0 else 0.0
                rows.append([mix, n, name, round(jct, 2), round(red, 1)])
    emit_csv(
        "fig7_simulation (avg JCT; reduction% = LLMSched vs baseline)",
        ["workload", "n_jobs", "scheduler", "avg_jct_s", "llmsched_reduction_pct"],
        rows,
    )
    print(f"# fig7 wall time: {time.perf_counter()-t0:.0f}s\n")
    return results


if __name__ == "__main__":
    main()
