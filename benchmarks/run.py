"""Benchmark harness: one entry per paper table/figure + the roofline.

``python -m benchmarks.run [--quick] [--only fig7,table1,...]``
Emits CSV blocks (name, header, rows) to stdout.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller job counts (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig7,fig8,fig9,fig10,"
                         "fig10_cascade,table1,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    t0 = time.perf_counter()
    if want("fig1"):
        from . import fig1_characterization
        fig1_characterization.main(n_jobs=200 if args.quick else 400)
    if want("fig7"):
        from . import fig7_simulation
        fig7_simulation.main(job_counts=(40, 80) if args.quick else (50, 100, 200))
    if want("table1"):
        from . import table1_overhead
        table1_overhead.main(n_jobs=30 if args.quick else 60)
    if want("fig9"):
        from . import fig9_sensitivity
        fig9_sensitivity.main(n_jobs=40 if args.quick else 80)
    if want("fig10"):
        from . import fig10_ablation
        fig10_ablation.main(n_jobs=50 if args.quick else 100)
    if want("fig10_cascade"):
        from . import fig10_cascade
        fig10_cascade.run(jobs=40 if args.quick else 60)
    if want("fig8"):
        from . import fig8_testbed
        fig8_testbed.main(jobs=8 if args.quick else 14)
    if want("roofline"):
        from . import roofline
        roofline.main()
    print(f"# total benchmark wall time: {time.perf_counter()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
