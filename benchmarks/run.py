"""Benchmark harness: one entry per paper table/figure + the roofline.

``python -m benchmarks.run [--quick] [--only fig7,table1,...]``
Emits CSV blocks (name, header, rows) to stdout.

``--only`` names are validated against the registry: an unknown name is
a hard error (it used to be silently skipped, so a typo like
``--only fig7_sl0`` ran nothing and exited 0 — green CI, no data).
"""

from __future__ import annotations

import argparse
import sys
import time


def _fig1(quick):
    from . import fig1_characterization
    fig1_characterization.main(n_jobs=200 if quick else 400)


def _fig7(quick):
    from . import fig7_simulation
    fig7_simulation.main(job_counts=(40, 80) if quick else (50, 100, 200))


def _fig7_slo(quick):
    from . import fig7_slo
    fig7_slo.run(jobs=30 if quick else 60)


def _table1(quick):
    from . import table1_overhead
    table1_overhead.main(n_jobs=30 if quick else 60)


def _fig9(quick):
    from . import fig9_sensitivity
    fig9_sensitivity.main(n_jobs=40 if quick else 80)


def _fig10(quick):
    from . import fig10_ablation
    fig10_ablation.main(n_jobs=50 if quick else 100)


def _fig10_cascade(quick):
    from . import fig10_cascade
    fig10_cascade.run(jobs=40 if quick else 60)


def _fig8(quick):
    from . import fig8_testbed
    fig8_testbed.main(jobs=8 if quick else 14)


def _fig11(quick):
    from . import fig11_kernels
    fig11_kernels.run(quick=quick)


def _roofline(quick):
    from . import roofline
    roofline.main()


# insertion order == execution order (cheap sims first, testbed last)
ENTRIES = {
    "fig1": _fig1,
    "fig7": _fig7,
    "fig7_slo": _fig7_slo,
    "table1": _table1,
    "fig9": _fig9,
    "fig10": _fig10,
    "fig10_cascade": _fig10_cascade,
    "fig8": _fig8,
    "fig11": _fig11,
    "roofline": _roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller job counts (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma list of entries: " + ",".join(ENTRIES))
    args = ap.parse_args(argv)
    only = None
    if args.only:
        only = {name.strip() for name in args.only.split(",") if name.strip()}
        unknown = sorted(only - set(ENTRIES))
        if unknown:
            ap.error(
                f"unknown benchmark name(s): {', '.join(unknown)} "
                f"(known: {', '.join(ENTRIES)})"
            )
        if not only:
            ap.error("--only given but no benchmark names parsed")

    t0 = time.perf_counter()
    for name, entry in ENTRIES.items():
        if only is None or name in only:
            entry(args.quick)
    print(f"# total benchmark wall time: {time.perf_counter()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
