"""Roofline report: aggregate dry-run artifacts into the §Roofline table.

Reads benchmarks/artifacts/dryrun_*.json (produced by repro.launch.dryrun)
and prints, per (arch × shape × mesh): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a one-line suggestion on
what would move the dominant term.
"""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List

ART_DIR = pathlib.Path(__file__).resolve().parent / "artifacts"


def _suggest(dom: str, rec: Dict) -> str:
    arch = rec["arch"]
    kind = rec["kind"]
    if dom == "collective":
        if kind == "train":
            return ("sequence-shard activations between blocks (all-reduce -> "
                    "reduce-scatter+all-gather) and keep collectives bf16")
        return "shard KV over heads where divisible; overlap a2a with compute"
    if dom == "memory":
        if kind == "decode":
            return "int8 KV cache / MLA-style compressed cache; fuse dequant into decode kernel"
        return "remat policy 'minimal'; fuse attention (flash) to skip score materialization"
    return "increase per-chip batch or reduce mesh to lift MXU occupancy"


def load_records(variant: str = "baseline") -> List[Dict]:
    recs = []
    for p in sorted(ART_DIR.glob("dryrun_*.json")):
        with open(p) as f:
            r = json.load(f)
        if r.get("variant", "baseline") == variant:
            recs.append(r)
    return recs


def main(variant: str = "baseline") -> List[Dict]:
    recs = load_records(variant)
    if not recs:
        print("# roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all --both-meshes` first")
        return []
    print(f"# roofline ({variant}): {len(recs)} cells")
    hdr = ["arch", "shape", "mesh", "compute_ms", "memory_ms", "collective_ms",
           "bottleneck", "useful_flops_ratio", "args_GiB_per_dev", "suggestion"]
    print(",".join(hdr))
    for r in recs:
        rt = r["roofline"]
        terms = {
            "compute": rt["compute_s"],
            "memory": rt["memory_s"],
            "collective": rt["collective_s"],
        }
        dom = max(terms, key=terms.get)
        ufr = r.get("useful_flops_ratio")
        row = [
            r["arch"], r["shape"], r["mesh"],
            f"{terms['compute']*1e3:.2f}", f"{terms['memory']*1e3:.2f}",
            f"{terms['collective']*1e3:.2f}", dom,
            f"{ufr:.2f}" if ufr else "-",
            f"{r['memory']['analytic_arg_bytes_per_dev']/2**30:.2f}",
            _suggest(dom, r),
        ]
        print(",".join(str(x) for x in row))
    print()
    return recs


if __name__ == "__main__":
    main()
