"""Fig. 10 — ablation: LLMSched vs 'w/o BN' (historical means only) and
'w/o uncertainty' (pure SRTF on BN posteriors)."""

from __future__ import annotations

import numpy as np

from repro.core import LLMSched
from repro.sim import simulate

from .common import SEEDS, cluster_for, emit_csv, store_for

MIXES = ("mixed", "predefined", "chain", "planning")


def main(n_jobs: int = 100) -> dict:
    rows = []
    results = {}
    for mix in MIXES:
        store = store_for(mix)
        cfg = cluster_for(mix)
        variants = {
            "llmsched": LLMSched(store, epsilon=0.2, seed=0),
            "wo_bn": LLMSched(store, epsilon=0.2, use_bn=False, seed=0),
            "wo_uncertainty": LLMSched(store, epsilon=0.0, seed=0),
        }
        jcts = {}
        for name, s in variants.items():
            js = [
                simulate(s, mix=mix, n_jobs=n_jobs, seed=seed, **cfg).avg_jct
                for seed in SEEDS
            ]
            jcts[name] = float(np.mean(js))
        results[mix] = jcts
        base = jcts["llmsched"]
        for name, v in jcts.items():
            rows.append([mix, name, round(v, 2), round(v / base, 3)])
    emit_csv(
        "fig10_ablation (normalized to full LLMSched)",
        ["workload", "variant", "avg_jct_s", "normalized"],
        rows,
    )
    return results


if __name__ == "__main__":
    main()
