"""Fig. 11 — fused paged-attention kernels and int8 KV pages.

Three sections, one JSON artifact (``benchmarks/out/fig11_kernels.json``):

- ``kernel_error`` — max |kernel − oracle| for the paged decode and the
  fused chunked-prefill kernels, fp32 and int8 pages, on seeded random
  pools.  The fp32 numbers certify the fused path against the dense
  reference; the int8 numbers bound the quantization error the per-page
  scales admit.
- ``capacity`` — pages (and tokens) a fixed byte budget buys under each
  ``kv_dtype``: the static ~1.6–4× capacity-per-byte claim (exact ratio
  depends on the compute dtype; int8 pays 4 bytes of scale per token
  per kv head on top of the 1-byte payload).
- ``serving`` — the claim end to end: two ``PagedLLMEngine`` fleets at
  an *equal KV byte budget* (``pages_for_byte_budget``), fp32 vs int8
  pages, serving the same seeded burst step-deterministically.  int8
  must admit strictly more concurrent requests and must not regress
  average JCT by more than 5 % (it should *improve* it at a starved
  budget — fewer evictions); both gates are recorded in the artifact
  for the nightly workflow to enforce.

A ``roofline`` block accompanies the capacity section: per decoded
token, attention reads the whole resident KV once, so bytes-per-token
drop by the same ratio pages grow — the kernel stays memory-bound and
the capacity win is also a bandwidth win.

CLI::

    PYTHONPATH=src python -m benchmarks.fig11_kernels           # full
    PYTHONPATH=src python -m benchmarks.fig11_kernels --quick
"""

from __future__ import annotations

import json
import time
from collections import deque
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.kernels import ops
from repro.kernels.ref import (
    attention_ref,
    dequantize_pages_ref,
    gather_pages,
    quantize_kv_ref,
)
from repro.models import init_params
from repro.serving import PagedLLMEngine, Request

from .common import emit_csv

OUT_DIR = Path(__file__).parent / "out"


# ---------------------------------------------------------------------------
# section 1: kernel error vs oracle
# ---------------------------------------------------------------------------
def _rand_pools(key, n_pages, page_size, K, hd):
    kk, kv = jax.random.split(key)
    k = jax.random.normal(kk, (n_pages, page_size, K, hd), jnp.float32)
    v = jax.random.normal(kv, (n_pages, page_size, K, hd), jnp.float32)
    return k, v


def kernel_error(seed: int = 0) -> dict:
    """Max abs error of both kernels vs the dense float oracle."""
    H, K, hd, ps = 4, 2, 16, 8
    n_pages, B = 16, 3
    key = jax.random.key(seed)
    kq, kp, kb = jax.random.split(key, 3)
    k_pages, v_pages = _rand_pools(kp, n_pages, ps, K, hd)
    out = {}

    # --- decode: B requests, random lengths/tables --------------------------
    lengths = jnp.array([5, 17, 26], jnp.int32)
    rng = np.random.default_rng(seed)
    bt = np.zeros((B, n_pages), np.int32)
    used = rng.permutation(np.arange(1, n_pages))
    pos = 0
    for i in range(B):
        need = -(-int(lengths[i]) // ps)
        bt[i, :need] = used[pos:pos + need]
        pos += need
    bt = jnp.asarray(bt)
    q = jax.random.normal(kq, (B, H, hd), jnp.float32)

    def dense_decode(kp_, vp_):
        outs = []
        for i in range(B):
            n = -(-int(lengths[i]) // ps)
            kk = gather_pages(kp_, bt[i:i + 1, :n]).reshape(1, -1, K, hd)
            vv = gather_pages(vp_, bt[i:i + 1, :n]).reshape(1, -1, K, hd)
            outs.append(attention_ref(
                q[i:i + 1, None], kk, vv, causal=False,
                kv_len=lengths[i:i + 1],
            )[0, 0])
        return jnp.stack(outs)

    # impl="pallas" so the *kernel* is measured (interpret-mode on CPU);
    # impl="auto" would fall back to the ref path, which IS the oracle
    got = ops.paged_decode_attention(
        q, k_pages, v_pages, bt, lengths, impl="pallas")
    out["decode_fp32"] = float(jnp.max(jnp.abs(got - dense_decode(
        k_pages, v_pages))))

    kq8, ks = quantize_kv_ref(k_pages)
    vq8, vs = quantize_kv_ref(v_pages)
    got8 = ops.paged_decode_attention(
        q, kq8, vq8, bt, lengths, k_scales=ks, v_scales=vs, impl="pallas")
    # oracle for int8 = dense attention over the *dequantized* pools
    out["decode_int8"] = float(jnp.max(jnp.abs(got8 - dense_decode(
        dequantize_pages_ref(kq8, ks), dequantize_pages_ref(vq8, vs)))))

    # --- fused chunked prefill: non-aligned past/chunk ----------------------
    past, C = 12, 7
    table = jnp.asarray(used[: -(-(past + C) // ps)].astype(np.int32))
    table = jnp.pad(table, (0, n_pages - table.shape[0]))
    qc = jax.random.normal(kb, (C, H, hd), jnp.float32)

    def dense_prefill(kp_, vp_):
        n = -(-(past + C) // ps)
        kk = gather_pages(kp_, table[None, :n]).reshape(1, -1, K, hd)
        vv = gather_pages(vp_, table[None, :n]).reshape(1, -1, K, hd)
        return attention_ref(
            qc[None], kk, vv, causal=True, q_offset=past,
            kv_len=jnp.array([past + C], jnp.int32),
        )[0]

    got = ops.paged_prefill_attention(
        qc, k_pages, v_pages, table, past, impl="pallas")
    out["prefill_fp32"] = float(jnp.max(jnp.abs(
        got - dense_prefill(k_pages, v_pages))))
    got8 = ops.paged_prefill_attention(
        qc, kq8, vq8, table, past, k_scales=ks, v_scales=vs, impl="pallas")
    out["prefill_int8"] = float(jnp.max(jnp.abs(got8 - dense_prefill(
        dequantize_pages_ref(kq8, ks), dequantize_pages_ref(vq8, vs)))))
    return out


# ---------------------------------------------------------------------------
# section 2: capacity + roofline at a byte budget
# ---------------------------------------------------------------------------
def capacity(cfg, page_size: int, budget_bytes: int) -> dict:
    """Pages/tokens per byte budget and the per-token read traffic."""
    out = {"budget_bytes": budget_bytes, "page_size": page_size}
    for dt in ("fp32", "int8"):
        pages = PagedLLMEngine.pages_for_byte_budget(
            cfg, page_size, budget_bytes, dt)
        out[dt] = {"pages": pages, "tokens": pages * page_size}
    out["capacity_ratio"] = round(
        out["int8"]["pages"] / max(out["fp32"]["pages"], 1), 3)
    # decode reads every resident KV byte once per token: traffic per
    # resident token is exactly the per-token storage footprint, so the
    # bandwidth ratio equals the inverse capacity ratio at fixed tokens
    K, hd = cfg.n_kv_heads, cfg.hd
    itemsize = jnp.zeros((), cfg.jdtype).dtype.itemsize
    fp32_tok = K * hd * itemsize * 2
    int8_tok = K * (hd * 1 + 4) * 2
    out["roofline"] = {
        "fp32_bytes_per_token": fp32_tok,
        "int8_bytes_per_token": int8_tok,
        "read_traffic_ratio": round(int8_tok / fp32_tok, 3),
    }
    return out


# ---------------------------------------------------------------------------
# section 3: serving at an equal byte budget
# ---------------------------------------------------------------------------
def serving(
    cfg,
    params,
    budget_bytes: int,
    n_requests: int = 16,
    prompt_len: int = 4,
    new_tokens: int = 20,
    page_size: int = 8,
    max_len: int = 96,
    seed: int = 3,
) -> dict:
    """fp32 vs int8 engines, same byte budget, same seeded burst.

    Admission is capacity-aware: a request enters only when the pool
    has un-reserved room for its *full* prompt+decode footprint, so
    ``max_concurrency`` measures how many requests the budget sustains
    side by side (not how many squeeze in before eviction churn).  Both
    engines run the identical policy; only the page count their byte
    budget buys differs.
    """
    out = {
        "budget_bytes": budget_bytes,
        "n_requests": n_requests,
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "seed": seed,
    }
    # whole-lifetime footprint of one request, in pages
    need = -(-(prompt_len + new_tokens) // page_size)
    rows = []
    for dt in ("fp32", "int8"):
        pages = PagedLLMEngine.pages_for_byte_budget(
            cfg, page_size, budget_bytes, dt)
        eng = PagedLLMEngine(
            cfg, max_seqs=n_requests, max_len=max_len, page_size=page_size,
            num_pages=pages, params=params, kv_dtype=dt,
        )
        assert eng.page_bytes * pages <= budget_bytes
        cur_step = [0]
        finish_step = {}
        reserved = [0]

        def _done(req, _fs=finish_step, _cs=cur_step, _rv=reserved):
            _fs[req.rid] = _cs[0]
            _rv[0] -= need

        pending = deque(
            Request(rid=i, prompt=[1 + i % 7] * prompt_len,
                    max_new_tokens=new_tokens, on_finish=_done)
            for i in range(n_requests)
        )
        max_conc = 0
        t0 = time.perf_counter()
        while pending or eng.batch_size or eng.waiting:
            while (pending and reserved[0] + need < eng.num_pages
                   and eng.can_admit() and eng.admit(pending[0])):
                pending.popleft()
                reserved[0] += need
            max_conc = max(max_conc, eng.batch_size)
            if eng.batch_size or eng.waiting:
                eng.step()
            cur_step[0] += 1
        wall = time.perf_counter() - t0
        eng.allocator.check_no_leaks()
        jcts = [finish_step[i] for i in range(n_requests)]
        out[dt] = {
            "num_pages": pages,
            "pool_bytes": eng.page_bytes * pages,
            "max_concurrency": max_conc,
            "avg_jct_steps": round(float(np.mean(jcts)), 2),
            "p95_jct_steps": round(float(np.percentile(jcts, 95)), 2),
            "makespan_steps": cur_step[0],
            "preemptions": eng.preemptions,
            "wall_s": round(wall, 3),
        }
        rows.append([dt, pages, max_conc, out[dt]["avg_jct_steps"],
                     out[dt]["p95_jct_steps"], eng.preemptions])
    out["admission_gain"] = (
        out["int8"]["max_concurrency"] - out["fp32"]["max_concurrency"])
    out["jct_ratio"] = round(
        out["int8"]["avg_jct_steps"]
        / max(out["fp32"]["avg_jct_steps"], 1e-9), 3)
    # acceptance gates consumed by the nightly workflow
    out["pass_admission"] = out["admission_gain"] > 0
    out["pass_jct"] = out["jct_ratio"] <= 1.05
    emit_csv(
        f"fig11_serving (equal {budget_bytes}-byte KV budget, "
        f"{n_requests}-request burst; JCT in engine steps)",
        ["kv_dtype", "pages", "max_conc", "avg_jct_steps", "p95_jct_steps",
         "preemptions"],
        rows,
    )
    print(f"# int8 admission gain: +{out['admission_gain']} concurrent "
          f"(JCT ratio {out['jct_ratio']})\n")
    return out


def run(quick: bool = False, seed: int = 3, budget_bytes: int = 1 << 17) -> dict:
    """Run all three sections; write the fig11 artifact."""
    t0 = time.perf_counter()
    cfg = get_smoke_config("stablelm_1_6b")
    params = init_params(cfg, jax.random.key(0))[0]

    err = kernel_error()
    emit_csv(
        "fig11_kernel_error (max |kernel - oracle|)",
        ["case", "max_abs_err"],
        [[k, f"{v:.3e}"] for k, v in err.items()],
    )
    cap = capacity(cfg, page_size=8, budget_bytes=budget_bytes)
    emit_csv(
        f"fig11_capacity ({budget_bytes}-byte budget)",
        ["kv_dtype", "pages", "tokens", "bytes_per_token"],
        [
            ["fp32", cap["fp32"]["pages"], cap["fp32"]["tokens"],
             cap["roofline"]["fp32_bytes_per_token"]],
            ["int8", cap["int8"]["pages"], cap["int8"]["tokens"],
             cap["roofline"]["int8_bytes_per_token"]],
        ],
    )
    srv = serving(
        cfg, params, budget_bytes,
        n_requests=8 if quick else 16,
        seed=seed,
    )
    out = {
        "model": cfg.name,
        "kernel_error": err,
        "capacity": cap,
        "serving": srv,
        "wall_s": round(time.perf_counter() - t0, 1),
    }
    OUT_DIR.mkdir(exist_ok=True)
    with open(OUT_DIR / "fig11_kernels.json", "w") as f:
        json.dump(out, f, indent=2)
    print(f"# fig11 wall time: {out['wall_s']}s\n")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--budget-bytes", type=int, default=1 << 17)
    args = ap.parse_args()
    run(quick=args.quick, seed=args.seed, budget_bytes=args.budget_bytes)
