"""Shared benchmark plumbing: profile fitting, cluster configs, CSV out."""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import LLMSched, ProfileStore, make_baselines
from repro.core.scheduler import Scheduler
from repro.sim import generate_traces, get_generators, simulate
from repro.sim.simulator import configure_cluster

# benchmark-wide defaults (paper §V parameter setting)
ARRIVAL_RATE = 0.9
TARGET_LOAD = 0.95       # moderate-to-heavy (paper: 85% avg; heavier tail
                         # here keeps queueing visible at small job counts)
TRACE_JOBS = 400
SEEDS = (3, 11, 29)


_STORE_CACHE: Dict[str, ProfileStore] = {}
_CLUSTER_CACHE: Dict[str, Dict[str, int]] = {}


def store_for(mix: str) -> ProfileStore:
    if mix not in _STORE_CACHE:
        gens = get_generators()
        apps = [g.template for g in gens.values()]
        _STORE_CACHE[mix] = ProfileStore().fit(
            apps, generate_traces(mix, TRACE_JOBS, seed=7)
        )
    return _STORE_CACHE[mix]


def cluster_for(mix: str, arrival_rate: float = ARRIVAL_RATE) -> Dict[str, int]:
    key = f"{mix}:{arrival_rate}"
    if key not in _CLUSTER_CACHE:
        _CLUSTER_CACHE[key] = configure_cluster(
            mix, arrival_rate=arrival_rate, target_load=TARGET_LOAD
        )
    return _CLUSTER_CACHE[key]


def schedulers_for(mix: str, epsilon: float = 0.2, seed: int = 0,
                   train_decima: bool = True) -> Dict[str, Scheduler]:
    store = store_for(mix)
    out: Dict[str, Scheduler] = dict(make_baselines(store))
    if train_decima:
        out["decima"] = trained_decima(mix, seed=seed)
    out["llmsched"] = LLMSched(store, epsilon=epsilon, seed=seed)
    return out


_DECIMA_CACHE: Dict[str, object] = {}


def trained_decima(mix: str, episodes: int = 8, seed: int = 0):
    """REINFORCE-train the Decima baseline on the target workload mix."""
    from repro.core.baselines import Decima

    key = f"{mix}:{seed}"
    if key in _DECIMA_CACHE:
        return _DECIMA_CACHE[key]
    store = store_for(mix)
    agent = Decima(store, seed=seed, train=True)
    cfg = cluster_for(mix)
    baseline_jct: Optional[float] = None
    for ep in range(episodes):
        r = simulate(agent, mix=mix, n_jobs=40, seed=100 + ep, **cfg)
        jct = r.avg_jct
        if baseline_jct is None:
            baseline_jct = jct
        # advantage vs running baseline
        agent.finish_episode(neg_avg_jct=(baseline_jct - jct) / max(baseline_jct, 1e-9),
                             lr=5e-2)
        baseline_jct = 0.8 * baseline_jct + 0.2 * jct
    agent.train = False
    _DECIMA_CACHE[key] = agent
    return agent


def run_grid(mix: str, n_jobs: int, seeds=SEEDS, schedulers=None,
             arrival_rate: float = ARRIVAL_RATE) -> Dict[str, float]:
    scheds = schedulers or schedulers_for(mix)
    cfg = cluster_for(mix, arrival_rate)
    out: Dict[str, float] = {}
    for name, s in scheds.items():
        js: List[float] = []
        for seed in seeds:
            if hasattr(s, "rng"):
                s.rng = np.random.default_rng(seed)  # fresh exploration RNG
            r = simulate(s, mix=mix, n_jobs=n_jobs, seed=seed,
                         arrival_rate=arrival_rate, **cfg)
            js.append(r.avg_jct)
        out[name] = float(np.mean(js))
    return out


def emit_csv(name: str, header: List[str], rows: List[List]) -> None:
    print(f"# {name}")
    print(",".join(header))
    for row in rows:
        print(",".join(str(x) for x in row))
    print()
