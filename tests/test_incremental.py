"""Incremental scheduling core: cache invalidation, cached==fresh
equivalence, `_merge` edge cases, and byte-identical decision sequences
between the incremental and from-scratch schedulers on seeded runs."""

import math

import numpy as np
import pytest

from repro.core import LLMSched, ProfileStore
from repro.core.calibration import LatencyProfile
from repro.core.dag import (
    ApplicationTemplate,
    StageTemplate,
    StageType,
    TaskState,
    make_job,
)
from repro.core.entropy import uncertainty_reduction
from repro.core.scheduler import ClusterView
from repro.sim import generate_traces, generate_workload, get_generators
from repro.sim.simulator import ClusterSim
from repro.sim.workloads import reveal_after_stage


@pytest.fixture(scope="module")
def store():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    return ProfileStore().fit(apps, generate_traces("mixed", 200, seed=7))


def _view(**kw):
    return ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)], **kw)


def _complete_stage(job, stage, now=1.0):
    for t in stage.tasks:
        t.state = TaskState.DONE
        t.start_time = 0.0
        t.finish_time = now
    reveal_after_stage(job, stage, get_generators())


# ---------------------------------------------------------------------------
# Invalidation + cached == fresh
# ---------------------------------------------------------------------------
def test_cache_invalidated_on_stage_completion(store):
    wl = generate_workload("predefined", 4, seed=21)
    job = wl[0].job
    p = store.get(job.app.name)

    v0 = job.evidence_version
    before = p.est_remaining(job, 0.0, version=v0)
    # same version -> cache hit, identical scalar
    assert p.est_remaining(job, 0.0, version=v0) == before

    stage = job.ready_stages()[0]
    _complete_stage(job, stage)
    assert job.evidence_version > v0  # reveal_after_stage bumped it

    after = p.est_remaining(job, 0.0, version=job.evidence_version)
    fresh = p.est_remaining(job, 0.0)  # uncached reference path
    assert after == fresh
    assert after < before  # finished work no longer counts


def test_cached_matches_fresh_along_job_lifetime(store):
    """Drive jobs through stage completions; at every step the versioned
    (cached) estimates must equal the version-less (recomputed) ones."""
    wl = generate_workload("mixed", 10, seed=33)
    for gj in wl:
        job = gj.job
        p = store.get(job.app.name)
        if p is None:
            continue
        for _ in range(8):
            v = job.evidence_version
            assert p.est_remaining(job, 0.0, version=v) == p.est_remaining(job, 0.0)
            assert p.job_bounds(job, version=v) == p.job_bounds(job)
            ready = job.ready_stages()
            if not ready:
                break
            names = [s.name for s in ready]
            batched = p.stage_uncertainty_reductions(job, names, version=v)
            single = [p.stage_uncertainty_reduction(job, n) for n in names]
            assert batched == single
            _complete_stage(job, ready[0])


def test_batched_ur_matches_reference_algorithm(store):
    """stage_uncertainty_reductions == the paper's per-stage Eq. 6 path."""
    wl = generate_workload("mixed", 8, seed=44)
    for gj in wl:
        job = gj.job
        p = store.get(job.app.name)
        ready = job.ready_stages()
        if p is None or not p._fitted or not ready:
            continue
        names = [s.name for s in ready]
        got = p.stage_uncertainty_reductions(job, names)
        ev = p.evidence_for(job)
        unscheduled = [
            n
            for n, s in job.stages.items()
            if not s.obs_done() and not s.running() and s.dispatched_tasks == 0
        ]
        for name, g in zip(names, got):
            bonus = p._dynamic_bonus(job, name, ev)
            if name not in p.bn.nodes:
                want = float(bonus)
            else:
                want = uncertainty_reduction(
                    p.bn, p.discretizers, name, unscheduled, ev,
                    dynamic_bonus=bonus,
                )
            assert g == want, name


def test_stale_version_is_callers_contract(store):
    """Passing an unbumped version after mutation returns the stale value —
    documenting that runtimes MUST bump evidence_version on events."""
    wl = generate_workload("predefined", 2, seed=55)
    job = wl[0].job
    p = store.get(job.app.name)
    v = job.evidence_version
    stale = p.est_remaining(job, 0.0, version=v)
    for t in job.ready_stages()[0].tasks:  # mutate WITHOUT bumping
        t.state = TaskState.DONE
        t.start_time, t.finish_time = 0.0, 1.0
    assert p.est_remaining(job, 0.0, version=v) == stale
    job.bump_evidence()
    assert p.est_remaining(job, 0.0, version=job.evidence_version) != stale


def test_calibration_context_not_overcached(store):
    """Same evidence version, different target batch -> different estimate."""
    wl = generate_workload("predefined", 2, seed=4)
    job = wl[0].job
    lat = LatencyProfile(np.arange(1, 9), 0.02 * (0.8 + 0.2 * np.arange(1, 9)))
    sched = LLMSched(store, epsilon=0.0, incremental=True)
    v1 = _view(latency_profile=lat)
    v2 = ClusterView(now=0.0, free_regular=4, llm_loads=[(7, 8)],
                     latency_profile=lat)
    e1 = sched.est_rd(job, v1)
    e2 = sched.est_rd(job, v2)
    assert e2 > e1
    # and repeat queries stay cache-consistent
    assert sched.est_rd(job, v1) == e1
    assert sched.est_rd(job, v2) == e2


def test_forget_job_evicts_slots(store):
    wl = generate_workload("predefined", 2, seed=66)
    job = wl[0].job
    p = store.get(job.app.name)
    p.est_remaining(job, 0.0, version=job.evidence_version)
    p.job_bounds(job, version=job.evidence_version)
    p.stage_uncertainty_reductions(
        job, [s.name for s in job.ready_stages()], version=job.evidence_version
    )
    assert (job.job_id, True) in p._job_base
    store.forget_job(job.job_id)
    assert (job.job_id, True) not in p._job_base
    assert (job.job_id, True) not in p._job_rd
    assert (job.job_id, True) not in p._job_bounds
    assert job.job_id not in p._job_ev
    assert job.job_id not in p._job_ur


# ---------------------------------------------------------------------------
# Vectorized interval grouping
# ---------------------------------------------------------------------------
def _scalar_groups(bounds):
    """Reference implementation (pre-vectorization semantics)."""
    if not bounds:
        return []
    bounds = sorted(bounds, key=lambda t: (t[0], t[1]))
    groups = [[bounds[0][2]]]
    cur_hi = bounds[0][1]
    for lo, hi, job in bounds[1:]:
        if lo <= cur_hi:
            groups[-1].append(job)
            cur_hi = max(cur_hi, hi)
        else:
            groups.append([job])
            cur_hi = hi
    return groups


def test_vectorized_grouping_matches_scalar_reference():
    rng = np.random.default_rng(0)
    for trial in range(200):
        n = int(rng.integers(0, 40))
        bounds = []
        for i in range(n):
            lo = float(rng.choice([0.0, 1.0, rng.uniform(0, 50)]))
            width = float(rng.choice([0.0, rng.uniform(0, 20)]))
            hi = lo + width if rng.random() > 0.05 else math.inf
            bounds.append((lo, hi, i))
        got = LLMSched.non_overlapping_sets(list(bounds))
        want = _scalar_groups(list(bounds))
        assert got == want, (trial, bounds)


# ---------------------------------------------------------------------------
# _merge edge cases
# ---------------------------------------------------------------------------
def _toy_jobs(n_stages=3, num_tasks=4, llm=False):
    stype = StageType.LLM if llm else StageType.REGULAR
    tpls = [StageTemplate(f"s{i}", stype, num_tasks=num_tasks) for i in range(n_stages)]
    app = ApplicationTemplate("toy_merge", tpls, edges=[])
    job = make_job(app, 0.0)
    for s in job.stages.values():
        s.revealed = True
    return job


def _sched(eps, ratio=0.3, seed=0):
    return LLMSched(ProfileStore(), epsilon=eps, sampling_ratio=ratio, seed=seed)


def test_merge_empty_su_is_pure_srtf_order():
    job = _toy_jobs(3)
    s_t = job.ready_stages()
    dec = _sched(eps=1.0)._merge(list(s_t), [])
    want = [t for s in s_t for t in s.pending_tasks()]
    assert dec.regular == want
    assert dec.llm == []


def test_merge_exploration_pick_coinciding_with_srtf_head_runs_fully():
    job = _toy_jobs(2, num_tasks=5)
    s_t = job.ready_stages()
    head = s_t[0]
    # epsilon=1 -> always explore; s_u head == SRTF head -> NO sampling split
    dec = _sched(eps=1.0, ratio=0.2)._merge(list(s_t), [head, s_t[1]])
    head_tasks = head.pending_tasks()
    assert dec.regular[: len(head_tasks)] == head_tasks  # contiguous, no deferral


def test_merge_deferred_tasks_come_last_in_order():
    job_a = _toy_jobs(1, num_tasks=6)
    job_b = _toy_jobs(1, num_tasks=6)
    (sa,) = job_a.ready_stages()
    (sb,) = job_b.ready_stages()
    # SRTF prefers A; exploration always picks B with ratio 1/3 -> 2 tasks
    dec = _sched(eps=1.0, ratio=1 / 3)._merge([sa], [sb])
    b_tasks = sb.pending_tasks()
    a_tasks = sa.pending_tasks()
    k = math.ceil(len(b_tasks) / 3)
    assert dec.regular[:k] == b_tasks[:k]          # sampled exploration slice
    assert dec.regular[k : k + len(a_tasks)] == a_tasks  # then the SRTF stage
    assert dec.regular[k + len(a_tasks) :] == b_tasks[k:]  # deferred last, in order


def test_merge_no_duplicates_under_any_epsilon(store):
    for eps in (0.0, 0.25, 0.75, 1.0):
        wl = generate_workload("mixed", 6, seed=13)
        jobs = [gj.job for gj in wl]
        dec = LLMSched(store, epsilon=eps, seed=3).schedule(jobs, _view())
        tasks = dec.regular + dec.llm
        assert len({id(t) for t in tasks}) == len(tasks)


# ---------------------------------------------------------------------------
# Decision-sequence equivalence on a seeded simulator run
# ---------------------------------------------------------------------------
def _record_run(incremental, fail=0.0, strag=0.0):
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 120, seed=7))
    wl = generate_workload("mixed", 20, seed=11)
    pos = {gj.job.job_id: i for i, gj in enumerate(wl)}
    sched = LLMSched(store, epsilon=0.3, seed=5, incremental=incremental)
    log = []
    orig = sched.schedule

    def recording(jobs, view):
        dec = orig(jobs, view)
        log.append(
            tuple(
                (pos[t.job_id], t.stage_name, t.index, t.is_llm)
                for t in dec.regular + dec.llm
            )
        )
        return dec

    sched.schedule = recording
    res = ClusterSim(
        sched, n_regular=3, n_llm=2, max_batch=4, seed=0,
        failure_rate=fail, straggler_factor=strag,
    ).run(wl)
    return log, res


def test_incremental_decisions_byte_identical_to_fresh():
    log_inc, res_inc = _record_run(True)
    log_ref, res_ref = _record_run(False)
    assert log_inc == log_ref
    assert res_inc.jcts == res_ref.jcts
    assert res_inc.makespan == res_ref.makespan


def test_incremental_decisions_identical_under_fault_injection():
    log_inc, _ = _record_run(True, fail=0.01, strag=2.0)
    log_ref, _ = _record_run(False, fail=0.01, strag=2.0)
    assert log_inc == log_ref
