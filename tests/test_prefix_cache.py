"""Shared-prefix KV cache: allocator/index properties + differentials.

Covers the prefix-sharing tentpole end to end:

- **property test** (hypothesis): random interleaved
  alloc/fork/free/index/adopt/evict/defrag sequences never leak, never
  double free, and every page's allocator refcount always equals the
  number of live model owners (``check_no_leaks`` extended to
  refcounted + dormant pages);
- **differential tests**: greedy decode through the prefix-cache
  engine is token-for-token identical to the cacheless engine on the
  same seeded trace — including under forced mid-decode eviction and
  under forced migration of a request holding shared pages;
- **golden-trajectory regression**: with prefix info absent *or*
  zeroed, LLMSched decisions on the seeded fig7-style trace are
  byte-identical to the pre-prefix-cache (PR 4) outputs;
- radix-index unit behaviour (longest-prefix match, first-writer-wins
  insert, LRU leaf eviction, defrag remap) and the engine's LRU
  reclaim-before-preempt policy.
"""

import hashlib

import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core import LLMSched, ProfileStore
from repro.models import init_params
from repro.serving import (
    PageAllocator,
    PagedLLMEngine,
    RadixPrefixIndex,
    Request,
    migrate_request,
)
from repro.sim import generate_traces, generate_workload, get_generators
from repro.sim.simulator import ClusterSim


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("stablelm_1_6b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.key(0))[0]


# ---------------------------------------------------------------------------
# radix index unit behaviour
# ---------------------------------------------------------------------------
def test_radix_match_insert_first_writer_wins():
    idx = RadixPrefixIndex(page_size=4)
    toks = list(range(1, 13))                       # 3 full blocks
    assert idx.match(toks) == []
    assert idx.insert(toks, [5, 6, 7]) == [5, 6, 7]
    assert idx.cached_pages == 3 and idx.cached_tokens == 12
    # longest-prefix semantics: a diverging third block matches 2 pages
    other = toks[:8] + [99, 98, 97, 96]
    assert idx.match(other) == [5, 6]
    # same blocks re-inserted under different pages: first writer wins
    assert idx.insert(toks, [8, 9, 10]) == []
    assert idx.match(toks) == [5, 6, 7]
    # partial blocks never participate
    assert idx.match(toks[:7]) == [5]
    assert idx.insert([1, 2, 3], [11]) == []        # < one full block


def test_radix_lru_leaf_eviction_and_remap():
    idx = RadixPrefixIndex(page_size=2)
    idx.insert([1, 2, 3, 4], [5, 6])                # chain 5 -> 6
    idx.insert([9, 9], [7])                         # separate leaf 7
    idx.match([1, 2, 3, 4])                         # chain is now MRU
    # only leaves are evictable, LRU first: 7 before 6, never 5 before 6
    assert idx.evict(1, lambda p: True) == [7]
    assert idx.evict(2, lambda p: True) == [6, 5]
    assert idx.cached_pages == 0
    # evictability filter respects live pages
    idx.insert([1, 2, 3, 4], [5, 6])
    assert idx.evict(2, lambda p: p != 6) == []     # leaf 6 pinned
    idx.remap({5: 1, 6: 2})
    assert idx.match([1, 2, 3, 4]) == [1, 2]


# ---------------------------------------------------------------------------
# allocator refcount / CoW-fork unit behaviour
# ---------------------------------------------------------------------------
def test_allocator_fork_refcounts_and_double_free():
    a = PageAllocator(num_pages=8, page_size=4)
    p = a.alloc(2, owner=1)
    assert [a.refcount(x) for x in p] == [1, 1]
    q = a.fork(p, owner=2)
    assert q == p and [a.refcount(x) for x in p] == [2, 2]
    a.free(p)                                       # owner 1 drops out
    assert [a.refcount(x) for x in p] == [1, 1]
    with pytest.raises(AssertionError):
        a.check_no_leaks()                          # owner 2 still holds
    a.free(q)
    a.check_no_leaks()
    with pytest.raises(ValueError):
        a.free(q)                                   # double free detected
    with pytest.raises(ValueError):
        a.fork([p[0]])                              # forking a dead page
    # duplicate ids within ONE call must also raise before mutating
    r = a.alloc(1, owner=3)
    with pytest.raises(ValueError):
        a.free([r[0], r[0]])
    assert a.refcount(r[0]) == 1                    # state untouched
    a.free(r)
    a.check_no_leaks()


def test_allocator_dormant_lifecycle():
    a = PageAllocator(num_pages=6, page_size=4)
    p = a.alloc(3, owner=1)
    a.mark_indexed(p[:2])
    a.free(p)
    # 2 dormant (indexed) + 1 freed outright
    assert a.dormant_pages == 2 and a.free_pages == 3
    a.check_no_leaks()                              # dormant is not a leak
    with pytest.raises(AssertionError):
        a.check_no_leaks(allow_indexed=False)
    # adopt revives a dormant page at refcount 1
    got = a.adopt([p[0]], owner=7)
    assert got == [p[0]] and a.refcount(p[0]) == 1
    with pytest.raises(ValueError):
        a.adopt([p[2]])                             # never indexed
    a.free(got)
    a.unmark_indexed(p[:2])                         # index eviction
    assert a.dormant_pages == 0 and a.free_pages == 5
    a.check_no_leaks(allow_indexed=False)


# ---------------------------------------------------------------------------
# property test: interleaved alloc/fork/free/index/adopt/evict/defrag
# ---------------------------------------------------------------------------
def _interp(ops, num_pages, page_size=4):
    """Drive allocator+index from an op stream, mirroring refcounts in a
    model; verifies after every op that allocator refcounts equal the
    model's live-owner counts and that free/live/dormant partition the
    pool."""
    a = PageAllocator(num_pages, page_size)
    idx = RadixPrefixIndex(page_size)
    model = {}                     # page -> expected refcount
    seqs = {}                      # seq id -> page list
    registry = []                  # (tokens, pages) inserted into the index
    next_seq, next_block = 0, 0

    def check():
        for p in range(1, num_pages):
            assert a.refcount(p) == model.get(p, 0), (
                f"page {p}: allocator ref {a.refcount(p)} != "
                f"model {model.get(p, 0)}"
            )
        assert a.used_pages == sum(1 for v in model.values() if v > 0)
        assert a.free_pages + a.used_pages + a.dormant_pages == num_pages - 1

    for x in ops:
        op, arg = x % 6, x // 6
        if op == 0:                                   # alloc a new sequence
            n = 1 + arg % 3
            pages = a.alloc(n, owner=next_seq)
            if pages is not None:
                assert all(model.get(p, 0) == 0 for p in pages)
                for p in pages:
                    model[p] = 1
                seqs[next_seq] = pages
                next_seq += 1
        elif op == 1 and seqs:                        # CoW-fork a sequence
            sid = sorted(seqs)[arg % len(seqs)]
            pages = a.fork(seqs[sid], owner=next_seq)
            for p in pages:
                model[p] += 1
            seqs[next_seq] = list(pages)
            next_seq += 1
        elif op == 2 and seqs:                        # free a sequence
            sid = sorted(seqs)[arg % len(seqs)]
            pages = seqs.pop(sid)
            a.free(pages)
            for p in pages:
                model[p] -= 1
                if model[p] == 0:
                    del model[p]  # freed ids get recycled by defrag
        elif op == 3 and seqs:                        # index + release
            sid = sorted(seqs)[arg % len(seqs)]
            pages = seqs[sid]
            if not any(a.is_indexed(p) for p in pages):
                tokens = []
                for _ in pages:
                    tokens.extend([next_block] * page_size)
                    next_block += 1
                fresh = idx.insert(tokens, pages)
                assert fresh == pages                 # all blocks were new
                a.mark_indexed(fresh)
                registry.append((tokens, list(pages)))
                a.free(seqs.pop(sid))
                for p in pages:
                    model[p] -= 1
                    if model[p] == 0:
                        del model[p]  # page may live on as dormant
        elif op == 4 and registry:                    # adopt a cached prefix
            tokens, pages = registry[arg % len(registry)]
            got = idx.match(tokens)
            assert got == pages[: len(got)]           # eviction keeps prefixes
            if got:
                a.adopt(got, owner=next_seq)
                for p in got:
                    model[p] = model.get(p, 0) + 1
                seqs[next_seq] = list(got)
                next_seq += 1
        elif op == 5:                                 # evict LRU + defrag
            evicted = idx.evict(1 + arg % 3, lambda p: a.refcount(p) == 0)
            assert all(model.get(p, 0) == 0 for p in evicted)
            a.unmark_indexed(evicted)
            mapping = a.defrag()
            if mapping:
                idx.remap(mapping)
                model = {mapping.get(p, p): r for p, r in model.items()}
                for s, pl in seqs.items():
                    seqs[s] = [mapping.get(p, p) for p in pl]
                registry = [
                    (t, [mapping.get(p, p) for p in pl])
                    for t, pl in registry
                ]
        check()
    return a, idx, model, seqs


def _interp_and_teardown(ops, num_pages):
    a, idx, model, seqs = _interp(ops, num_pages)
    # drain: free every live sequence, then drop the index entirely
    for sid in sorted(seqs):
        a.free(seqs[sid])
    a.check_no_leaks()                                # dormant pages allowed
    a.unmark_indexed(idx.drop())
    a.check_no_leaks(allow_indexed=False)             # now fully clean


@given(
    ops=st.lists(st.integers(0, 2 ** 16), min_size=1, max_size=120),
    num_pages=st.integers(4, 24),
)
@settings(max_examples=30, deadline=None)
def test_refcount_property_fast(ops, num_pages):
    """Tier-1 slice of the property sweep: no leaks, no double frees,
    refcounts always equal the number of live owners."""
    _interp_and_teardown(ops, num_pages)


@pytest.mark.slow
@given(
    ops=st.lists(st.integers(0, 2 ** 16), min_size=1, max_size=400),
    num_pages=st.integers(4, 48),
)
@settings(max_examples=300, deadline=None)
def test_refcount_property_sweep(ops, num_pages):
    """Nightly sweep: longer op streams, bigger pools, more examples."""
    _interp_and_teardown(ops, num_pages)


# ---------------------------------------------------------------------------
# differential: prefix-cache engine == cacheless engine, token for token
# ---------------------------------------------------------------------------
def _run_trace(cfg, params, prompts, *, prefix, n_new=8, chunk=8, ps=8,
               pages=None, max_seqs=8, stagger=2, max_steps=600):
    """Drive one engine over a staggered arrival trace; return outputs."""
    eng = PagedLLMEngine(cfg, max_seqs=max_seqs, max_len=64, page_size=ps,
                         params=params, prefill_chunk=chunk,
                         num_pages=pages, prefix_cache=prefix)
    out = {}
    reqs = [
        Request(rid=i, prompt=list(p), max_new_tokens=n_new,
                on_finish=lambda r: out.__setitem__(r.rid, list(r.out_tokens)))
        for i, p in enumerate(prompts)
    ]
    pending = list(reqs)
    steps = 0
    while (pending or eng.batch_size or eng.waiting) and steps < max_steps:
        if pending and steps % stagger == 0 and eng.can_admit() \
                and eng.admit(pending[0]):
            pending.pop(0)
        eng.step()
        steps += 1
    assert not pending and not eng.batch_size and not eng.waiting, (
        f"trace did not drain in {max_steps} steps"
    )
    eng.allocator.check_no_leaks()
    return out, eng, reqs


def test_differential_shared_prompt_trace(cfg, params):
    """Seeded shared-prefix trace (suffix variants + exact page-aligned
    duplicates): greedy outputs must match the cacheless engine exactly,
    while the cache engine really hits (and CoWs the aligned case)."""
    shared = [3 + (7 * i) % 29 for i in range(32)]   # 4 pages at ps=8
    prompts = (
        [shared + [50 + i] for i in range(4)]        # shared + 1-token suffix
        + [shared, shared]                           # aligned duplicates
        + [[70, 71, 72]]                             # unrelated short prompt
    )
    base, _, base_reqs = _run_trace(cfg, params, prompts, prefix=False)
    got, eng, reqs = _run_trace(cfg, params, prompts, prefix=True)
    assert got == base
    assert eng.prefix_index.hits > 0
    assert eng.prefill_skipped_tokens > 0
    assert eng.cow_copies > 0                        # aligned dup re-runs tail
    # exact accounting (no evictions here): prefilled + skipped covers
    # every prompt token, and the cacheless run prefilled them all
    total = sum(len(p) for p in prompts)
    assert sum(r.prefill_tokens for r in base_reqs) == total
    assert sum(r.prefill_tokens for r in reqs) \
        + eng.prefill_skipped_tokens == total
    assert sum(r.prefill_tokens for r in reqs) < total


def test_differential_under_forced_eviction(cfg, params):
    """Pool far too small for the offered load: the cache engine must
    evict (preemptions > 0, possibly dropping dormant prefix pages) and
    still reproduce the cacheless outputs token for token."""
    shared = [3 + (5 * i) % 23 for i in range(16)]
    prompts = [shared + [40 + i] for i in range(6)]
    base, e0, _ = _run_trace(cfg, params, prompts, prefix=False,
                             n_new=14, pages=12, max_seqs=4)
    got, e1, _ = _run_trace(cfg, params, prompts, prefix=True,
                            n_new=14, pages=12, max_seqs=4)
    assert got == base
    assert e1.preemptions > 0                        # eviction really forced
    assert e1.prefix_index.hits > 0


def test_prefix_pages_reclaimed_before_preemption(cfg, params):
    """Dormant prefix pages are strictly cheaper than live requests:
    filling the pool with dead cached prefixes must not block a new
    admission — the index LRU-evicts instead of refusing."""
    eng = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                         num_pages=9, params=params, prefill_chunk=8,
                         prefix_cache=True)
    done = []
    # two requests with disjoint 2-page prompts; run each to completion
    # so their prompt pages go dormant in the index
    for i, base in enumerate((10, 40)):
        assert eng.admit(Request(rid=i, prompt=[base + k for k in range(16)],
                                 max_new_tokens=2,
                                 on_finish=lambda r: done.append(r.rid)))
        while eng.batch_size:
            eng.step()
    assert sorted(done) == [0, 1]
    assert eng.allocator.dormant_pages == 4          # 2 prompts x 2 pages
    assert eng.allocator.free_pages == 4             # 8 usable - 4 dormant
    # a 41-token prompt needs 6 pages: only reclaiming the dormant
    # prefixes can satisfy it, and nobody may be preempted for it
    big = [70 + k for k in range(41)]
    assert eng.admit(Request(rid=9, prompt=big, max_new_tokens=2,
                             on_finish=lambda r: done.append(r.rid)))
    assert eng.prefix_index.evictions > 0            # LRU reclaim fired
    while eng.batch_size or eng.waiting:
        eng.step()
    assert 9 in done
    assert eng.preemptions == 0                      # nobody was preempted
    eng.allocator.check_no_leaks()


def test_refused_admissions_do_not_inflate_hit_stats(cfg, params):
    """A matching request that cannot be admitted (fresh pages
    unavailable, its own adopted prefix protected from reclaim) must
    not count as a cache hit, however often the runtime retries."""
    eng = PagedLLMEngine(cfg, max_seqs=3, max_len=64, page_size=8,
                         num_pages=9, params=params, prefill_chunk=8,
                         prefix_cache=True)
    done = []
    first = [10 + k for k in range(16)]              # 2 full pages
    assert eng.admit(Request(rid=0, prompt=first, max_new_tokens=2,
                             on_finish=lambda r: done.append(r.rid)))
    while eng.batch_size:
        eng.step()
    assert done == [0] and eng.allocator.dormant_pages == 2
    # a long-running request eats most of the free list
    assert eng.admit(Request(rid=1, prompt=[60 + k for k in range(33)],
                             max_new_tokens=4,
                             on_finish=lambda r: done.append(r.rid)))
    # rid 2 shares rid 0's prefix but needs 2 fresh pages; only 1 free
    blocked = Request(rid=2, prompt=first + [90 + k for k in range(8)],
                      max_new_tokens=2, on_finish=lambda r: done.append(r.rid))
    for _ in range(3):
        assert not eng.admit(blocked)                # retried and refused
    assert eng.prefix_index.hits == 0                # no phantom hits
    assert eng.prefill_skipped_tokens == 0
    while eng.batch_size:                            # drain rid 1
        eng.step()
    assert eng.admit(blocked)
    assert eng.prefix_index.hits == 1                # counted exactly once
    assert eng.prefill_skipped_tokens == 16
    while eng.batch_size:
        eng.step()
    assert sorted(done) == [0, 1, 2]
    eng.allocator.check_no_leaks()


def test_differential_under_forced_migration_with_shared_pages(cfg, params):
    """Two requests sharing 2 prefix pages; migrate the younger one
    (refcount-2 pages in its block table) mid-decode to a peer replica:
    the ticket carries the shared-page refcounts, both engines stay
    leak-free, and the decode continues token-for-token."""
    shared = [3 + i for i in range(16)]              # 2 pages at ps=8
    p0, p1 = shared + [60], shared + [61]

    # cacheless single-engine reference
    base, _, _ = _run_trace(cfg, params, [p0, p1], prefix=False, n_new=10,
                            stagger=6)

    a = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                       params=params, prefill_chunk=8, prefix_cache=True)
    b = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                       params=params, prefill_chunk=8, prefix_cache=True)
    out = {}

    def collect(r):
        out[r.rid] = list(r.out_tokens)

    assert a.admit(Request(rid=0, prompt=p0, max_new_tokens=10,
                           on_finish=collect))
    for _ in range(6):                               # finish prefill, decode
        a.step()
    assert a.admit(Request(rid=1, prompt=p1, max_new_tokens=10,
                           on_finish=collect))       # hits the shared prefix
    for _ in range(4):
        a.step()
    row = a.youngest_active_row()
    assert row is not None and a.active[row].rid == 1
    shared_refs = [a.allocator.refcount(p) for p in a.seq_pages[row]]
    assert max(shared_refs) > 1                      # genuinely shared pages

    # export/import directly so the ticket's refcounts are observable
    ticket = a.export_request(row)
    assert ticket.page_refcounts is not None
    assert max(ticket.page_refcounts) > 1            # carried shared counts
    assert b.import_request(ticket)
    while a.batch_size or b.batch_size:
        if a.batch_size:
            a.step()
        if b.batch_size:
            b.step()
    assert out == base                               # token-for-token
    a.allocator.check_no_leaks()
    b.allocator.check_no_leaks()
    # the migrated prompt's prefix is now reusable on the destination too
    assert b.prefix_index.cached_pages >= 2


def test_migrate_request_roundtrip_with_shared_pages(cfg, params):
    """The policy-level wrapper moves a shared-prefix holder losslessly
    (the source keeps the shared pages alive for its co-owner)."""
    shared = [5 + i for i in range(16)]
    a = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                       params=params, prefill_chunk=8, prefix_cache=True)
    b = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                       params=params, prefill_chunk=8, prefix_cache=True)
    done = []
    a.admit(Request(rid=0, prompt=shared + [90], max_new_tokens=12,
                    on_finish=lambda r: done.append(r.rid)))
    for _ in range(6):
        a.step()
    a.admit(Request(rid=1, prompt=shared + [91], max_new_tokens=12,
                    on_finish=lambda r: done.append(r.rid)))
    for _ in range(4):
        a.step()
    row = a.youngest_active_row()
    assert migrate_request(a, b, row)
    while a.batch_size or a.waiting or b.batch_size:
        for e in (a, b):
            if e.batch_size or e.waiting:
                e.step()
    assert sorted(done) == [0, 1]
    a.allocator.check_no_leaks()
    b.allocator.check_no_leaks()


# ---------------------------------------------------------------------------
# golden-trajectory regression: placement degeneracy vs PR 4
# ---------------------------------------------------------------------------
# SHA-256 of the (job-index-normalized) LLMSched decision stream on the
# seeded fig7-style trace, captured at the PR 4 commit (before any
# prefix-cache code existed).  The cache-aware scheduler must reproduce
# these exactly whenever prefix info is absent — or present but zeroed.
_GOLD = {
    "no_kv": ("f0a1535da4df96f382ac82bd79543816d4647d2041c61866eec03a6ea89c2ee2",
              185, 34.531148),
    "kv": ("76ff31e613e53efc6b261452a5a0936094c42b7280ea999d343e3a670e88322a",
           196, 39.830019),
}


def _trajectory(kv, zero_prefix):
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 120, seed=7))
    sched = LLMSched(store, epsilon=0.2, seed=0)
    wl = generate_workload("mixed", 20, arrival_rate=1.2, seed=11)
    jid = {gj.job.job_id: i for i, gj in enumerate(wl)}
    log = []
    orig = sched.schedule

    def rec(jobs, view):
        if zero_prefix:
            view.llm_prefix_hit_tokens = [0] * len(view.llm_loads)
        dec = orig(jobs, view)
        log.append((
            tuple((jid[t.job_id], t.stage_name, t.index) for t in dec.regular),
            tuple((jid[t.job_id], t.stage_name, t.index) for t in dec.llm),
            tuple(sorted(
                (jid[j], s, i, e) for (j, s, i), e in dec.placement.items()
            )),
        ))
        return dec

    sched.schedule = rec
    sim = ClusterSim(sched, n_regular=4, n_llm=2, max_batch=8,
                     kv_budget_tokens=kv, seed=0)
    res = sim.run(wl)
    return (hashlib.sha256(repr(log).encode()).hexdigest(), len(log),
            round(res.avg_jct, 6))


@pytest.mark.parametrize("tag,kv", [("no_kv", None), ("kv", [3000, 8000])])
def test_placement_degenerates_to_pr4_golden_trajectory(tag, kv):
    """Absent and zeroed prefix info must both reproduce the PR 4
    decision stream byte-for-byte on the seeded fig7 trace."""
    absent = _trajectory(kv, zero_prefix=False)
    zeroed = _trajectory(kv, zero_prefix=True)
    assert absent == zeroed                 # exact degeneracy, any platform
    assert absent == _GOLD[tag], (
        f"LLMSched {tag} trajectory drifted from the PR 4 golden capture: "
        f"{absent} != {_GOLD[tag]}"
    )


def test_cache_aware_placement_prefers_resident_prefix():
    """With nonzero prefix residency the score must actually steer:
    equal load and KV, one replica holding the shared prompt -> that
    replica wins the placement."""
    from repro.core.scheduler import ClusterView

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 100, seed=7))
    wl = generate_workload("mixed", 6, seed=9)
    jobs = [gj.job for gj in wl]
    sched = LLMSched(store, epsilon=0.0, seed=0)
    view = ClusterView(
        now=0.0, free_regular=4,
        llm_loads=[(0, 8), (0, 8)],
        llm_free_tokens=[4096, 4096],
        llm_prefix_hit_tokens=[0, 512],
    )
    dec = sched.schedule(jobs, view)
    assert dec.llm
    first = dec.replica_for(dec.llm[0])
    assert first == 1                       # cache affinity broke the tie
