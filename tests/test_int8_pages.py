"""Int8 KV pages at the engine level: determinism + lifecycle coverage.

Quantize-once semantics make int8 page bits a pure function of the
tokens they hold, so every *within-int8* differential that held for
fp32 pages must keep holding:

- prefix-cache on/off token equality under ``kv_dtype="int8"``;
- live migration token equality under int8, with the ticket carrying
  the per-page scale pools;
- kv_dtype-mismatched tickets rejected loudly (int8 payload bytes mean
  nothing to an fp32 pool and vice versa);
- sanitized int8 runs exercise the scale-pool shadow checks end to end;
- byte accounting: ``pages_for_byte_budget`` buys strictly more int8
  pages per byte, ``page_bytes`` counts the scale pools, and the
  ``ServeConfig`` surface validates the new knobs.

``kv_dtype="fp32"`` remains the default everywhere, so the existing
golden trajectories and paged-vs-slot equality suites pin that path.
"""

import jax
import numpy as np
import pytest

from repro.analysis.kvsan import KVSanError, KVSanitizer
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import PagedLLMEngine, Request, migrate_request
from repro.serving.config import ServeConfig, build_engines


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("stablelm_1_6b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.key(0))[0]


def _engine(cfg, params, **kw):
    kw.setdefault("max_seqs", 4)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("num_pages", 49)
    return PagedLLMEngine(cfg, params=params, kv_dtype="int8", **kw)


def _drain(eng, reqs):
    for r in reqs:
        assert eng.admit(r)
    toks = {}
    for _ in range(400):
        for r in eng.step():
            toks[r.rid] = list(r.out_tokens)
        if not eng.batch_size and not eng.waiting:
            break
    assert not eng.batch_size and not eng.waiting
    return toks


def _reqs(prompts, max_new=10):
    return [
        Request(rid=i, prompt=list(p), max_new_tokens=max_new)
        for i, p in enumerate(prompts)
    ]


PROMPTS = [[1, 2, 3, 4], [1, 2, 3, 9], [5, 6], [7, 8, 9, 10, 11]]
SHARED = [3, 1, 4, 1, 5, 9, 2, 6] * 2          # two full 8-token pages


# ---------------------------------------------------------------------------
# differential determinism under int8
# ---------------------------------------------------------------------------
def test_int8_decode_is_deterministic(cfg, params):
    a = _drain(_engine(cfg, params), _reqs(PROMPTS))
    b = _drain(_engine(cfg, params), _reqs(PROMPTS))
    assert a == b


def test_int8_prefix_cache_token_equality(cfg, params):
    prompts = [SHARED + [20 + i] for i in range(4)]
    plain = {}
    plain.update(_drain(_engine(cfg, params), _reqs(prompts[:1])))
    plain.update(_drain(_engine(cfg, params), _reqs(prompts)[1:]))
    eng = _engine(cfg, params, prefix_cache=True)
    # first request populates the radix index, the rest adopt its pages
    cached = dict(_drain(eng, _reqs(prompts[:1])))
    cached.update(_drain(eng, _reqs(prompts)[1:]))
    assert cached == plain
    assert eng.prefill_skipped_tokens > 0       # the cache actually fired
    eng.allocator.check_no_leaks()


def test_int8_migration_token_equality(cfg, params):
    ref_out = _drain(_engine(cfg, params), _reqs([PROMPTS[0]], max_new=12))
    a = _engine(cfg, params)
    b = _engine(cfg, params)
    out = {}
    a.admit(Request(rid=0, prompt=list(PROMPTS[0]), max_new_tokens=12,
                    on_finish=lambda r: out.update({r.rid: list(r.out_tokens)})))
    for _ in range(4):
        a.step()
    assert migrate_request(a, b, a.youngest_active_row())
    for _ in range(40):
        b.step()
        if not b.batch_size:
            break
    assert out == ref_out
    a.allocator.check_no_leaks()
    b.allocator.check_no_leaks()


def test_int8_ticket_carries_scales_and_dtype(cfg, params):
    a = _engine(cfg, params)
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8))
    for _ in range(2):
        a.step()
    ticket = a.export_request(a.youngest_active_row())
    assert ticket.kv_dtype == "int8"
    for layer_kv in ticket.kv.values():
        assert {"k", "v", "k_s", "v_s"} <= set(layer_kv)
        assert layer_kv["k"].dtype == np.int8
        assert layer_kv["k_s"].dtype == np.float32
    assert a.import_request(ticket)             # roll back, no leak
    while a.batch_size or a.waiting:
        a.step()
    a.allocator.check_no_leaks()


def test_kv_dtype_mismatch_import_rejected(cfg, params):
    a = _engine(cfg, params)
    c = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                       num_pages=49, params=params, kv_dtype="fp32")
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=6))
    a.step()
    ticket = a.export_request(a.youngest_active_row())
    with pytest.raises(ValueError, match="kv_dtype mismatch"):
        c.import_request(ticket)
    assert a.import_request(ticket)
    while a.batch_size or a.waiting:
        a.step()
    a.allocator.check_no_leaks()


def test_int8_sanitized_run_clean(cfg, params):
    """A full int8 serve under the sanitizer: every write is marked
    quantized, exports validate scale coverage, scales stay finite."""
    eng = _engine(cfg, params, sanitize=True, prefix_cache=True)
    prompts = [SHARED + [30 + i] for i in range(3)]
    toks = _drain(eng, _reqs(prompts))
    assert len(toks) == 3
    eng.allocator.check_no_leaks()


def test_sanitizer_scale_export_check():
    san = KVSanitizer(num_pages=8, page_size=4)
    san.on_alloc([1, 2], 0)
    san.note_table(0, [1, 2])
    san.note_write(0, 1, quantized=True)
    san.validate_scale_export([1])
    with pytest.raises(KVSanError, match="scale-pool mismatch"):
        san.validate_scale_export([1, 2])      # page 2 never scale-written
    # CoW copies inherit the source page's scale coverage
    san.on_alloc([3], 0)
    san.note_scale_copy(1, 3)
    san.validate_scale_export([3])


# ---------------------------------------------------------------------------
# byte accounting + config surface
# ---------------------------------------------------------------------------
def test_pages_for_byte_budget_ratio(cfg):
    budget = 1 << 18
    fp32 = PagedLLMEngine.pages_for_byte_budget(cfg, 8, budget, "fp32")
    int8 = PagedLLMEngine.pages_for_byte_budget(cfg, 8, budget, "int8")
    assert int8 > fp32 > 0


def test_page_bytes_counts_scale_pools(cfg, params):
    fp32 = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8,
                          num_pages=17, params=params, kv_dtype="fp32")
    int8 = _engine(cfg, params, max_seqs=2, num_pages=17)
    # int8 payload is 1B/elem + 4B/slot/head of scales; the engine's own
    # accounting must match a hand count over the pool leaves
    for eng in (fp32, int8):
        hand = sum(
            arr.nbytes // arr.shape[1]
            for pool in eng.pools["blocks"].values()
            for arr in pool.values()
        )
        assert eng.page_bytes == hand
    assert int8.page_bytes < fp32.page_bytes
    # budget sizing never exceeds the budget it was given
    budget = 1 << 18
    for dt, eng in (("fp32", fp32), ("int8", int8)):
        pages = PagedLLMEngine.pages_for_byte_budget(cfg, 8, budget, dt)
        assert pages * eng.page_bytes <= budget


def test_serve_config_kv_dtype_validation():
    with pytest.raises(ValueError, match="engine='paged'"):
        ServeConfig(engine="slot", kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ServeConfig(engine="paged", kv_dtype="fp16")
    with pytest.raises(ValueError, match="mutually exclusive"):
        ServeConfig(engine="paged", kv_pages=(8,), kv_budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="engine='paged'"):
        ServeConfig(engine="slot", kv_budget_bytes=1 << 20)
    cfg = ServeConfig(engine="paged", kv_dtype="int8",
                      kv_budget_bytes=1 << 20)
    assert cfg.kv_dtype == "int8"


def test_build_engines_equal_byte_budget(cfg):
    budget = 1 << 18
    fleets = {}
    for dt in ("fp32", "int8"):
        sc = ServeConfig(engine="paged", replicas=1, kv_dtype=dt,
                         kv_budget_bytes=budget, seed=0)
        fleets[dt] = build_engines(cfg, sc)[0]
        assert fleets[dt].kv_dtype == dt
        assert fleets[dt].num_pages * fleets[dt].page_bytes <= budget
    assert fleets["int8"].num_pages > fleets["fp32"].num_pages


def test_env_var_default_kv_dtype(cfg, params, monkeypatch):
    monkeypatch.setenv("REPRO_KV_DTYPE", "int8")
    eng = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8,
                         num_pages=17, params=params)
    assert eng.kv_dtype == "int8"
    assert "k_s" in eng.pools["blocks"]["0"]
    monkeypatch.setenv("REPRO_KV_DTYPE", "bogus")
    with pytest.raises(ValueError, match="kv_dtype"):
        PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8,
                       num_pages=17, params=params)
