"""Multi-device SPMD correctness (8 forced host devices, subprocess).

These run the REAL sharded paths — EP MoE, weight-stationary decode MLP,
sharded decode — on an 8-device host mesh and check numerics against the
single-device oracle.  Subprocesses are used because the device count is
locked at jax init.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert r.returncode == 0, f"stdout:{r.stdout}\nstderr:{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_moe_ep_matches_dense_on_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_smoke_config
        from repro.models import moe as M
        from repro.models.layers import Maker
        from repro.distributed.sharding import use_mesh

        cfg = get_smoke_config("kimi_k2_1t_a32b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        mk = Maker(jax.random.key(0), jnp.float32)
        M.init_moe(mk, cfg.with_(dtype="float32"))
        x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model)) * 0.5
        ref = M.moe_dense(mk.params, cfg, x)
        cfg2 = cfg.with_(moe=dataclasses.replace(cfg.moe, impl="ep",
                                                 capacity_factor=2.0))
        with use_mesh(mesh):
            out = jax.jit(lambda p, x: M.moe_ep(p, cfg2, x, mesh=mesh))(mk.params, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        print("EP-OK", err)
    """)
    assert "EP-OK" in out


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_params, prefill, decode_step
        from repro.models.zoo import param_shapes
        from repro.distributed import sharding as shd

        cfg = get_smoke_config("internlm2_20b").with_(dtype="float32")
        params, specs = init_params(cfg, jax.random.key(0))
        toks = jnp.asarray(np.arange(2 * 8).reshape(2, 8) % cfg.vocab, jnp.int32)

        # single device reference
        last_ref, cache = prefill(params, cfg, toks, max_len=32)
        lg_ref, _ = decode_step(params, cfg, cache, jnp.argmax(last_ref, -1))

        # sharded over (data=4, model=2)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with shd.use_mesh(mesh):
            p_sh = shd.tree_shardings(specs, params, mesh)
            params_s = jax.device_put(params, p_sh)
            last_s, cache_s = jax.jit(
                lambda p, t: prefill(p, cfg, t, max_len=32)
            )(params_s, toks)
            lg_s, _ = jax.jit(
                lambda p, c, t: decode_step(p, cfg, c, t)
            )(params_s, cache_s, jnp.argmax(last_s, -1))
        err = float(jnp.max(jnp.abs(lg_s - lg_ref)))
        assert err < 2e-3, err
        print("SHARD-OK", err)
    """)
    assert "SHARD-OK" in out


@pytest.mark.slow
def test_weight_stationary_decode_mlp_matches():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.models.layers import Maker, init_mlp, mlp, mlp_ws_decode
        from repro.distributed.sharding import use_mesh

        cfg = get_smoke_config("llama3_405b").with_(dtype="float32")
        mk = Maker(jax.random.key(0), jnp.float32)
        init_mlp(mk, cfg.d_model, 192)
        x = jax.random.normal(jax.random.key(1), (4, 1, cfg.d_model))
        ref = mlp(mk.params, x)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with use_mesh(mesh):
            out = jax.jit(lambda p, x: mlp_ws_decode(p, cfg, x))(mk.params, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        print("WS-OK", err)
    """)
    assert "WS-OK" in out


@pytest.mark.slow
def test_compressed_grad_allreduce_on_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import compressed_grad_allreduce

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16, 32)),
                              jnp.float32)}
        out = compressed_grad_allreduce(g, mesh, axis="pod")
        # replicated input: psum over 2 pods = 2x the (quantized) value
        rel = float(jnp.max(jnp.abs(out["w"] - 2 * g["w"]))
                    / jnp.max(jnp.abs(g["w"])))
        assert rel < 0.05, rel
        print("COMP-OK", rel)
    """)
    assert "COMP-OK" in out
