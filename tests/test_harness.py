"""Repo-hygiene and benchmark-harness regression tests.

Two bug classes this file pins down:

- **tracked bytecode** — four ``__pycache__/*.pyc`` files were once
  committed; the index must stay free of bytecode and ``.gitignore``
  must keep new ones out of ``git status`` noise.
- **silent benchmark skips** — ``python -m benchmarks.run --only <typo>``
  used to run *nothing* and exit 0 (green CI, no data), and the
  ``fig7_slo`` benchmark was never dispatched at all.  The harness now
  validates ``--only`` against its registry and errors loudly.
"""

import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _git(*args):
    return subprocess.run(
        ["git", *args], cwd=REPO, capture_output=True, text=True
    )


# ---------------------------------------------------------------------------
# repo hygiene
# ---------------------------------------------------------------------------
def test_no_tracked_bytecode():
    res = _git("ls-files")
    if res.returncode != 0:
        pytest.skip("not a git checkout")
    bad = [
        line for line in res.stdout.splitlines()
        if line.endswith((".pyc", ".pyo")) or "__pycache__/" in line
    ]
    assert not bad, f"bytecode artifacts tracked in git: {bad}"


def test_gitignore_covers_bytecode():
    text = (REPO / ".gitignore").read_text()
    assert "__pycache__/" in text.split()
    assert "*.pyc" in text.split()


# ---------------------------------------------------------------------------
# benchmark harness
# ---------------------------------------------------------------------------
def _run_harness(*args):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, capture_output=True, text=True, timeout=120,
    )


def test_unknown_only_name_is_an_error():
    # pre-fix this exited 0 having run nothing
    res = _run_harness("--only", "fig7_sl0")
    assert res.returncode != 0
    assert "unknown benchmark name" in res.stderr
    assert "fig7_sl0" in res.stderr
    assert "fig7_slo" in res.stderr  # the known set is listed for the user


def test_empty_only_is_an_error():
    res = _run_harness("--only", ",")
    assert res.returncode != 0


def test_registry_dispatches_every_benchmark():
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks import run as harness
    finally:
        sys.path.remove(str(REPO))
    # fig7_slo existed as a module but was missing from the dispatcher;
    # fig11 is the kernel/capacity benchmark added alongside int8 pages
    for name in ("fig1", "fig7", "fig7_slo", "table1", "fig9", "fig10",
                 "fig10_cascade", "fig8", "fig11", "roofline"):
        assert name in harness.ENTRIES, f"{name} missing from harness"
    # every registered entry maps to an importable benchmark module
    import importlib
    mod_by_entry = {
        "fig1": "fig1_characterization",
        "fig7": "fig7_simulation",
        "fig7_slo": "fig7_slo",
        "table1": "table1_overhead",
        "fig9": "fig9_sensitivity",
        "fig10": "fig10_ablation",
        "fig10_cascade": "fig10_cascade",
        "fig8": "fig8_testbed",
        "fig11": "fig11_kernels",
        "roofline": "roofline",
    }
    assert set(mod_by_entry) == set(harness.ENTRIES)
    for mod in mod_by_entry.values():
        assert (REPO / "benchmarks" / f"{mod}.py").exists(), mod
