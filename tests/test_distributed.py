"""Sharding rules, checkpoint/restore (+ elastic reshard), optimizer
state quantization, gradient compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import sharding as shd
from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.distributed.compression import (
    compress_decompress,
    dequantize_rowwise,
    quantize_rowwise,
)
from repro.distributed.optimizer import OptConfig, adamw_update, init_opt_state


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_resolve_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = shd.resolve_spec(("batch", "kv_seq", "kv_heads", None),
                            (128, 4096, 8, 128), mesh=FakeMesh(),
                            rules=shd.DEFAULT_RULES)
    # kv_seq grabs model; kv_heads (8 % 16 != 0) falls back to replicated
    assert spec[1] == "model" and spec[2] is None
    assert spec[0] == "data"


def test_resolve_spec_no_double_axis():
    class FakeMesh:
        shape = {"data": 4, "model": 4}

    spec = shd.resolve_spec(("ff", "ff"), (64, 64), mesh=FakeMesh(),
                            rules=shd.DEFAULT_RULES)
    used = [s for s in spec if s is not None]
    assert len(used) == len(set(used)) <= 1


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, ("batch", None))
    assert (np.asarray(x) == np.asarray(y)).all()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
    }


def test_checkpoint_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, t)
        save_checkpoint(d, 20, jax.tree.map(lambda x: x + 1, t))
        assert latest_step(d) == 20
        restored, step = restore_checkpoint(d, like=t)
        assert step == 20
        np.testing.assert_allclose(
            np.asarray(restored["w"]), np.asarray(t["w"]) + 1
        )
        restored10, _ = restore_checkpoint(d, like=t, step=10)
        np.testing.assert_allclose(np.asarray(restored10["w"]), np.asarray(t["w"]))


def test_checkpoint_gc_keeps_last():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, t, keep_last=2)
        steps = sorted(p for p in os.listdir(d) if p.startswith("step_"))
        assert len(steps) == 2 and steps[-1].endswith("5".zfill(8))


def test_checkpoint_crash_restart_resumes():
    """Fault-tolerance: training resumes from the latest atomic step."""
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 100, t)
        # simulate partial write (crash): stray tmp dir must be ignored
        os.makedirs(os.path.join(d, ".tmp_crashed"), exist_ok=True)
        restored, step = restore_checkpoint(d, like=t)
        assert step == 100


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("state_dtype", ["float32", "int8"])
def test_adamw_reduces_loss(state_dtype):
    cfg = OptConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype,
                    warmup_steps=1)
    w = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)),
                          jnp.float32)}
    target = jnp.zeros((4, 8))
    state = init_opt_state(w, cfg)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(w))
    for _ in range(30):
        g = jax.grad(loss)(w)
        w, state, _ = adamw_update(w, g, state, cfg)
    assert float(loss(w)) < 0.2 * l0


def test_int8_state_memory_is_quarter():
    cfg8 = OptConfig(state_dtype="int8")
    w = {"w": jnp.zeros((128, 256), jnp.float32)}
    st = init_opt_state(w, cfg8)
    q = st["mv"]["w"]["m"].q
    assert q.dtype == jnp.int8 and q.shape == (128, 256)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_quantize_roundtrip_accuracy():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 64)), jnp.float32)
    q, s = quantize_rowwise(x)
    xh = dequantize_rowwise(q, s)
    rel = float(jnp.max(jnp.abs(xh - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02  # int8 rowwise: <2% of row max


def test_error_feedback_telescopes():
    """With error feedback the cumulative transmitted signal converges to
    the cumulative true signal (bias telescopes away)."""
    rng = np.random.default_rng(2)
    g_true = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32) * 1e-3
    resid = None
    sent_total = jnp.zeros_like(g_true)
    for _ in range(50):
        sent, resid = compress_decompress(g_true, resid)
        sent_total = sent_total + sent
    avg_sent = sent_total / 50
    np.testing.assert_allclose(np.asarray(avg_sent), np.asarray(g_true),
                               atol=float(jnp.max(jnp.abs(g_true))) * 0.05)
