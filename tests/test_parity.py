"""Sim ↔ testbed parity smoke test (calibration-drift canary).

The same seeded job set runs through the event-driven simulator and the
real paged-KV engine testbed under the same scheduler.  Absolute times
differ (the simulator uses the analytic l(b), the testbed wall-clock on
a smoke model), but the per-job JCT *ordering* must agree: a drift in
rank correlation means the simulator's latency/batching model and the
real engine have diverged, which silently invalidates every simulator
figure.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FCFS
from repro.serving import PagedLLMEngine, ServingCluster
from repro.sim import generate_workload
from repro.sim.simulator import ClusterSim


def _spearman(x, y):
    def ranks(v):
        order = np.argsort(v)
        r = np.empty(len(v))
        r[order] = np.arange(len(v))
        return r
    rx, ry = ranks(np.asarray(x)), ranks(np.asarray(y))
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / denom) if denom else 0.0


@pytest.mark.slow
def test_sim_testbed_jct_rank_parity():
    # predefined mix (seq_sort/doc_merge): wide per-job duration spread,
    # so the rank signal dominates wall-clock noise (ρ≈0.95 in practice)
    n_jobs, seed = 10, 5
    # identical ground truth: same generator seed for both runtimes
    wl_sim = generate_workload("predefined", n_jobs, arrival_rate=1.5, seed=seed)
    wl_tb = generate_workload("predefined", n_jobs, arrival_rate=1.5, seed=seed)
    for a, b in zip(wl_sim, wl_tb):
        assert a.durations.keys() == b.durations.keys()

    sim = ClusterSim(FCFS(), n_regular=3, n_llm=1, max_batch=4, seed=0)
    res_sim = sim.run(wl_sim)

    # token_scale 10: enough decode work per job that JCT differences are
    # dominated by the jobs themselves, not by event-loop overhead —
    # over-compressed workloads make the rank correlation pure noise
    cluster = ServingCluster(
        FCFS(),
        [PagedLLMEngine(get_smoke_config("stablelm_1_6b"), max_seqs=4,
                        max_len=96, page_size=16, seed=0)],
        n_regular=3, token_scale=10.0, time_scale=10.0,
    )
    res_tb = cluster.run(wl_tb)

    assert len(res_sim.jct_by_job) == n_jobs
    assert len(res_tb.jct_by_job) == n_jobs
    jct_sim = [res_sim.jct_by_job[gj.job.job_id] for gj in wl_sim]
    jct_tb = [res_tb.jct_by_job[gj.job.job_id] for gj in wl_tb]

    rho = _spearman(jct_sim, jct_tb)
    # fixed threshold: catches calibration drift, tolerates wall-clock noise
    assert rho > 0.5, (
        f"sim↔testbed JCT rank correlation collapsed: ρ={rho:.2f}\n"
        f"sim: {np.round(jct_sim, 2)}\ntestbed: {np.round(jct_tb, 2)}"
    )
