"""Sim ↔ testbed parity smoke tests (model-drift canaries).

The same seeded job set runs through the event-driven simulator and the
real paged-KV engine testbed under the same scheduler.  Absolute times
differ (the simulator uses the analytic l(b), the testbed wall-clock on
a smoke model), but the per-job *orderings* must agree:

- JCT rank drift means the simulator's latency/batching model and the
  real engine have diverged, silently invalidating every simulator
  figure;
- per-job **prefill-token** rank drift means the simulator's shared-
  prefix cache model (app-keyed residency) and the testbed's radix
  prefix index no longer describe the same savings, silently
  invalidating every cache sweep.
"""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FCFS
from repro.serving import PagedLLMEngine, ServeConfig, ServingCluster
from repro.sim import generate_workload
from repro.sim.simulator import ClusterSim


def _spearman(x, y):
    def ranks(v):
        v = np.asarray(v, dtype=np.float64)
        order = np.argsort(v, kind="stable")
        r = np.empty(len(v))
        r[order] = np.arange(len(v), dtype=np.float64)
        # tie-average so integer-valued series (prefill counts) don't
        # pick up spurious rank noise from argsort order
        for val in np.unique(v):
            mask = v == val
            r[mask] = r[mask].mean()
        return r
    rx, ry = ranks(x), ranks(y)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = np.sqrt((rx ** 2).sum() * (ry ** 2).sum())
    return float((rx * ry).sum() / denom) if denom else 0.0


@pytest.mark.slow
def test_sim_testbed_jct_rank_parity():
    # predefined mix (seq_sort/doc_merge): wide per-job duration spread,
    # so the rank signal dominates wall-clock noise (ρ≈0.95 in practice)
    n_jobs, seed = 10, 5
    # identical ground truth: same generator seed for both runtimes
    wl_sim = generate_workload("predefined", n_jobs, arrival_rate=1.5, seed=seed)
    wl_tb = generate_workload("predefined", n_jobs, arrival_rate=1.5, seed=seed)
    for a, b in zip(wl_sim, wl_tb):
        assert a.durations.keys() == b.durations.keys()

    sim = ClusterSim(FCFS(), n_regular=3, n_llm=1, max_batch=4, seed=0)
    res_sim = sim.run(wl_sim)

    # token_scale 10: enough decode work per job that JCT differences are
    # dominated by the jobs themselves, not by event-loop overhead —
    # over-compressed workloads make the rank correlation pure noise
    cluster = ServingCluster(
        FCFS(),
        [PagedLLMEngine(get_smoke_config("stablelm_1_6b"), max_seqs=4,
                        max_len=96, page_size=16, seed=0)],
        ServeConfig(n_regular=3, token_scale=10.0, time_scale=10.0),
    )
    res_tb = cluster.run(wl_tb)

    assert len(res_sim.jct_by_job) == n_jobs
    assert len(res_tb.jct_by_job) == n_jobs
    jct_sim = [res_sim.jct_by_job[gj.job.job_id] for gj in wl_sim]
    jct_tb = [res_tb.jct_by_job[gj.job.job_id] for gj in wl_tb]

    rho = _spearman(jct_sim, jct_tb)
    # fixed threshold: catches calibration drift, tolerates wall-clock noise
    assert rho > 0.5, (
        f"sim↔testbed JCT rank correlation collapsed: ρ={rho:.2f}\n"
        f"sim: {np.round(jct_sim, 2)}\ntestbed: {np.round(jct_tb, 2)}"
    )


@pytest.mark.slow
def test_sim_testbed_prefill_token_rank_parity():
    """Cache-model drift canary: with the prefix cache on in both
    runtimes (same shared-prompt geometry), the per-job prefill token
    totals must rank-agree — the sim's app-keyed residency model and
    the testbed's radix index describe the same savings."""
    n_jobs, seed, shared = 10, 5, 16   # shared prompt = 2 pages at ps=8
    wl_sim = generate_workload("predefined", n_jobs, arrival_rate=1.5,
                               seed=seed)
    wl_tb = generate_workload("predefined", n_jobs, arrival_rate=1.5,
                              seed=seed)

    sim = ClusterSim(FCFS(), n_regular=3, n_llm=1, max_batch=4,
                     prompt_tokens_per_task=float(shared + 2),
                     shared_prompt_tokens=float(shared),
                     prefix_cache=True, seed=0)
    res_sim = sim.run(wl_sim)

    cluster = ServingCluster(
        FCFS(),
        [PagedLLMEngine(get_smoke_config("stablelm_1_6b"), max_seqs=4,
                        max_len=96, page_size=8, prefill_chunk=8, seed=0,
                        prefix_cache=True)],
        ServeConfig(n_regular=3, token_scale=10.0, time_scale=10.0,
                    shared_prompt_tokens=shared),
    )
    res_tb = cluster.run(wl_tb)

    # both runtimes actually hit their caches
    assert res_sim.prefill_saved_tokens > 0
    assert res_tb.prefill_saved_tokens > 0
    assert set(res_sim.prefill_by_job) == {gj.job.job_id for gj in wl_sim}
    assert set(res_tb.prefill_by_job) == {gj.job.job_id for gj in wl_tb}

    pf_sim = [res_sim.prefill_by_job[gj.job.job_id] for gj in wl_sim]
    pf_tb = [res_tb.prefill_by_job[gj.job.job_id] for gj in wl_tb]
    rho = _spearman(pf_sim, pf_tb)
    assert rho > 0.5, (
        f"sim↔testbed prefill-token rank correlation collapsed: "
        f"ρ={rho:.2f}\nsim: {np.round(pf_sim, 1)}\n"
        f"testbed: {np.round(pf_tb, 1)}"
    )
