"""Unit + property tests for the discrete Bayesian network (§IV-B)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bayesnet import (
    BayesNet,
    Factor,
    eliminate,
    fit_discretizer,
)


# ---------------------------------------------------------------------------
# Factor algebra
# ---------------------------------------------------------------------------
def test_factor_product_marginalize():
    fa = Factor(("a",), np.array([0.3, 0.7]))
    fb = Factor(("b",), np.array([0.5, 0.5]))
    prod = fa.product(fb)
    assert prod.vars == ("a", "b")
    np.testing.assert_allclose(prod.values.sum(), 1.0)
    ma = prod.marginalize("b")
    np.testing.assert_allclose(ma.values, [0.3, 0.7])


def test_factor_reduce():
    f = Factor(("a", "b"), np.arange(6, dtype=float).reshape(2, 3))
    r = f.reduce("a", 1)
    np.testing.assert_allclose(r.values, [3, 4, 5])
    assert r.vars == ("b",)


def test_eliminate_chain():
    # a -> b: P(b) = sum_a P(a) P(b|a)
    pa = Factor(("a",), np.array([0.2, 0.8]))
    pba = Factor(("b", "a"), np.array([[0.9, 0.1], [0.1, 0.9]]))
    out = eliminate([pa, pba], keep=["b"]).normalize()
    np.testing.assert_allclose(out.values, [0.9 * 0.2 + 0.1 * 0.8,
                                            0.1 * 0.2 + 0.9 * 0.8])


# ---------------------------------------------------------------------------
# Discretizer
# ---------------------------------------------------------------------------
def test_discretizer_zero_bin():
    d = fit_discretizer([0.0, 0.0, 1.0, 2.0, 3.0, 4.0], max_bins=3)
    assert d.has_zero_bin
    assert d.transform(0.0) == 0
    assert d.transform(10.0) == d.cardinality - 1


@given(st.lists(st.floats(0.1, 1000.0), min_size=2, max_size=200))
@settings(max_examples=50, deadline=None)
def test_discretizer_total_order(samples):
    d = fit_discretizer(samples, max_bins=6)
    # transform is monotone non-decreasing in duration
    xs = sorted(samples)
    bins = [d.transform(x) for x in xs]
    assert bins == sorted(bins)
    assert max(bins) < d.cardinality


@given(st.lists(st.floats(0.1, 100.0), min_size=5, max_size=100))
@settings(max_examples=30, deadline=None)
def test_discretizer_expectation_in_range(samples):
    d = fit_discretizer(samples, max_bins=6)
    probs = np.ones(d.cardinality) / d.cardinality
    e = d.expectation(probs)
    assert 0.0 <= e <= max(samples) + 1e-9


# ---------------------------------------------------------------------------
# BN fit + inference
# ---------------------------------------------------------------------------
def _toy_bn(n=2000, seed=0):
    """a ~ Bernoulli, b strongly correlated with a, c independent."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, n)
    b = np.where(rng.random(n) < 0.9, a, 1 - a)
    c = rng.integers(0, 3, n)
    data = np.stack([a, b, c], axis=1)
    bn = BayesNet().fit(
        data, names=["a", "b", "c"], cards=[2, 2, 3],
        template_edges=[("a", "b")],
    )
    return bn


def test_bn_posterior_updates():
    bn = _toy_bn()
    prior_b = bn.marginal("b")
    post_b = bn.marginal("b", {"a": 1})
    assert post_b[1] > prior_b[1] + 0.2  # evidence sharpens prediction
    assert abs(post_b.sum() - 1.0) < 1e-9


def test_bn_independent_unchanged():
    bn = _toy_bn()
    prior_c = bn.marginal("c")
    post_c = bn.marginal("c", {"a": 1})
    np.testing.assert_allclose(prior_c, post_c, atol=0.05)


def test_bn_correlated_path():
    bn = _toy_bn()
    assert bn.correlated("a", "b")
    assert not bn.correlated("b", "a")  # directed
    assert "a" in bn.uncertainty_reducing()


def test_bn_joint_normalized():
    bn = _toy_bn()
    j = bn.joint(["a", "b"], {"c": 0})
    assert abs(j.values.sum() - 1.0) < 1e-9
    assert j.values.shape == (2, 2)


# ---------------------------------------------------------------------------
# Exact-inference property: variable elimination == brute-force enumeration
# ---------------------------------------------------------------------------
@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_bn_inference_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    n_vars = int(rng.integers(3, 6))
    cards = [int(rng.integers(2, 4)) for _ in range(n_vars)]
    names = [f"v{i}" for i in range(n_vars)]
    # random DAG data with chained dependencies
    n = 500
    cols = []
    for i, c in enumerate(cards):
        if i == 0 or rng.random() < 0.3:
            cols.append(rng.integers(0, c, n))
        else:
            parent = cols[int(rng.integers(0, i))]
            noise = rng.integers(0, c, n)
            cols.append(np.where(rng.random(n) < 0.7, parent % c, noise))
    data = np.stack(cols, axis=1)
    bn = BayesNet().fit(data, names=names, cards=cards,
                        template_edges=[(names[i], names[i + 1])
                                        for i in range(n_vars - 1)])

    # brute force: enumerate the full joint from the CPDs
    import itertools as it
    full = np.zeros(cards)
    for assign in it.product(*[range(c) for c in cards]):
        p = 1.0
        for v in names:
            f = bn.cpds[v]
            idx = tuple(assign[names.index(x)] for x in f.vars)
            p *= float(f.values[idx])
        full[assign] = p
    full /= full.sum()

    # compare marginals with and without evidence
    q = names[-1]
    marg_ve = bn.marginal(q)
    axes = tuple(i for i in range(n_vars) if names[i] != q)
    marg_bf = full.sum(axis=axes)
    np.testing.assert_allclose(marg_ve, marg_bf, atol=1e-9)

    ev_var, ev_val = names[0], 0
    post_ve = bn.marginal(q, {ev_var: ev_val})
    sliced = np.take(full, ev_val, axis=0)
    axes2 = tuple(i for i in range(n_vars - 1) if names[i + 1] != q)
    post_bf = sliced.sum(axis=axes2)
    post_bf = post_bf / post_bf.sum()
    np.testing.assert_allclose(post_ve, post_bf, atol=1e-9)


def test_discretizer_clamps_unseen_duration_class():
    """Regression: a stage whose training history was all zeros (never
    executed) fits a single zero bin; observing it *execute* at runtime
    produced bin 1 and indexed past the CPD's cardinality deep inside
    factor reduction.  Out-of-support durations now clamp to the last
    fitted bin."""
    from repro.core.bayesnet import fit_discretizer

    d = fit_discretizer([0.0, 0.0, 0.0])
    assert d.transform(0.0) == 0
    assert d.transform(5.0) == 0          # clamped, not 1
    # well-fitted discretizers are untouched by the clamp
    d2 = fit_discretizer([0.0, 1.0, 2.0, 3.0, 4.0])
    assert d2.transform(0.0) == 0
    assert d2.transform(2.5) == d2.transform(2.5)
    assert d2.transform(1e9) == len(d2.repr_value) - 1
