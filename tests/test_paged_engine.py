"""Paged KV-cache engine + allocator tests.

Covers the edge cases the slot-engine suite never exercised: page
alloc/free invariants, admission refusal on pool exhaustion,
preemption-by-eviction with requeue, stop-token early exit, chunked
prefill, defrag — plus the acceptance gate: the paged engine matches
the slot engine token-for-token under greedy decoding.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import LLMEngine, PagedLLMEngine, PageAllocator, Request


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("stablelm_1_6b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.key(0))[0]


def _drain(eng, max_steps=400):
    steps = 0
    while (eng.batch_size or getattr(eng, "waiting", ())) and steps < max_steps:
        eng.step()
        steps += 1
    return steps


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------
def test_allocator_alloc_free_invariants():
    a = PageAllocator(num_pages=8, page_size=16)
    assert a.free_pages == 7  # page 0 reserved
    p1 = a.alloc(3, owner=1)
    p2 = a.alloc(2, owner=2)
    assert p1 is not None and p2 is not None
    assert 0 not in p1 + p2                      # trash page never handed out
    assert len(set(p1) | set(p2)) == 5           # no aliasing
    assert a.alloc(3) is None                    # atomic refusal (2 left)
    assert a.free_pages == 2
    a.free(p1)
    assert a.free_pages == 5
    with pytest.raises(ValueError):
        a.free(p1)                               # double free detected
    with pytest.raises(AssertionError):
        a.check_no_leaks()                       # p2 still held
    a.free(p2)
    a.check_no_leaks()
    assert a.pages_for(0) == 0 and a.pages_for(1) == 1 and a.pages_for(17) == 2


def test_allocator_defrag_compacts():
    a = PageAllocator(num_pages=16, page_size=8)
    p1 = a.alloc(4, owner=1)
    p2 = a.alloc(4, owner=2)
    p3 = a.alloc(4, owner=3)
    a.free(p2)  # hole in the middle
    mapping = a.defrag()
    assert a.owned_by(1) + a.owned_by(3) == list(range(1, 9))  # compact
    assert all(old > new for old, new in mapping.items())
    # allocator still functional after compaction
    p4 = a.alloc(7, owner=4)
    assert p4 is not None and len(set(p4)) == 7
    a.free(p4)
    a.free([mapping.get(p, p) for p in p1])
    a.free([mapping.get(p, p) for p in p3])
    a.check_no_leaks()


# ---------------------------------------------------------------------------
# acceptance: token-for-token parity with the slot engine
# ---------------------------------------------------------------------------
def test_paged_matches_slot_token_for_token(cfg, params):
    slot = LLMEngine(cfg, max_batch=4, max_len=64, params=params)
    # pinned fp32: slot-parity is a *bit-identical* contract, which int8
    # quantization intentionally relaxes (nightly runs REPRO_KV_DTYPE=int8)
    paged = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                           params=params, kv_dtype="fp32")
    prompts = [[1, 2, 3], [5, 6], [7, 8, 9, 10], [2]]
    out_slot, out_paged = {}, {}
    for i, p in enumerate(prompts):
        assert slot.admit(Request(
            rid=i, prompt=p, max_new_tokens=10,
            on_finish=lambda r: out_slot.__setitem__(r.rid, list(r.out_tokens))))
        assert paged.admit(Request(
            rid=i, prompt=p, max_new_tokens=10,
            on_finish=lambda r: out_paged.__setitem__(r.rid, list(r.out_tokens))))
    _drain(slot)
    _drain(paged)
    assert out_slot == out_paged          # greedy decode: exact match
    paged.allocator.check_no_leaks()      # all pages returned


def test_chunked_prefill_interleaves_and_matches(cfg, params):
    """A prompt longer than prefill_chunk crosses chunk+page boundaries
    and still reproduces the slot engine's tokens."""
    prompt = list(range(1, 30))
    slot = LLMEngine(cfg, max_batch=2, max_len=64, params=params)
    paged = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8,
                           params=params, prefill_chunk=8, kv_dtype="fp32")
    o1, o2 = {}, {}
    slot.admit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                       on_finish=lambda r: o1.__setitem__(r.rid, list(r.out_tokens))))
    paged.admit(Request(rid=0, prompt=prompt, max_new_tokens=6,
                        on_finish=lambda r: o2.__setitem__(r.rid, list(r.out_tokens))))
    _drain(slot)
    # chunked prefill: the request must NOT be decoding after one step
    paged.step()
    assert paged.prefilling and not paged.active
    _drain(paged)
    assert o1 == o2


# ---------------------------------------------------------------------------
# edge cases the slot-engine suite misses
# ---------------------------------------------------------------------------
def test_admission_refused_when_pool_exhausted(cfg, params):
    eng = PagedLLMEngine(cfg, max_seqs=8, max_len=64, page_size=8,
                         num_pages=9, params=params)
    assert eng.admit(Request(rid=0, prompt=[1] * 40, max_new_tokens=4))  # 6 pages
    assert not eng.admit(Request(rid=1, prompt=[1] * 40, max_new_tokens=4))
    done = []
    assert eng.admit(Request(rid=2, prompt=[2], max_new_tokens=2,
                             on_finish=lambda r: done.append(r.rid)))
    _drain(eng)
    assert done == [2]
    eng.allocator.check_no_leaks()


def test_preemption_eviction_requeues_and_completes(cfg, params):
    """Pool too small for 3 full sequences: decode growth must evict the
    youngest (pages freed, request requeued) and still finish everyone."""
    eng = PagedLLMEngine(cfg, max_seqs=3, max_len=64, page_size=8,
                         num_pages=14, params=params)
    done = []
    for i in range(3):
        assert eng.admit(Request(rid=i, prompt=[1 + i] * 4, max_new_tokens=40,
                                 on_finish=lambda r: done.append(r.rid)))
    _drain(eng)
    assert sorted(done) == [0, 1, 2]      # evicted requests re-ran to completion
    assert eng.preemptions > 0            # eviction actually happened
    eng.allocator.check_no_leaks()        # freed victim pages were not lost


def test_no_mutual_eviction_livelock(cfg, params):
    """Two requests that each need (almost) the whole pool must not evict
    each other forever: eviction is strictly age-ordered, so the older
    one runs to completion while the younger self-preempts and waits."""
    eng = PagedLLMEngine(cfg, max_seqs=2, max_len=16, page_size=4,
                         num_pages=5, params=params)
    done = []
    for i in range(2):
        assert eng.admit(Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=11,
                                 on_finish=lambda r: done.append(r.rid)))
    steps = _drain(eng, max_steps=120)
    assert sorted(done) == [0, 1], f"livelock: {len(done)} finished in {steps} steps"
    eng.allocator.check_no_leaks()


def test_admit_refusal_with_can_admit_true(cfg, params):
    """can_admit() is a cheap 1-page pre-filter; admit() may still refuse
    a multi-page prompt.  Callers must handle the False return (the
    cluster leaves the task PENDING and retries next round)."""
    eng = PagedLLMEngine(cfg, max_seqs=4, max_len=16, page_size=2,
                         num_pages=9, params=params)
    assert eng.admit(Request(rid=0, prompt=[1] * 13, max_new_tokens=2))  # 7 pages
    assert eng.can_admit()                      # 1 page free, row free
    assert not eng.admit(Request(rid=1, prompt=[1, 2, 3], max_new_tokens=2))
    # the refusal left no partial state behind
    assert len(eng.seq_pages) == 1 and len(eng.free_rows) == 3
    _drain(eng)
    eng.allocator.check_no_leaks()


def test_stop_token_early_exit(cfg, params):
    ref = PagedLLMEngine(cfg, max_seqs=1, max_len=64, page_size=8, params=params)
    outs = {}
    ref.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=12,
                      on_finish=lambda r: outs.__setitem__(r.rid, list(r.out_tokens))))
    _drain(ref)
    seq = outs[0]
    stop = seq[3]                          # a token generated mid-stream
    eng = PagedLLMEngine(cfg, max_seqs=1, max_len=64, page_size=8, params=params)
    outs2 = {}
    eng.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=12, stop_token=stop,
                      on_finish=lambda r: outs2.__setitem__(r.rid, list(r.out_tokens))))
    _drain(eng)
    got = outs2[0]
    first_stop = next(i for i, t in enumerate(got) if i >= 1 and t == stop)
    assert got[-1] == stop and len(got) == first_stop + 1
    assert len(got) < len(seq)            # actually exited early
    eng.allocator.check_no_leaks()


def test_engine_defrag_after_churn(cfg, params):
    """Finish interleaved requests to fragment the pool, defrag, and keep
    decoding — remapped pages must preserve outputs exactly."""
    def run(defrag_at):
        eng = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                             params=params)
        outs = {}
        lens = [3, 14, 3, 14]
        for i, n in enumerate(lens):
            eng.admit(Request(rid=i, prompt=[2 + i, 5], max_new_tokens=n,
                              on_finish=lambda r: outs.__setitem__(r.rid, list(r.out_tokens))))
        steps = 0
        moved = 0
        while eng.batch_size and steps < 100:
            eng.step()
            steps += 1
            if steps == defrag_at:
                moved = eng.defrag()
        eng.allocator.check_no_leaks()
        return outs, moved

    base, _ = run(defrag_at=-1)
    # short requests finish by step 5 -> their pages leave holes
    got, moved = run(defrag_at=6)
    assert got == base
    assert moved > 0                       # compaction actually moved pages


def test_latency_profile_feeds_calibration(cfg, params):
    eng = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8, params=params)
    for i in range(3):
        eng.admit(Request(rid=i, prompt=[1, 2], max_new_tokens=6))
    _drain(eng)
    prof = eng.latency_profile()
    assert prof is not None and prof.l(1) > 0
    assert prof.calibrate(10.0, b_r=1, b_t=3) > 0


# ---------------------------------------------------------------------------
# EDF waiting-queue drain: equal deadlines re-admit in arrival order
# ---------------------------------------------------------------------------
def test_equal_deadline_waiters_drain_in_arrival_order(cfg, params):
    """Regression: the waiting-queue drain tie-broke equal-priority
    requests by deque position (= eviction order), not arrival order.
    Single-engine eviction happens to preserve arrival order, but a
    live-migrated request evicted late sits at the deque head — so an
    equal-deadline *younger* arrival was re-admitted ahead of an older
    waiter.  The drain key is now (priority, arrival_seq)."""
    e1 = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8, params=params)
    e2 = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8, params=params)

    # A arrives first (on e2), B second (on e1): fleet arrival order A < B
    req_a = Request(rid=1, prompt=[1, 2, 3], max_new_tokens=20, priority=5.0)
    req_b = Request(rid=2, prompt=[4, 5, 6], max_new_tokens=20, priority=5.0)
    assert e2.admit(req_a)
    assert e1.admit(req_b)
    assert req_a.arrival_seq < req_b.arrival_seq

    # migrate A onto e1: it lands with the *youngest* admission stamp
    # there despite being the older arrival
    for _ in range(4):           # finish A's prefill so it is exportable
        e2.step()
    row_a = e2.youngest_active_row()
    assert row_a is not None
    assert e1.import_request(e2.export_request(row_a))

    # pool pressure evicts youngest-row first (exactly what _evict_for
    # does when decode growth finds the pool dry): A is evicted before
    # B, so appendleft leaves the younger arrival B at the deque head
    for _ in range(2):
        rows = dict(e1.active)
        rows.update({r: rq for r, (rq, _) in e1.prefilling.items()})
        e1._evict_row(max(rows, key=lambda r: e1._row_seq[r]))
    assert list(e1.waiting)[0] is req_b   # the head-position trap

    # drain: the older arrival must re-admit first despite B at head
    e1.step()
    rows = dict(e1.active)
    rows.update({r: rq for r, (rq, _) in e1.prefilling.items()})
    seq_of = {rq.rid: e1._row_seq[r] for r, rq in rows.items()}
    assert seq_of[req_a.rid] < seq_of[req_b.rid], (
        "equal-deadline drain re-admitted the younger arrival first"
    )
