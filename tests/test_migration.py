"""Live cross-replica KV migration: mechanism, policy, and payoff.

Covers the multi-replica tentpole end to end:
- the handoff preserves greedy decode token-for-token (the migrated
  continuation equals an unmigrated reference run);
- the allocator invariants survive the handoff (no leaks on the source,
  exact ownership on the destination, double frees still caught);
- the rollback path (destination refuses at the last moment) loses
  neither the request nor pages;
- the rebalancer converts eviction churn on a starved replica into
  lossless migrations;
- the scheduler's placement map sends LLM tasks to replicas with KV
  headroom;
- a seeded simulator run under a skewed arrival burst shows migration
  reduces p95 JCT vs the identical no-migration cluster.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FCFS, LLMSched, ProfileStore
from repro.core.scheduler import ClusterView, task_key
from repro.models import init_params
from repro.serving import (
    PagedLLMEngine,
    Rebalancer,
    Request,
    migrate_request,
)
from repro.sim import generate_traces, generate_workload, get_generators
from repro.sim.simulator import ClusterSim


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("stablelm_1_6b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.key(0))[0]


def _drain(*engines, max_steps=400):
    steps = 0
    while any(e.batch_size or e.waiting for e in engines) and steps < max_steps:
        for e in engines:
            if e.batch_size or e.waiting:
                e.step()
        steps += 1
    return steps


def _collects(out):
    return lambda r: out.__setitem__(r.rid, list(r.out_tokens))


# ---------------------------------------------------------------------------
# acceptance: token-for-token equality across a forced mid-decode move
# ---------------------------------------------------------------------------
def test_forced_migration_token_equality(cfg, params):
    """Decode 4 tokens on A, migrate mid-decode to B, finish there: the
    full output must equal an unmigrated reference run exactly."""
    ref_out = {}
    ref = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                         params=params)
    ref.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=12,
                      on_finish=_collects(ref_out)))
    _drain(ref)

    out = {}
    a = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8, params=params)
    b = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8, params=params)
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=12,
                    on_finish=_collects(out)))
    for _ in range(5):  # prefill + 4 decode steps
        a.step()
    row = a.youngest_active_row()
    mid = list(a.active[row].out_tokens)
    assert 0 < len(mid) < 12          # genuinely mid-decode
    assert migrate_request(a, b, row)
    a.allocator.check_no_leaks()      # source fully released immediately
    assert a.batch_size == 0 and b.batch_size == 1
    _drain(b)
    b.allocator.check_no_leaks()
    assert out == ref_out             # greedy continuation is unaffected
    assert a.migrations_out == 1 and b.migrations_in == 1


def test_migration_across_page_boundary_and_growth(cfg, params):
    """Migrate a request whose KV spans several pages and which must
    allocate fresh pages on the destination to keep growing."""
    ref_out, out = {}, {}
    ref = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=4,
                         params=params)
    ref.admit(Request(rid=7, prompt=list(range(1, 11)), max_new_tokens=20,
                      on_finish=_collects(ref_out)))
    _drain(ref)

    a = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=4, params=params)
    b = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=4, params=params)
    a.admit(Request(rid=7, prompt=list(range(1, 11)), max_new_tokens=20,
                    on_finish=_collects(out)))
    for _ in range(8):
        a.step()
    row = a.youngest_active_row()
    assert len(a.seq_pages[row]) >= 3       # multi-page KV really moves
    assert migrate_request(a, b, row)
    _drain(b)
    assert out == ref_out
    a.allocator.check_no_leaks()
    b.allocator.check_no_leaks()


# ---------------------------------------------------------------------------
# allocator handoff invariants
# ---------------------------------------------------------------------------
def test_allocator_handoff_no_leak_no_double_free(cfg, params):
    a = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8, params=params)
    b = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8, params=params)
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=10))
    for _ in range(3):
        a.step()
    row = a.youngest_active_row()
    old_pages = list(a.seq_pages[row])
    free_before = b.allocator.free_pages
    ticket = a.export_request(row)
    # source: pages returned exactly once; a second free must raise
    a.allocator.check_no_leaks()
    with pytest.raises(ValueError):
        a.allocator.free(old_pages)
    # destination: allocates exactly the ticket's page count
    assert b.import_request(ticket)
    assert b.allocator.free_pages == free_before - ticket.n_pages
    new_row = b.youngest_active_row()
    assert b.allocator.owned_by(new_row) == sorted(b.seq_pages[new_row])
    _drain(b)
    b.allocator.check_no_leaks()


def test_import_rejects_incompatible_ticket(cfg, params):
    a = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8, params=params)
    b = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=4, params=params)
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8))
    for _ in range(2):
        a.step()
    ticket = a.export_request(a.youngest_active_row())
    with pytest.raises(ValueError):
        b.import_request(ticket)          # page-size mismatch
    # the ticket is still usable: source can take its request back
    assert a.import_request(ticket)
    _drain(a)
    a.allocator.check_no_leaks()


def test_migration_rejects_smaller_max_len_dest(cfg, params):
    """A destination with a shorter max_len could silently truncate the
    continuation: migrate_request must refuse up front (request stays on
    the source) and a direct import must raise."""
    a = PagedLLMEngine(cfg, max_seqs=2, max_len=64, page_size=8, params=params)
    c = PagedLLMEngine(cfg, max_seqs=2, max_len=32, page_size=8, params=params)
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8))
    for _ in range(2):
        a.step()
    row = a.youngest_active_row()
    assert not migrate_request(a, c, row)   # pre-checked: no export happened
    assert row in a.active
    ticket = a.export_request(row)
    with pytest.raises(ValueError):
        c.import_request(ticket)            # direct misuse still raises
    assert a.import_request(ticket)         # ticket survives; roll back
    _drain(a)
    a.allocator.check_no_leaks()


def test_sim_rejects_sub_reserve_kv_budget():
    """A KV budget below the admission reserve would refuse every LLM
    dispatch and deadlock silently — the constructor must reject it."""
    with pytest.raises(ValueError):
        ClusterSim(FCFS(), n_llm=1, max_batch=8, kv_budget_tokens=200)


def test_migrate_request_rolls_back_when_dest_cannot_accept(cfg, params):
    a = PagedLLMEngine(cfg, max_seqs=2, max_len=32, page_size=8, params=params)
    b = PagedLLMEngine(cfg, max_seqs=1, max_len=32, page_size=8, params=params)
    done = []
    b.admit(Request(rid=9, prompt=[5, 6], max_new_tokens=25))  # occupies b
    a.admit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=10,
                    on_finish=lambda r: done.append(r.rid)))
    for _ in range(2):
        a.step()
    row = a.youngest_active_row()
    assert not migrate_request(a, b, row)  # no free row on b
    assert row in a.active                 # request untouched on a
    assert a.migrations_out == 0 and b.migrations_in == 0
    _drain(a, b)
    assert 0 in done
    a.allocator.check_no_leaks()
    b.allocator.check_no_leaks()


# ---------------------------------------------------------------------------
# rebalancer policy
# ---------------------------------------------------------------------------
def test_rebalancer_relieves_starved_replica(cfg, params):
    """Pool too small for 3 growing requests on the small replica while a
    big peer idles: the rebalancer must migrate (not evict) and everyone
    finishes with zero recompute restarts."""
    small = PagedLLMEngine(cfg, max_seqs=3, max_len=64, page_size=8,
                           num_pages=10, params=params)
    big = PagedLLMEngine(cfg, max_seqs=8, max_len=64, page_size=8,
                         params=params)
    done = []
    for i in range(3):
        assert small.admit(Request(rid=i, prompt=[1 + i] * 4,
                                   max_new_tokens=40,
                                   on_finish=lambda r: done.append(r.rid)))
    rb = Rebalancer([small, big])
    steps = 0
    while (small.batch_size or small.waiting or big.batch_size) and steps < 300:
        rb.step()
        for e in (small, big):
            if e.batch_size or e.waiting:
                e.step()
        steps += 1
    assert sorted(done) == [0, 1, 2]
    assert rb.migrations > 0
    assert small.preemptions == 0        # migration pre-empted the eviction
    small.allocator.check_no_leaks()
    big.allocator.check_no_leaks()


def test_rebalancer_ignores_balanced_fleet(cfg, params):
    e1 = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                        params=params)
    e2 = PagedLLMEngine(cfg, max_seqs=4, max_len=64, page_size=8,
                        params=params)
    e1.admit(Request(rid=0, prompt=[1, 2], max_new_tokens=6))
    e2.admit(Request(rid=1, prompt=[3, 4], max_new_tokens=6))
    rb = Rebalancer([e1, e2])
    assert rb.step() == 0                # nobody pressured: no churn
    _drain(e1, e2)
    assert rb.migrations == 0


# ---------------------------------------------------------------------------
# scheduler placement
# ---------------------------------------------------------------------------
def test_llmsched_places_llm_tasks_on_replicas_with_kv_headroom():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 100, seed=7))
    wl = generate_workload("mixed", 10, seed=4)
    jobs = [gj.job for gj in wl]
    sched = LLMSched(store, epsilon=0.2, seed=0)
    view = ClusterView(
        now=0.0, free_regular=4,
        llm_loads=[(0, 8), (0, 8), (0, 8)],
        llm_free_tokens=[0, 64, 4096],   # replica 0 has no KV left
    )
    dec = sched.schedule(jobs, view)
    assert dec.llm                       # the workload has LLM work
    placed = [dec.replica_for(t) for t in dec.llm]
    # tasks beyond the fleet's projected batch+KV capacity stay unplaced
    # (the runtime retries them next round); everything placed avoids
    # the KV-exhausted replica 0 and uses the headroom-rich replica 2
    assert any(p is not None for p in placed)
    assert all(p in (1, 2) for p in placed if p is not None)
    assert 2 in placed
    # keys are stable task identities, not object ids
    assert set(dec.placement) <= {task_key(t) for t in dec.llm}


def test_placement_degenerates_to_least_loaded_without_kv_info():
    """Same decision stream with and without the placement field being
    consumed: no KV info -> placement must equal least-loaded order."""
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 100, seed=7))
    wl = generate_workload("mixed", 6, seed=9)
    jobs = [gj.job for gj in wl]
    sched = LLMSched(store, epsilon=0.0, seed=0)
    view = ClusterView(now=0.0, free_regular=4, llm_loads=[(2, 8), (0, 8)])
    dec = sched.schedule(jobs, view)
    # projected least-loaded: first two tasks go to replica 1 (load 0,1),
    # then strict alternation as projected loads tie-break to index order
    proj = [2, 0]
    for t in dec.llm:
        e = dec.replica_for(t)
        if proj[0] >= 8 and proj[1] >= 8:
            assert e is None     # projected full: left for the next round
            continue
        assert e == min(range(2), key=lambda x: (proj[x], x))
        proj[e] += 1


# ---------------------------------------------------------------------------
# payoff: seeded sim, skewed burst
# ---------------------------------------------------------------------------
def test_sim_migration_reduces_p95_under_skewed_burst():
    """Two KV-budgeted replicas under a compressed arrival burst: live
    migration must cut p95 JCT and preemptions vs the identical cluster
    without it (fully deterministic event-driven run)."""
    def run(migrate: bool):
        wl = generate_workload("mixed", 40, arrival_rate=3.0, seed=3)
        sim = ClusterSim(FCFS(), n_regular=4, n_llm=2, max_batch=8,
                         kv_budget_tokens=[3000, 8000],
                         migrate=migrate, seed=0)
        return sim.run(wl)

    base = run(False)
    mig = run(True)
    assert len(base.jcts) == len(mig.jcts) == 40
    assert base.migrations == 0 and mig.migrations > 0
    assert mig.p95_jct < base.p95_jct
    assert mig.avg_jct <= base.avg_jct
    assert mig.preemptions < base.preemptions


def test_sim_without_kv_budget_unchanged_by_migration_flag():
    """No KV budgets and a single replica: the migrate flag must be a
    no-op (guards the historical single-replica trajectories)."""
    def run(migrate: bool):
        wl = generate_workload("mixed", 12, arrival_rate=1.0, seed=5)
        sim = ClusterSim(FCFS(), n_regular=4, n_llm=1, max_batch=8,
                         migrate=migrate, seed=0)
        return sim.run(wl)

    a, b = run(False), run(True)
    assert a.jcts == b.jcts and a.makespan == b.makespan
    assert b.migrations == 0
