"""Paged decode-attention kernel vs the dense/paged oracles.

All Pallas calls run in interpret mode so the sweep works on CPU CI;
shapes sweep head counts (MHA/GQA/MQA), page sizes, ragged per-request
lengths, and dtypes per the kernel-hardening contract.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.paged_attention import paged_decode_attention

RNG = np.random.default_rng(42)

TOL = {jnp.float32: 3e-5, jnp.bfloat16: 2e-2}


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype)


def _random_tables(B, npp, P):
    """Permuted, non-contiguous page assignments (page 0 reserved)."""
    perm = RNG.permutation(np.arange(1, P))[: B * npp].reshape(B, npp)
    return jnp.asarray(perm, jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,hd,ps,npp",
    [
        (2, 8, 2, 64, 16, 8),     # GQA g=4
        (1, 4, 4, 64, 8, 16),     # MHA, small pages
        (3, 4, 1, 128, 32, 4),    # MQA, wide heads, big pages
        (2, 16, 8, 64, 16, 6),    # many kv heads
        (4, 6, 2, 64, 8, 5),      # odd head-group/page combo
    ],
)
def test_paged_decode_matches_oracles(B, H, K, hd, ps, npp, dtype):
    P = B * npp + 1
    q = _rand((B, H, hd), dtype)
    kp = _rand((P, ps, K, hd), dtype)
    vp = _rand((P, ps, K, hd), dtype)
    bt = _random_tables(B, npp, P)
    lens = jnp.asarray(RNG.integers(1, npp * ps + 1, size=(B,)), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    ref = R.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    # oracle self-consistency: paged ref == dense ref on the gathered view
    dense = R.decode_attention_ref(
        q, R.gather_pages(kp, bt), R.gather_pages(vp, bt), lens
    )
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(dense, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_paged_decode_ignores_garbage_pages():
    """Unreferenced pages and the region past `lengths` must not leak
    into the output — freed-page recycling depends on this."""
    B, H, K, hd, ps, npp = 3, 4, 2, 64, 8, 6
    P = B * npp + 3
    q = _rand((B, H, hd), jnp.float32)
    kp = np.asarray(_rand((P, ps, K, hd), jnp.float32))
    vp = np.asarray(_rand((P, ps, K, hd), jnp.float32))
    bt = np.asarray(_random_tables(B, npp, P))
    lens = np.asarray(RNG.integers(1, npp * ps, size=(B,)), np.int64)

    kp2, vp2 = kp.copy(), vp.copy()
    referenced = set(bt.reshape(-1).tolist())
    for p in range(P):
        if p not in referenced:  # poison unreferenced pages
            kp2[p] = 99.0
            vp2[p] = -99.0
    for b in range(B):  # poison the tail past each request's length
        for j in range(npp):
            lo = max(0, int(lens[b]) - j * ps)
            if lo < ps:
                kp2[bt[b, j], lo:] = 77.0
                vp2[bt[b, j], lo:] = -77.0

    args = (jnp.asarray(bt, jnp.int32), jnp.asarray(lens, jnp.int32))
    o1 = paged_decode_attention(q, jnp.asarray(kp), jnp.asarray(vp), *args,
                                interpret=True)
    o2 = paged_decode_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), *args,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_ops_dispatch_paged_matches_ref():
    """The ops-layer entry point (ref impl on CPU) equals the oracle."""
    B, H, K, hd, ps, npp = 2, 4, 2, 64, 16, 4
    P = B * npp + 1
    q = _rand((B, H, hd), jnp.float32)
    kp = _rand((P, ps, K, hd), jnp.float32)
    vp = _rand((P, ps, K, hd), jnp.float32)
    bt = _random_tables(B, npp, P)
    lens = jnp.asarray([5, 37], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lens)
    ref = R.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
