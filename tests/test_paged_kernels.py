"""Paged attention kernels (decode + fused chunked prefill) vs oracles.

All Pallas calls run in interpret mode so the sweep works on CPU CI;
shapes sweep head counts (MHA/GQA/MQA), page sizes, ragged per-request
lengths, and dtypes per the kernel-hardening contract.  The quantized
sweeps run both kernels over int8 pages with per-page scales and
compare against the dense oracle on the *dequantized* pools — the
quantization error itself is bounded separately (round-trip and
hypothesis property tests on ``quantize_kv_ref``).
"""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.paged_attention import (
    paged_decode_attention,
    paged_prefill_attention,
)

RNG = np.random.default_rng(42)

TOL = {jnp.float32: 3e-5, jnp.bfloat16: 2e-2}
# int8 paths: dominated by quantization, not kernel arithmetic
Q_TOL = 3e-5


def _rand(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype)


def _random_tables(B, npp, P):
    """Permuted, non-contiguous page assignments (page 0 reserved)."""
    perm = RNG.permutation(np.arange(1, P))[: B * npp].reshape(B, npp)
    return jnp.asarray(perm, jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,hd,ps,npp",
    [
        (2, 8, 2, 64, 16, 8),     # GQA g=4
        (1, 4, 4, 64, 8, 16),     # MHA, small pages
        (3, 4, 1, 128, 32, 4),    # MQA, wide heads, big pages
        (2, 16, 8, 64, 16, 6),    # many kv heads
        (4, 6, 2, 64, 8, 5),      # odd head-group/page combo
    ],
)
def test_paged_decode_matches_oracles(B, H, K, hd, ps, npp, dtype):
    P = B * npp + 1
    q = _rand((B, H, hd), dtype)
    kp = _rand((P, ps, K, hd), dtype)
    vp = _rand((P, ps, K, hd), dtype)
    bt = _random_tables(B, npp, P)
    lens = jnp.asarray(RNG.integers(1, npp * ps + 1, size=(B,)), jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    ref = R.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    # oracle self-consistency: paged ref == dense ref on the gathered view
    dense = R.decode_attention_ref(
        q, R.gather_pages(kp, bt), R.gather_pages(vp, bt), lens
    )
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(dense, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_paged_decode_ignores_garbage_pages():
    """Unreferenced pages and the region past `lengths` must not leak
    into the output — freed-page recycling depends on this."""
    B, H, K, hd, ps, npp = 3, 4, 2, 64, 8, 6
    P = B * npp + 3
    q = _rand((B, H, hd), jnp.float32)
    kp = np.asarray(_rand((P, ps, K, hd), jnp.float32))
    vp = np.asarray(_rand((P, ps, K, hd), jnp.float32))
    bt = np.asarray(_random_tables(B, npp, P))
    lens = np.asarray(RNG.integers(1, npp * ps, size=(B,)), np.int64)

    kp2, vp2 = kp.copy(), vp.copy()
    referenced = set(bt.reshape(-1).tolist())
    for p in range(P):
        if p not in referenced:  # poison unreferenced pages
            kp2[p] = 99.0
            vp2[p] = -99.0
    for b in range(B):  # poison the tail past each request's length
        for j in range(npp):
            lo = max(0, int(lens[b]) - j * ps)
            if lo < ps:
                kp2[bt[b, j], lo:] = 77.0
                vp2[bt[b, j], lo:] = -77.0

    args = (jnp.asarray(bt, jnp.int32), jnp.asarray(lens, jnp.int32))
    o1 = paged_decode_attention(q, jnp.asarray(kp), jnp.asarray(vp), *args,
                                interpret=True)
    o2 = paged_decode_attention(q, jnp.asarray(kp2), jnp.asarray(vp2), *args,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)


def test_ops_dispatch_paged_matches_ref():
    """The ops-layer entry point (ref impl on CPU) equals the oracle."""
    B, H, K, hd, ps, npp = 2, 4, 2, 64, 16, 4
    P = B * npp + 1
    q = _rand((B, H, hd), jnp.float32)
    kp = _rand((P, ps, K, hd), jnp.float32)
    vp = _rand((P, ps, K, hd), jnp.float32)
    bt = _random_tables(B, npp, P)
    lens = jnp.asarray([5, 37], jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lens)
    ref = R.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# fused chunked prefill
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "H,K,hd,ps,past,C",
    [
        (8, 2, 64, 16, 0, 16),    # first chunk, page-aligned
        (4, 4, 64, 8, 8, 8),      # aligned continuation
        (4, 2, 64, 8, 12, 7),     # non-aligned past AND tail
        (4, 1, 128, 32, 5, 3),    # MQA, chunk inside one page
        (6, 2, 64, 8, 17, 23),    # odd everything, multi-page chunk
    ],
)
def test_paged_prefill_matches_oracles(H, K, hd, ps, past, C, dtype):
    ctx = past + C
    npp = -(-ctx // ps) + 2          # slack pages past the context
    P = npp + 4
    q = _rand((C, H, hd), dtype)
    kp = _rand((P, ps, K, hd), dtype)
    vp = _rand((P, ps, K, hd), dtype)
    bt = _random_tables(1, npp, P)[0]
    out = paged_prefill_attention(q, kp, vp, bt, past, interpret=True)
    ref = R.paged_prefill_attention_ref(q, kp, vp, bt, past)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    # oracle self-consistency: the paged ref IS the dense path (gather
    # + causal attention_ref with a query offset) — exactly what the
    # pre-fused prefill computed, so fp32 equality here certifies the
    # fused kernel against the historical dense implementation
    n_ctx = -(-ctx // ps)
    dense = R.attention_ref(
        q[None],
        R.gather_pages(kp, bt[None, :n_ctx]).reshape(1, -1, K, hd),
        R.gather_pages(vp, bt[None, :n_ctx]).reshape(1, -1, K, hd),
        causal=True, q_offset=past, kv_len=jnp.array([ctx], jnp.int32),
    )[0]
    np.testing.assert_allclose(
        np.asarray(ref, np.float32), np.asarray(dense, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("quantized", [False, True])
def test_paged_prefill_ignores_garbage(quantized):
    """Pages outside the block table and slots >= ctx must not leak —
    including under int8, where a garbage *scale* could amplify them."""
    H, K, hd, ps, past, C = 4, 2, 64, 8, 12, 7
    ctx = past + C
    npp = -(-ctx // ps)
    P = npp + 5
    q = _rand((C, H, hd), jnp.float32)
    kp = np.asarray(_rand((P, ps, K, hd), jnp.float32))
    vp = np.asarray(_rand((P, ps, K, hd), jnp.float32))
    bt = np.asarray(_random_tables(1, npp, P)[0])

    kp2, vp2 = kp.copy(), vp.copy()
    for p in range(P):
        if p not in set(bt.tolist()):
            kp2[p] = 99.0
            vp2[p] = -99.0
    tail = ctx - (npp - 1) * ps      # live slots in the last ctx page
    if tail < ps:
        kp2[bt[-1], tail:] = 77.0
        vp2[bt[-1], tail:] = -77.0

    def run(kparr, vparr):
        kj, vj = jnp.asarray(kparr), jnp.asarray(vparr)
        if quantized:
            kq, ks = R.quantize_kv_ref(kj)
            vq, vs = R.quantize_kv_ref(vj)
            return paged_prefill_attention(
                q, kq, vq, jnp.asarray(bt, jnp.int32), past,
                interpret=True, k_scales=ks, v_scales=vs,
            )
        return paged_prefill_attention(
            q, kj, vj, jnp.asarray(bt, jnp.int32), past, interpret=True
        )

    np.testing.assert_allclose(
        np.asarray(run(kp, vp)), np.asarray(run(kp2, vp2)), atol=1e-6
    )


# ---------------------------------------------------------------------------
# int8 pages with per-page scales
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,H,K,hd,ps,npp",
    [
        (2, 8, 2, 64, 16, 8),
        (3, 4, 1, 128, 32, 4),
        (4, 6, 2, 64, 8, 5),
    ],
)
def test_paged_decode_quantized_matches_oracle(B, H, K, hd, ps, npp):
    P = B * npp + 1
    q = _rand((B, H, hd), jnp.float32)
    kq, ks = R.quantize_kv_ref(_rand((P, ps, K, hd), jnp.float32))
    vq, vs = R.quantize_kv_ref(_rand((P, ps, K, hd), jnp.float32))
    bt = _random_tables(B, npp, P)
    lens = jnp.asarray(RNG.integers(1, npp * ps + 1, size=(B,)), jnp.int32)
    out = paged_decode_attention(
        q, kq, vq, bt, lens, interpret=True, k_scales=ks, v_scales=vs
    )
    ref = R.paged_decode_attention_ref(
        q, kq, vq, bt, lens, k_scales=ks, v_scales=vs
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=Q_TOL, rtol=Q_TOL
    )
    # and the ref equals dense attention over explicitly dequantized pools
    dense = R.decode_attention_ref(
        q,
        R.gather_pages(R.dequantize_pages_ref(kq, ks), bt),
        R.gather_pages(R.dequantize_pages_ref(vq, vs), bt),
        lens,
    )
    np.testing.assert_allclose(
        np.asarray(ref), np.asarray(dense), atol=Q_TOL, rtol=Q_TOL
    )


@pytest.mark.parametrize("past,C", [(0, 16), (12, 7), (17, 23)])
def test_paged_prefill_quantized_matches_oracle(past, C):
    H, K, hd, ps = 4, 2, 64, 8
    ctx = past + C
    npp = -(-ctx // ps) + 1
    P = npp + 3
    q = _rand((C, H, hd), jnp.float32)
    kq, ks = R.quantize_kv_ref(_rand((P, ps, K, hd), jnp.float32))
    vq, vs = R.quantize_kv_ref(_rand((P, ps, K, hd), jnp.float32))
    bt = _random_tables(1, npp, P)[0]
    out = paged_prefill_attention(
        q, kq, vq, bt, past, interpret=True, k_scales=ks, v_scales=vs
    )
    ref = R.paged_prefill_attention_ref(
        q, kq, vq, bt, past, k_scales=ks, v_scales=vs
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=Q_TOL, rtol=Q_TOL
    )


def test_quantize_roundtrip_error_bound():
    """|dequant(quant(x)) - x| <= scale/2 elementwise, scales positive."""
    x = _rand((7, 8, 3, 32), jnp.float32)
    q, s = R.quantize_kv_ref(x)
    assert q.dtype == jnp.int8
    assert s.dtype == jnp.float32
    assert bool(jnp.all(s > 0))
    err = jnp.abs(R.dequantize_pages_ref(q, s) - x)
    assert bool(jnp.all(err <= s[..., None] * 0.5 + 1e-7))
    # zero vectors quantize to exact zeros with the floor scale
    q0, s0 = R.quantize_kv_ref(jnp.zeros((2, 4, 1, 8), jnp.float32))
    assert bool(jnp.all(q0 == 0)) and bool(jnp.all(s0 > 0))


@settings(max_examples=50, deadline=None)
@given(
    vals=st.lists(
        st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                  width=32),
        min_size=8, max_size=8,
    )
)
def test_quantize_error_bound_property(vals):
    """Hypothesis: the per-vector scale bounds round-trip error at any
    magnitude (amax/127-scaled, so error <= scale/2 + float eps)."""
    x = jnp.asarray(np.array(vals, np.float32).reshape(1, 1, 1, 8))
    q, s = R.quantize_kv_ref(x)
    err = np.asarray(jnp.abs(R.dequantize_pages_ref(q, s) - x))
    bound = float(s.reshape(())) * 0.5 * (1 + 1e-5) + 1e-7
    assert err.max() <= bound
