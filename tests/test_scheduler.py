"""Algorithm 1 invariants + DAG runtime semantics (property-based)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import LLMSched, ProfileStore, make_baselines
from repro.core.calibration import LatencyProfile
from repro.core.dag import TaskState
from repro.core.scheduler import ClusterView
from repro.sim import generate_traces, generate_workload, get_generators


@pytest.fixture(scope="module")
def store():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    return ProfileStore().fit(apps, generate_traces("mixed", 200, seed=7))


def _view():
    return ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)])


@given(seed=st.integers(0, 1000), eps=st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_decision_covers_each_pending_task_once(seed, eps):
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 60, seed=3))
    wl = generate_workload("mixed", 8, seed=seed)
    jobs = [gj.job for gj in wl]
    sched = LLMSched(store, epsilon=eps, sampling_ratio=0.4, seed=seed)
    dec = sched.schedule(jobs, _view())
    all_tasks = dec.regular + dec.llm
    # no duplicates
    assert len({id(t) for t in all_tasks}) == len(all_tasks)
    # exactly the ready pending tasks
    expected = set()
    for j in jobs:
        for s in j.ready_stages():
            expected.update(id(t) for t in s.pending_tasks())
    assert {id(t) for t in all_tasks} == expected
    # list typing is respected
    assert all(t.is_llm for t in dec.llm)
    assert all(not t.is_llm for t in dec.regular)


def test_non_overlapping_grouping_properties(store):
    wl = generate_workload("mixed", 20, seed=5)
    bounds = []
    for gj in wl:
        p = store.get(gj.job.app.name)
        lo, hi = p.job_bounds(gj.job)
        assert lo <= hi + 1e-9
        bounds.append((lo, hi, gj.job))
    groups = LLMSched.non_overlapping_sets(bounds)
    # partition: every job in exactly one group
    flat = [j.job_id for g in groups for j in g]
    assert sorted(flat) == sorted(j.job_id for _, _, j in bounds)
    # groups ordered by lower bound and truly disjoint between groups
    by_job = {j.job_id: (lo, hi) for lo, hi, j in bounds}
    for g1, g2 in zip(groups, groups[1:]):
        hi1 = max(by_job[j.job_id][1] for j in g1)
        lo2 = min(by_job[j.job_id][0] for j in g2)
        assert lo2 > hi1


def test_epsilon_zero_is_pure_srtf_order(store):
    wl = generate_workload("mixed", 10, seed=9)
    jobs = [gj.job for gj in wl]
    sched = LLMSched(store, epsilon=0.0, seed=0)
    dec = sched.schedule(jobs, _view())
    # job order in the decision must be sorted by est remaining duration
    order = []
    for t in dec.llm + dec.regular:
        if t.job_id not in order:
            order.append(t.job_id)
    ests = {j.job_id: sched.est_rd(j, _view()) for j in jobs}
    # first job in the preference list is (one of) the shortest
    first = next(iter(order))
    assert ests[first] <= min(ests.values()) + 1e-6


def test_sampling_ratio_defers_tasks(store):
    wl = generate_workload("predefined", 6, seed=2)
    jobs = [gj.job for gj in wl]
    sched = LLMSched(store, epsilon=1.0, sampling_ratio=0.34, seed=1)
    dec = sched.schedule(jobs, _view())
    assert dec.llm or dec.regular  # exploration still schedules everything


def test_calibration_changes_estimates(store):
    wl = generate_workload("predefined", 4, seed=4)
    job = wl[0].job
    lat = LatencyProfile(np.arange(1, 9), 0.02 * (0.8 + 0.2 * np.arange(1, 9)))
    sched = LLMSched(store, epsilon=0.0)
    v1 = ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)],
                     latency_profile=lat)
    v2 = ClusterView(now=0.0, free_regular=4, llm_loads=[(7, 8)],
                     latency_profile=lat)
    e1 = sched.est_rd(job, v1)
    e2 = sched.est_rd(job, v2)
    assert e2 > e1  # higher batch -> slower tokens -> longer estimate


def test_observability_no_oracle_leak(store):
    """Unrevealed chain iterations must not leak into estimates."""
    wl = generate_workload("chain", 40, seed=8)
    short, long_ = None, None
    for gj in wl:
        if gj.job.app.name != "code_gen":
            continue
        iters = sum(
            1 for n, s in gj.job.stages.items()
            if n.startswith("code_gen_") and s.will_execute
        )
        if iters == 1 and short is None:
            short = gj.job
        if iters >= 4 and long_ is None:
            long_ = gj.job
    if short is None or long_ is None:
        pytest.skip("seed produced no contrast pair")
    p = store.get("code_gen")
    e_short = p.est_remaining(short, 0.0)
    e_long = p.est_remaining(long_, 0.0)
    # with no evidence the two jobs are indistinguishable
    assert abs(e_short - e_long) < 1e-6


def test_baselines_complete_decisions(store):
    wl = generate_workload("mixed", 10, seed=12)
    jobs = [gj.job for gj in wl]
    for name, sched in make_baselines(store).items():
        dec = sched.schedule(jobs, _view())
        tasks = dec.regular + dec.llm
        assert len({id(t) for t in tasks}) == len(tasks), name
        if name != "decima":  # decima picks one stage at a time (by design)
            expected = sum(
                len(s.pending_tasks()) for j in jobs for s in j.ready_stages()
            )
            assert len(tasks) == expected, name
