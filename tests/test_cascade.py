"""Cost-aware cascade routing over heterogeneous pools.

Covers the cascade tentpole end to end:

- **model-zoo tier table**: every config-name spelling (arch id,
  published name, smoke name) resolves to one tier; unknown models
  resolve to ``None`` so callers gate the cost signal off;
- **deterministic gate**: pure in its arguments, strictness-validated,
  inert at strictness 0;
- **closed-form cascade walk** (``cascade_cost``): escalation counting,
  top-tier terminal rejection, and the hypothesis property that total
  cost is monotone in gate strictness (the shared-draw construction);
- **differential inertness**: an always-pass gate produces the exact
  run an ungated simulator produces — same decisions, same JCTs, zero
  escalations;
- **forced escalation**: a strictness-1.0 gate on a tier ladder
  escalates every out-of-depth stage, reproducibly, with every retry
  charged to ``cost_by_job``;
- **cost-aware routing is live**: pricing the fleet changes LLMSched's
  placement stream (the ``w_model`` term fires) while the cost-blind
  ablation (``w_model=0``) matches the unpriced stream;
- **testbed parity**: a heterogeneous paged fleet escalates through
  the real engines, honouring ``Task.tier_floor`` at dispatch.
"""

import hashlib

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FCFS,
    DeterministicGate,
    LLMSched,
    ProfileStore,
    cascade_cost,
    fleet_ranks,
    stage_difficulty,
)
from repro.models.zoo import MODEL_TIERS, cost_per_token, resolve_tier, tier_spec
from repro.sim import TIER_POOLS, generate_traces, generate_workload, get_generators, tier_pool
from repro.sim.simulator import ClusterSim


# ---------------------------------------------------------------------------
# model-zoo tier table
# ---------------------------------------------------------------------------
def test_every_arch_spelling_resolves_to_one_tier():
    from repro.configs import ARCH_IDS, get_config, get_smoke_config

    for arch in ARCH_IDS:
        assert resolve_tier(arch) == arch
        assert resolve_tier(get_config(arch).name) == arch
        assert resolve_tier(get_smoke_config(arch).name) == arch
    assert resolve_tier("not-a-model") is None
    assert tier_spec("not-a-model") is None
    assert cost_per_token("not-a-model") is None


def test_tier_quality_monotone_in_price_within_ladder():
    """The fig10 ladder must actually be a ladder: quality and price
    both strictly increase up the cascade."""
    specs = [tier_spec(n) for n in TIER_POOLS["ladder3"]]
    costs = [s.usd_per_mtok for s in specs]
    quals = [s.quality for s in specs]
    assert costs == sorted(costs) and len(set(costs)) == 3
    assert quals == sorted(quals) and len(set(quals)) == 3


def test_tier_pool_helper_cycles():
    assert tier_pool("cheap3") == TIER_POOLS["cheap3"]
    assert tier_pool("ladder3", 5) == (
        "stablelm_1_6b", "internlm2_20b", "kimi_k2_1t_a32b",
        "stablelm_1_6b", "internlm2_20b",
    )
    with pytest.raises(KeyError):
        tier_pool("nonexistent")


def test_fleet_ranks_are_dense_over_cost_classes():
    assert fleet_ranks([3.0, 1.0, 3.0, 2.0]) == [2, 0, 2, 1]
    assert fleet_ranks([5.0, 5.0]) == [0, 0]


# ---------------------------------------------------------------------------
# deterministic gate
# ---------------------------------------------------------------------------
def test_gate_validates_strictness():
    with pytest.raises(ValueError):
        DeterministicGate(strictness=1.5, seed=0)
    with pytest.raises(ValueError):
        DeterministicGate(strictness=-0.1, seed=0)


def test_gate_is_pure_and_inert_at_zero():
    g = DeterministicGate(strictness=0.7, seed=3)
    args = ("WebSearch", "search", 0, 1, 0.45)
    assert g.passes(*args) == g.passes(*args)          # pure
    g0 = DeterministicGate(strictness=0.0, seed=0)
    for q in (0.0, 0.45, 0.96):
        assert g0.passes("WebSearch", "search", 0, 0, q)   # inert
    # in-depth outputs always pass regardless of strictness
    g1 = DeterministicGate(strictness=1.0, seed=0)
    d = stage_difficulty("WebSearch", "search")
    assert g1.passes("WebSearch", "search", 0, 0, d + 1e-9)
    assert not g1.passes("WebSearch", "search", 0, 0, d - 1e-9)


# ---------------------------------------------------------------------------
# closed-form cascade walk
# ---------------------------------------------------------------------------
_LADDER = [(0.1, 0.45), (0.35, 0.62), (2.4, 0.96)]


def test_cascade_cost_walks_up_and_counts():
    # a stage every tier clears: one attempt, no escalation
    cost, esc, ok = cascade_cost(
        "a", "b", 0, 100, [(0.1, 1.0), (0.35, 1.0)],
        DeterministicGate(strictness=1.0, seed=0),
    )
    assert (cost, esc, ok) == (pytest.approx(10.0), 0, True)
    # a stage no tier clears at strictness 1: pays every tier, rejected
    hard = [(c, 0.0) for c, _ in _LADDER]
    cost, esc, ok = cascade_cost(
        "a", "b", 0, 100, hard, DeterministicGate(strictness=1.0, seed=0)
    )
    assert cost == pytest.approx(100 * sum(c for c, _ in _LADDER))
    assert esc == len(_LADDER) - 1 and not ok
    # start_rank skips the lower tiers entirely
    cost, esc, ok = cascade_cost(
        "a", "b", 0, 100, hard, DeterministicGate(strictness=1.0, seed=0),
        start_rank=2,
    )
    assert cost == pytest.approx(240.0) and esc == 0 and not ok


@settings(max_examples=60, deadline=None)
@given(
    app=st.sampled_from(["WebSearch", "DocMerging", "CodeGeneration"]),
    stage=st.sampled_from(["search", "merge", "plan", "verify"]),
    index=st.integers(0, 3),
    tokens=st.integers(1, 500),
    seed=st.integers(0, 10),
    lo=st.floats(0.0, 1.0),
    hi=st.floats(0.0, 1.0),
)
def test_cascade_total_cost_monotone_in_strictness(
    app, stage, index, tokens, seed, lo, hi
):
    """The shared per-attempt draw makes the set of rejections grow
    with strictness, so a stricter gate can only visit a superset of
    the tiers — total cost is monotone in strictness."""
    if lo > hi:
        lo, hi = hi, lo
    c_lo, _, _ = cascade_cost(
        app, stage, index, tokens, _LADDER,
        DeterministicGate(strictness=lo, seed=seed),
    )
    c_hi, _, _ = cascade_cost(
        app, stage, index, tokens, _LADDER,
        DeterministicGate(strictness=hi, seed=seed),
    )
    assert c_hi >= c_lo


# ---------------------------------------------------------------------------
# simulator integration
# ---------------------------------------------------------------------------
def _sched():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 120, seed=7))
    return LLMSched(store, epsilon=0.2, seed=0)


def _run(wl, sched, **kw):
    sim = ClusterSim(sched, n_regular=4, n_llm=3, max_batch=8, seed=0, **kw)
    return sim.run(wl)


def _decision_stream(wl, sched, **kw):
    jid = {gj.job.job_id: i for i, gj in enumerate(wl)}
    log = []
    orig = sched.schedule

    def rec(jobs, view):
        dec = orig(jobs, view)
        log.append((
            tuple((jid[t.job_id], t.stage_name, t.index) for t in dec.llm),
            tuple(sorted(
                (jid[j], s, i, e) for (j, s, i), e in dec.placement.items()
            )),
        ))
        return dec

    sched.schedule = rec
    res = _run(wl, sched, **kw)
    return hashlib.sha256(repr(log).encode()).hexdigest(), res


TIERS = TIER_POOLS["ladder3"]


def test_always_pass_gate_is_differentially_inert():
    """strictness=0 accepts everything: the gated run must equal the
    ungated run on the same priced fleet — decision stream, JCTs, and
    cost all identical, with zero escalations."""
    wl1 = generate_workload("mixed", 14, arrival_rate=1.2, seed=3)
    sig1, r1 = _decision_stream(wl1, _sched(), model_tiers=TIERS)
    wl2 = generate_workload("mixed", 14, arrival_rate=1.2, seed=3)
    sig2, r2 = _decision_stream(
        wl2, _sched(), model_tiers=TIERS,
        gate=DeterministicGate(strictness=0.0, seed=0), cascade=True,
    )
    assert sig1 == sig2
    assert sorted(r1.jct_by_job.values()) == sorted(r2.jct_by_job.values())
    assert sorted(r1.cost_by_job.values()) == sorted(r2.cost_by_job.values())
    assert r2.escalations == 0
    assert all(r2.quality_by_job.values())   # everything accepted


def test_forced_escalation_is_deterministic_and_charged():
    """strictness=1.0 rejects every out-of-depth output: escalations
    must occur, every retry must be charged, and two fresh runs must
    agree exactly (the gate consumes no shared RNG stream)."""
    runs = []
    for _ in range(2):
        wl = generate_workload("mixed", 14, arrival_rate=1.2, seed=3)
        sig, res = _decision_stream(
            wl, _sched(), model_tiers=TIERS,
            gate=DeterministicGate(strictness=1.0, seed=0), cascade=True,
        )
        runs.append((sig, res))
    (sig_a, res_a), (sig_b, res_b) = runs
    assert sig_a == sig_b
    assert res_a.escalations == res_b.escalations > 0
    assert sorted(res_a.jct_by_job.values()) == sorted(res_b.jct_by_job.values())
    # escalated retries are real spend: the forced run costs strictly
    # more than the inert-gate run on the same workload
    wl = generate_workload("mixed", 14, arrival_rate=1.2, seed=3)
    base = _run(wl, _sched(), model_tiers=TIERS)
    assert res_a.total_cost > base.total_cost
    # every job finished despite the churn
    assert len(res_a.jct_by_job) == 14


def test_escalated_tasks_respect_tier_floor():
    """After a cascade retry, no task may run below its floor: with a
    strictness-1.0 gate, any stage too hard for the cheap tier must
    end on a replica whose quality its last gate verdict reflects."""
    wl = generate_workload("mixed", 10, arrival_rate=1.2, seed=5)
    res = _run(
        wl, _sched(), model_tiers=TIERS,
        gate=DeterministicGate(strictness=1.0, seed=0), cascade=True,
    )
    top_q = max(tier_spec(n).quality for n in TIERS)
    for gj in wl:
        for stage in gj.job.stages.values():
            for t in stage.tasks:
                if not t.is_llm:
                    continue
                d = stage_difficulty(gj.job.app.name, stage.name)
                if d > top_q:
                    # too hard for the whole fleet: must have climbed
                    # to the top and been rejected there
                    assert t.tier_floor == max(fleet_ranks(
                        [tier_spec(n).usd_per_mtok for n in TIERS]
                    ))
                    assert not res.quality_by_job[t.job_id]


# ---------------------------------------------------------------------------
# testbed parity
# ---------------------------------------------------------------------------
def test_testbed_heterogeneous_fleet_escalates_through_real_engines():
    """The testbed mirrors the simulator's cascade semantics: a paged
    two-tier fleet under a strictness-1.0 gate escalates out-of-depth
    stages to the expensive replica, charges every attempt, and every
    escalated task carries a ``tier_floor`` above the cheap tier."""
    from repro.serving import ServeConfig, ServingCluster, build_engines

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("chain", 150, seed=7))
    wl = generate_workload("chain", 5, arrival_rate=2.0, seed=4)
    cfg = ServeConfig(
        engine="paged", replicas=2,
        models=("stablelm_1_6b", "internlm2_20b"),
        cascade=True, max_batch=4, max_len=96,
        n_regular=3, token_scale=30.0, time_scale=30.0,
    )
    cluster = ServingCluster(
        LLMSched(store, epsilon=0.2, seed=0),
        build_engines(None, cfg),
        cfg,
        gate=DeterministicGate(strictness=1.0, seed=0),
    )
    res = cluster.run(wl)
    assert len(res.jcts) == 5            # churn never strands a job
    assert res.escalations > 0
    assert res.total_cost > 0            # every attempt was priced
    floors = [
        t.tier_floor
        for gj in wl
        for stage in gj.job.stages.values()
        for t in stage.tasks
        if t.is_llm
    ]
    # escalated tasks were floored above the cheap tier, and the floor
    # never exceeds the fleet's top rank
    assert any(f > 0 for f in floors)
    assert all(f <= 1 for f in floors)


def test_pricing_the_fleet_changes_llmsched_placement():
    """The w_model term must actually fire on a heterogeneous fleet:
    the priced decision stream differs from the unpriced one, while
    the cost-blind ablation (w_model=0) reproduces the unpriced
    stream's placements whenever latency scales are equalized."""
    wl = generate_workload("mixed", 14, arrival_rate=1.2, seed=3)
    sig_priced, _ = _decision_stream(wl, _sched(), model_tiers=TIERS)
    wl = generate_workload("mixed", 14, arrival_rate=1.2, seed=3)
    blind = _sched()
    blind.w_model = 0.0
    sig_blind, _ = _decision_stream(wl, blind, model_tiers=TIERS)
    assert sig_priced != sig_blind
