"""Per-kernel allclose sweeps vs the ref.py oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssm_scan import ssm_scan

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 3e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention: shape × dtype × causal sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,K,hd,causal",
    [
        (1, 128, 128, 4, 4, 64, True),     # MHA
        (2, 256, 256, 8, 2, 64, True),     # GQA g=4
        (1, 384, 384, 6, 6, 64, False),    # bidirectional (encoder)
        (2, 128, 128, 4, 1, 128, True),    # MQA
        (1, 512, 512, 2, 2, 128, True),    # long-ish
        (1, 96, 96, 4, 2, 64, True),       # non-multiple-of-128 seq
    ],
)
def test_flash_attention_matches_ref(B, Sq, Sk, H, K, hd, causal, dtype):
    q = _rand((B, Sq, H, hd), dtype)
    k = _rand((B, Sk, K, hd), dtype)
    v = _rand((B, Sk, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=64, block_k=64)
    ref = R.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_flash_attention_mla_vdim():
    """MLA: v head dim != qk head dim."""
    q = _rand((1, 128, 4, 192), jnp.float32)
    k = _rand((1, 128, 4, 192), jnp.float32)
    v = _rand((1, 128, 4, 128), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = R.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,K,hd,block_k",
    [
        (2, 512, 8, 2, 64, 128),
        (4, 256, 4, 4, 128, 256),
        (1, 1024, 16, 8, 64, 512),
        (3, 320, 4, 1, 64, 128),   # ragged length vs block
    ],
)
def test_decode_attention_matches_ref(B, S, H, K, hd, block_k, dtype):
    q = _rand((B, H, hd), dtype)
    kc = _rand((B, S, K, hd), dtype)
    vc = _rand((B, S, K, hd), dtype)
    lens = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, kc, vc, lens, interpret=True, block_k=block_k)
    ref = R.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@given(lens=st.lists(st.integers(1, 256), min_size=2, max_size=4))
@settings(max_examples=10, deadline=None)
def test_decode_attention_length_property(lens):
    """Entries beyond `lengths` must not affect the output."""
    B = len(lens)
    S, H, K, hd = 256, 4, 2, 64
    q = _rand((B, H, hd), jnp.float32)
    kc = np.asarray(_rand((B, S, K, hd), jnp.float32))
    vc = np.asarray(_rand((B, S, K, hd), jnp.float32))
    kc2, vc2 = kc.copy(), vc.copy()
    for b, L in enumerate(lens):  # poison the invalid region
        kc2[b, L:] = 99.0
        vc2[b, L:] = -99.0
    lens_a = jnp.asarray(lens, jnp.int32)
    o1 = decode_attention(jnp.asarray(kc) * 0 + q, jnp.asarray(kc),
                          jnp.asarray(vc), lens_a, interpret=True, block_k=64) \
        if False else decode_attention(q, jnp.asarray(kc), jnp.asarray(vc),
                                       lens_a, interpret=True, block_k=64)
    o2 = decode_attention(q, jnp.asarray(kc2), jnp.asarray(vc2), lens_a,
                          interpret=True, block_k=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64, 256), (2, 128), (1, 7, 384)])
def test_rmsnorm_matches_ref(shape, dtype):
    x = _rand(shape, dtype)
    g = _rand(shape[-1:], dtype)
    out = rmsnorm(x, g, interpret=True, block_rows=32)
    ref = R.rmsnorm_ref(x, g)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "B,T,D,N,chunk,d_block",
    [(2, 256, 128, 16, 64, 64), (1, 128, 256, 8, 128, 128), (2, 64, 64, 16, 32, 64)],
)
def test_ssm_scan_matches_ref(B, T, D, N, chunk, d_block):
    x = _rand((B, T, D), jnp.float32) * 0.5
    dt = jnp.abs(_rand((B, T, D), jnp.float32)) * 0.1
    A = -(jnp.abs(_rand((D, N), jnp.float32)) + 0.1)
    Bm = _rand((B, T, N), jnp.float32) * 0.3
    Cm = _rand((B, T, N), jnp.float32) * 0.3
    Dk = _rand((D,), jnp.float32)
    y, h = ssm_scan(x, dt, A, Bm, Cm, Dk, chunk=chunk, d_block=d_block,
                    interpret=True)
    yr, hr = R.ssm_scan_ref(x, dt, A, Bm, Cm, Dk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=2e-4)


def test_ssm_scan_carries_state():
    """Scanning two halves with carried state == scanning the whole."""
    B, T, D, N = 1, 128, 64, 8
    x = _rand((B, T, D), jnp.float32) * 0.5
    dt = jnp.abs(_rand((B, T, D), jnp.float32)) * 0.1
    A = -(jnp.abs(_rand((D, N), jnp.float32)) + 0.1)
    Bm = _rand((B, T, N), jnp.float32) * 0.3
    Cm = _rand((B, T, N), jnp.float32) * 0.3
    Dk = _rand((D,), jnp.float32)
    y_full, h_full = R.ssm_scan_ref(x, dt, A, Bm, Cm, Dk)
    h = None
    ys = []
    for lo, hi in ((0, 64), (64, 128)):
        y, h = ssm_scan(x[:, lo:hi], dt[:, lo:hi], A, Bm[:, lo:hi],
                        Cm[:, lo:hi], Dk, h0=h, chunk=32, d_block=64,
                        interpret=True)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, axis=1)), np.asarray(y_full), atol=2e-4
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full), atol=2e-4)
