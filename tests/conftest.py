"""Test-suite bootstrap.

If the real `hypothesis` package is installed (CI / dev environments via
``pip install -e .[test]``) it is used untouched.  In hermetic
environments without it, a minimal deterministic fallback implementing the
same API surface (``given``/``settings``/``strategies``) is installed into
``sys.modules`` so the tier-1 suite still collects and runs.
"""

import importlib.util
import os
import sys


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401  (real package available)
        return
    except ImportError:
        pass
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = mod.strategies


_install_hypothesis_fallback()
