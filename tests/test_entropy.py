"""Property tests for the entropy/MI uncertainty quantification (§IV-C)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.bayesnet import BayesNet
from repro.core.entropy import (
    binary_entropy,
    conditional_mutual_information,
    dynamic_stage_entropy,
    entropy,
)


@given(st.lists(st.floats(1e-6, 1.0), min_size=1, max_size=16))
@settings(max_examples=100, deadline=None)
def test_entropy_nonneg_and_bounded(ps):
    p = np.array(ps) / sum(ps)
    h = entropy(p)
    assert 0.0 <= h <= np.log2(len(p)) + 1e-9


def test_entropy_uniform_max():
    assert abs(entropy(np.ones(8) / 8) - 3.0) < 1e-9
    assert entropy(np.array([1.0, 0.0, 0.0])) == 0.0


@given(st.floats(0.0, 1.0))
@settings(max_examples=50, deadline=None)
def test_binary_entropy_symmetry(p):
    assert abs(binary_entropy(p) - binary_entropy(1 - p)) < 1e-9


def test_dynamic_stage_entropy_eq4():
    # deterministic plan (all probs 0/1) has zero structural entropy
    assert dynamic_stage_entropy({"x": 1.0, "y": 0.0}, {("x", "y"): 0.0}) == 0.0
    # maximal uncertainty: every candidate/edge is a fair coin
    h = dynamic_stage_entropy({"x": 0.5, "y": 0.5}, {("x", "y"): 0.5})
    assert abs(h - 3.0) < 1e-9


def _bn(n=3000, corr=0.9, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, n)
    b = np.where(rng.random(n) < corr, a, rng.integers(0, 2, n))
    c = np.where(rng.random(n) < corr, a, rng.integers(0, 2, n))
    return BayesNet().fit(
        np.stack([a, b, c], 1), names=["a", "b", "c"], cards=[2, 2, 2],
        template_edges=[("a", "b"), ("a", "c")],
    )


def test_mi_nonnegative_and_informative():
    bn = _bn()
    mi = conditional_mutual_information(bn, ["b", "c"], "a")
    assert mi > 0.1
    # conditioning on a leaves nothing to learn from it
    mi0 = conditional_mutual_information(bn, ["b"], "a", evidence={"a": 1})
    assert mi0 == 0.0


def test_mi_decreases_with_weaker_correlation():
    strong = conditional_mutual_information(_bn(corr=0.95), ["b", "c"], "a")
    weak = conditional_mutual_information(_bn(corr=0.6), ["b", "c"], "a")
    assert strong > weak
