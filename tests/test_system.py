"""End-to-end behaviour tests for the full LLMSched system."""

import numpy as np
import pytest

from repro.core import LLMSched, ProfileStore, make_baselines
from repro.sim import generate_traces, generate_workload, get_generators, simulate
from repro.sim.simulator import configure_cluster


@pytest.fixture(scope="module")
def setup():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 300, seed=7))
    return apps, store


def test_full_pipeline_all_schedulers(setup):
    """Every scheduler (ours + 6 baselines) completes a mixed workload."""
    _, store = setup
    scheds = dict(make_baselines(store))
    scheds["llmsched"] = LLMSched(store, epsilon=0.2, seed=0)
    for name, s in scheds.items():
        r = simulate(s, mix="mixed", n_jobs=15, seed=3, n_regular=4,
                     n_llm=2, max_batch=8)
        assert len(r.jcts) == 15, name


def test_ablation_components_exist(setup):
    """The two paper ablations are expressible (Fig. 10)."""
    _, store = setup
    full = LLMSched(store, epsilon=0.2, seed=0)
    wo_bn = LLMSched(store, epsilon=0.2, use_bn=False, seed=0)
    wo_unc = LLMSched(store, epsilon=0.0, seed=0)
    for s in (full, wo_bn, wo_unc):
        r = simulate(s, mix="planning", n_jobs=12, seed=3, n_regular=6,
                     n_llm=1, max_batch=8)
        assert len(r.jcts) == 12


def test_dynamic_stage_lifecycle(setup):
    """Planning jobs: dynamic stages expand only after the plan finishes,
    and expanded stages complete."""
    _, store = setup
    wl = generate_workload("planning", 8, seed=5)
    ta = [gj for gj in wl if gj.job.app.name == "task_auto"]
    if not ta:
        pytest.skip("no task_auto in sample")
    job = ta[0].job
    dyn = job.stages["auto_tools"]
    assert not dyn.revealed
    r = simulate(LLMSched(store, seed=0), mix="planning", n_jobs=8, seed=5,
                 n_regular=6, n_llm=1, max_batch=8)
    assert len(r.jcts) == 8


def test_fault_tolerance_executor_failures():
    """Executor failures requeue running tasks; every job still finishes
    (checkpoint/restart at the scheduling layer)."""
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("mixed", 150, seed=7))
    r = simulate(LLMSched(store, seed=0), mix="mixed", n_jobs=20, seed=3,
                 n_regular=4, n_llm=2, max_batch=8,
                 failure_rate=0.03, straggler_factor=0.0)
    assert len(r.jcts) == 20
    assert r.preemptions > 0


def test_straggler_speculative_reissue():
    """Straggling regular tasks get a speculative duplicate; first wins."""
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("planning", 150, seed=7))
    r = simulate(LLMSched(store, seed=1), mix="planning", n_jobs=25, seed=5,
                 n_regular=8, n_llm=1, max_batch=8, straggler_factor=3.0)
    assert len(r.jcts) == 25
    assert r.reissues > 0
