"""Differential validity sweep over all seven baseline schedulers.

Every baseline (FCFS, Fair, SJF, SRTF, Argus, Carbyne, Decima) runs the
same seeded mixed workload — jobs from all six generators — through the
event simulator behind a validating proxy that checks, at every
scheduling round:

- decisions only contain PENDING tasks (nothing dispatched twice);
- every decided task belongs to a stage that is *ready* (parents done,
  stage revealed — schedulers must not see hidden chain iterations or
  unexpanded dynamic stages);
- task states only ever move PENDING → RUNNING → DONE.

And at the end of the run: every job completed, every will-execute stage
fully DONE.
"""

import numpy as np
import pytest

from repro.core import ProfileStore, make_baselines
from repro.core.baselines import SRTF
from repro.core.dag import TaskState
from repro.core.scheduler import Scheduler
from repro.sim import generate_traces, generate_workload, get_generators
from repro.sim.simulator import ClusterSim

_ORDER = {TaskState.PENDING: 0, TaskState.RUNNING: 1, TaskState.DONE: 2}


class ValidatingScheduler(Scheduler):
    """Proxy asserting scheduling invariants around an inner scheduler."""

    def __init__(self, inner: Scheduler) -> None:
        self.inner = inner
        self.name = f"validated-{inner.name}"
        self._last_state = {}
        self.rounds = 0

    def schedule(self, jobs, view):
        # state-transition audit: PENDING -> RUNNING -> DONE, never back
        for job in jobs:
            for st in job.stages.values():
                for t in st.tasks:
                    prev = self._last_state.get(id(t))
                    cur = _ORDER[t.state]
                    if prev is not None:
                        assert cur >= prev, (
                            f"task {t.stage_name}[{t.index}] of job {t.job_id} "
                            f"went backwards: {prev} -> {cur}"
                        )
                    self._last_state[id(t)] = cur
        dec = self.inner.schedule(jobs, view)
        self.rounds += 1
        ready = {
            (j.job_id, s.name) for j in jobs for s in j.ready_stages()
        }
        for t in list(dec.regular) + list(dec.llm):
            assert t.state is TaskState.PENDING, (
                f"{self.inner.name} re-dispatched a {t.state.name} task "
                f"{t.stage_name}[{t.index}] of job {t.job_id}"
            )
            assert (t.job_id, t.stage_name) in ready, (
                f"{self.inner.name} scheduled non-ready stage "
                f"{t.stage_name} of job {t.job_id}"
            )
        return dec

    def observe_completion(self, job, now):
        self.inner.observe_completion(job, now)


@pytest.fixture(scope="module")
def store():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    return ProfileStore().fit(apps, generate_traces("mixed", 120, seed=3))


def _all_seven(store):
    scheds = dict(make_baselines(store))      # fcfs fair sjf argus carbyne decima
    scheds["srtf"] = SRTF(store)
    assert len(scheds) == 7
    return scheds


@pytest.mark.parametrize(
    "name", ["fcfs", "fair", "sjf", "srtf", "argus", "carbyne", "decima"]
)
def test_baseline_validity_mixed_workload(store, name):
    sched = ValidatingScheduler(_all_seven(store)[name])
    n_jobs = 12
    wl = generate_workload("mixed", n_jobs, arrival_rate=1.2, seed=17)
    sim = ClusterSim(sched, n_regular=3, n_llm=1, max_batch=4, seed=0)
    res = sim.run(wl)

    # every job eventually completes
    assert len(res.jcts) == n_jobs
    assert sched.rounds > 0
    for gj in wl:
        assert gj.job.done()
        for st in gj.job.stages.values():
            if st.will_execute and st.tasks:
                assert all(t.state is TaskState.DONE for t in st.tasks), (
                    f"{name}: stage {st.name} of job {gj.job.job_id} "
                    "left unfinished tasks"
                )
        assert gj.job.job_id in res.jct_by_job


def test_validator_catches_double_dispatch(store):
    """The validator itself must be able to fail: a scheduler replaying
    running tasks is rejected (meta-test for the differential harness)."""

    class DoubleDispatch(Scheduler):
        name = "evil"

        def schedule(self, jobs, view):
            from repro.core.scheduler import Decision

            dec = Decision()
            for job in jobs:
                for st in job.stages.values():
                    for t in st.tasks:
                        if t.state is TaskState.RUNNING:
                            (dec.llm if t.is_llm else dec.regular).append(t)
                    for t in st.pending_tasks():
                        if st.revealed and st.will_execute:
                            (dec.llm if t.is_llm else dec.regular).append(t)
            return dec

    wl = generate_workload("predefined", 4, arrival_rate=2.0, seed=5)
    sim = ClusterSim(ValidatingScheduler(DoubleDispatch()), n_regular=2,
                     n_llm=1, max_batch=4, seed=0)
    with pytest.raises(AssertionError):
        sim.run(wl)
