"""Minimal, dependency-free stand-in for the `hypothesis` API surface used
by this test suite (``given`` / ``settings`` / ``strategies``).

Installed by ``conftest.py`` into ``sys.modules['hypothesis']`` ONLY when
the real package is unavailable (e.g. a hermetic container without network
access), so the tier-1 suite still collects and runs everywhere.  CI and
dev environments that ``pip install -e .[test]`` get real Hypothesis with
shrinking, the example database, and far richer strategies — this fallback
trades all of that for determinism and zero dependencies:

- examples are drawn from a fixed-seed PRNG (fully reproducible runs);
- each strategy emits its boundary values first (lo/hi endpoints,
  min-size lists) before random interior draws;
- a failing example is re-raised unchanged with the drawn values attached
  to the exception message.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
from typing import Any, Callable, List

IS_FALLBACK = True

__version__ = "0.0-fallback"


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------
class SearchStrategy:
    def draw(self, rnd: random.Random) -> Any:
        raise NotImplementedError

    def boundary(self) -> List[Any]:
        """Deterministic edge-case examples, tried before random draws."""
        return []

    def example_at(self, rnd: random.Random, i: int) -> Any:
        b = self.boundary()
        if i < len(b):
            return b[i]
        return self.draw(rnd)


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int) -> None:
        self.lo, self.hi = int(min_value), int(max_value)

    def draw(self, rnd: random.Random) -> int:
        return rnd.randint(self.lo, self.hi)

    def boundary(self) -> List[int]:
        out = [self.lo, self.hi]
        if self.hi - self.lo > 1:
            out.append((self.lo + self.hi) // 2)
        return out


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float) -> None:
        self.lo, self.hi = float(min_value), float(max_value)

    def draw(self, rnd: random.Random) -> float:
        return rnd.uniform(self.lo, self.hi)

    def boundary(self) -> List[float]:
        return [self.lo, self.hi, 0.5 * (self.lo + self.hi)]


class _Lists(SearchStrategy):
    def __init__(
        self,
        elements: SearchStrategy,
        min_size: int = 0,
        max_size: int = 10,
    ) -> None:
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = int(max_size) if max_size is not None else min_size + 10

    def draw(self, rnd: random.Random) -> list:
        n = rnd.randint(self.min_size, self.max_size)
        return [self.elements.draw(rnd) for _ in range(n)]

    def boundary(self) -> List[list]:
        eb = self.elements.boundary() or [self.elements.draw(random.Random(0))]
        out = [[eb[0]] * max(self.min_size, 1)]
        if len(eb) > 1:
            out.append([eb[1]] * max(self.min_size, 1))
        return out


class _SampledFrom(SearchStrategy):
    def __init__(self, elements) -> None:
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty collection")

    def draw(self, rnd: random.Random) -> Any:
        return rnd.choice(self.elements)

    def boundary(self) -> List[Any]:
        return self.elements[:2]


def integers(min_value: int = 0, max_value: int = 100) -> SearchStrategy:
    return _Integers(min_value, max_value)


def sampled_from(elements) -> SearchStrategy:
    return _SampledFrom(elements)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> SearchStrategy:
    return _Floats(min_value, max_value)


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> SearchStrategy:
    return _Lists(elements, min_size=min_size, max_size=max_size)


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.lists = lists
strategies.sampled_from = sampled_from
strategies.SearchStrategy = SearchStrategy


# ---------------------------------------------------------------------------
# settings / given
# ---------------------------------------------------------------------------
_DEFAULT_MAX_EXAMPLES = 100


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn: Callable) -> Callable:
        fn._hf_settings = {"max_examples": int(max_examples)}
        return fn

    return deco


def given(*pos_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Property decorator: runs the test once per generated example."""

    def deco(fn: Callable) -> Callable:
        sig = inspect.signature(fn)
        params = list(sig.parameters)
        kw_names = list(kw_strategies)
        pos_names = [p for p in params if p not in kw_names][: len(pos_strategies)]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = (
                getattr(wrapper, "_hf_settings", None)
                or getattr(fn, "_hf_settings", None)
                or {}
            )
            n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(0xC0FFEE)
            for i in range(n):
                drawn = {
                    name: s.example_at(rnd, i)
                    for name, s in zip(pos_names, pos_strategies)
                }
                drawn.update(
                    {name: s.example_at(rnd, i) for name, s in kw_strategies.items()}
                )
                try:
                    fn(*args, **{**kwargs, **drawn})
                except Exception as e:  # attach the falsifying example
                    e.args = (
                        f"{e}\nFalsifying example (fallback hypothesis, "
                        f"example #{i}): {drawn!r}",
                    )
                    raise

        # hide the generated params from pytest's fixture resolution
        remaining = [
            p
            for name, p in sig.parameters.items()
            if name not in kw_names and name not in pos_names
        ]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return deco
