"""End-to-end simulator behaviour (paper §V reproduction at test scale)."""

import numpy as np
import pytest

from repro.core import FCFS, LLMSched, ProfileStore, make_baselines
from repro.core.baselines import SRTF
from repro.sim import generate_traces, generate_workload, get_generators, simulate
from repro.sim.simulator import ClusterSim, configure_cluster


@pytest.fixture(scope="module")
def store():
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    return ProfileStore().fit(apps, generate_traces("mixed", 300, seed=7))


def test_all_jobs_complete(store):
    for mix in ("mixed", "predefined", "chain", "planning"):
        r = simulate(LLMSched(store, seed=0), mix=mix, n_jobs=25, seed=3,
                     n_regular=4, n_llm=2, max_batch=8)
        assert len(r.jcts) == 25, mix
        assert all(j > 0 for j in r.jcts)
        assert r.makespan > 0


def test_batching_stretches_tokens(store):
    """More concurrent requests -> slower per-token decode (sim physics)."""
    wl1 = generate_workload("predefined", 6, arrival_rate=100.0, seed=5)
    r_small = ClusterSim(FCFS(), n_regular=4, n_llm=1, max_batch=1).run(wl1)
    wl2 = generate_workload("predefined", 6, arrival_rate=100.0, seed=5)
    r_big = ClusterSim(FCFS(), n_regular=4, n_llm=1, max_batch=8).run(wl2)
    # batch=8 shares the executor: higher throughput => shorter makespan
    assert r_big.makespan < r_small.makespan


def test_llmsched_beats_fcfs_on_planning(store):
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    pstore = ProfileStore().fit(apps, generate_traces("planning", 300, seed=7))
    cfg = configure_cluster("planning", arrival_rate=0.9, target_load=0.9)
    ours, fcfs = [], []
    for seed in (3, 11):
        ours.append(simulate(LLMSched(pstore, epsilon=0.2, seed=0),
                             mix="planning", n_jobs=60, seed=seed, **cfg).avg_jct)
        fcfs.append(simulate(FCFS(), mix="planning", n_jobs=60, seed=seed,
                             **cfg).avg_jct)
    assert np.mean(ours) < np.mean(fcfs)


def test_scheduler_overhead_reasonable(store):
    r = simulate(LLMSched(store, seed=0), mix="mixed", n_jobs=30, seed=3,
                 n_regular=4, n_llm=2, max_batch=8)
    # paper Table I: LLMSched < 3 ms average overhead
    assert r.avg_overhead_ms < 30.0  # generous CI margin over the paper's 3 ms


def test_deterministic_given_seed(store):
    a = simulate(LLMSched(store, seed=0), mix="mixed", n_jobs=15, seed=3)
    b = simulate(LLMSched(store, seed=0), mix="mixed", n_jobs=15, seed=3)
    assert a.avg_jct == b.avg_jct


def test_configure_cluster_targets_load():
    cfg = configure_cluster("mixed", arrival_rate=0.9, target_load=0.9)
    assert cfg["n_llm"] >= 1 and cfg["n_regular"] >= 2
    assert cfg["max_batch"] in (2, 4, 8, 16)


def test_workload_characteristics_match_paper():
    """Fig. 1 reproduction: duration + structural uncertainty exist."""
    wl = generate_workload("mixed", 300, seed=1)
    by_app = {}
    for gj in wl:
        tot = sum(v for k, v in gj.durations.items() if "." not in k)
        by_app.setdefault(gj.job.app.name, []).append(tot)
    # wide duration ranges (Obs. 1)
    ss = np.array(by_app["seq_sort"])
    assert ss.max() / ss.min() > 5
    # chain length varies (Obs. 2)
    lens = set()
    for gj in wl:
        if gj.job.app.name == "code_gen":
            lens.add(sum(1 for n, s in gj.job.stages.items()
                         if n.startswith("code_gen_") and s.will_execute))
    assert len(lens) >= 3
    # dynamic stage counts vary (Obs. 2, task automation 1-8)
    counts = set()
    for gj in wl:
        if gj.job.app.name == "task_auto":
            counts.add(len(gj.job.dynamic_realization["auto_tools"][0]))
    assert len(counts) >= 3
