"""Analysis-layer tests: lint framework, kvsan, scheduler invariants.

Covers the static-analysis tentpole end to end:

- **framework units** — suppression parsing (bare and ``[rule]`` forms),
  rule filtering, parse-error reporting, the rule catalog;
- **per-rule lint units** — wall-clock (``time.time``, ``datetime.now``,
  ``from time import time``), unordered-set iteration, mutable default
  arguments, and the seed-discipline rules absorbed from the retired
  ``tools/check_seeds.py`` (keyword/positional/splat seeds, unseeded
  RNG constructors, module-level global-RNG use);
- **repo sweep** — ``run_paths`` over ``src/ benchmarks/ examples/
  tests/`` returns zero findings (the repo stays suppress-free);
- **kvsan units** — double free vs refcount underflow wording,
  use-after-free and CoW-bypass writes, block-table aliasing, ticket
  refcount drift, EDF-drain violations, shadow/allocator crosscheck;
- **mutation tests** — a deliberately injected double free, a
  CoW-bypassing engine write, and a stale-plan retraction bug are each
  caught loudly by the corresponding checker;
- **observability guarantees** — a clean ``sanitize=True`` run is
  byte-identical to ``sanitize=False``, and ``check_invariants=True``
  never perturbs the LLMSched decision stream.
"""

import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.analysis import (
    Source,
    all_checkers,
    check_source,
    iter_py_files,
    rule_catalog,
    run_paths,
)
from repro.analysis.invariants import InvariantViolation, check_decision
from repro.analysis.kvsan import KVSanError, KVSanitizer
from repro.configs import get_smoke_config
from repro.core import LLMSched, ProfileStore
from repro.core.dag import Task, TaskState
from repro.core.scheduler import ClusterView, Decision
from repro.kernels.paged_attention import check_block_table_bounds
from repro.models import init_params
from repro.serving import PageAllocator, PagedLLMEngine, Request
from repro.sim import generate_traces, get_generators
from repro.sim.simulator import ClusterSim
from repro.sim.workloads import generate_tiered_workload

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("stablelm_1_6b")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(cfg, jax.random.key(0))[0]


_STORE = None


def _store():
    global _STORE
    if _STORE is None:
        gens = get_generators()
        apps = [g.template for g in gens.values()]
        _STORE = ProfileStore().fit(apps, generate_traces("mixed", 120, seed=7))
    return _STORE


def _sched(**kw):
    kw.setdefault("epsilon", 0.0)
    kw.setdefault("seed", 0)
    return LLMSched(_store(), **kw)


def _lint(code, rules=None):
    """Lint a dedented snippet with every registered checker."""
    src = Source("<snippet>", textwrap.dedent(code))
    return check_source(src, all_checkers(rules), rules)


def _rules(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# framework: suppressions, filtering, catalog, file walking
# ---------------------------------------------------------------------------
def test_rule_catalog_is_complete():
    assert set(rule_catalog()) == {
        "wall-clock", "unordered-set", "mutable-default",
        "seed-missing", "unseeded-rng", "global-rng",
        "hot-loop-import",
    }


def test_suppression_parsing_forms():
    src = Source("<s>", (
        "x = 1  # analysis: ignore\n"
        "y = 2  # analysis: ignore[wall-clock]\n"
        "z = 3  # analysis: ignore[wall-clock, seed-missing]\n"
        "w = 4\n"
    ))
    assert src.suppressed(1, "anything")
    assert src.suppressed(2, "wall-clock")
    assert not src.suppressed(2, "seed-missing")
    assert src.suppressed(3, "seed-missing")
    assert not src.suppressed(4, "wall-clock")


def test_suppression_silences_only_named_rule():
    flagged = _lint("import time\nt = time.time()\n")
    assert _rules(flagged) == ["wall-clock"]
    assert _lint(
        "import time\nt = time.time()  # analysis: ignore[wall-clock]\n"
    ) == []
    assert _lint("import time\nt = time.time()  # analysis: ignore\n") == []
    # suppressing a different rule leaves the finding live
    still = _lint(
        "import time\nt = time.time()  # analysis: ignore[seed-missing]\n"
    )
    assert _rules(still) == ["wall-clock"]


def test_rule_filtering():
    code = (
        "import time\n"
        "t = time.time()\n"
        "def f(xs=[]):\n"
        "    return xs\n"
    )
    assert _rules(_lint(code)) == ["wall-clock", "mutable-default"]
    assert _rules(_lint(code, rules={"mutable-default"})) == ["mutable-default"]


def test_parse_error_reported_not_raised(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n    pass\n")
    findings = run_paths([str(bad)])
    assert len(findings) == 1
    assert findings[0].rule == "parse-error"
    assert str(bad) in str(findings[0])


def test_iter_py_files_sorted_and_filtered(tmp_path):
    (tmp_path / "b.py").write_text("x = 1\n")
    (tmp_path / "a.py").write_text("y = 2\n")
    (tmp_path / "notes.txt").write_text("not python\n")
    sub = tmp_path / "sub"
    sub.mkdir()
    (sub / "c.py").write_text("z = 3\n")
    names = [p.name for p in iter_py_files([str(tmp_path)])]
    assert names == ["a.py", "b.py", "c.py"]


# ---------------------------------------------------------------------------
# determinism rules
# ---------------------------------------------------------------------------
def test_wall_clock_rule_positives():
    assert _rules(_lint("import time\nt = time.time()\n")) == ["wall-clock"]
    assert _rules(_lint(
        "from time import time\nt = time()\n"
    )) == ["wall-clock"]
    assert _rules(_lint(
        "import datetime\nd = datetime.datetime.now()\n"
    )) == ["wall-clock"]
    assert _rules(_lint(
        "from datetime import date\nd = date.today()\n"
    )) == ["wall-clock"]


def test_wall_clock_rule_negatives():
    assert _lint("import time\nt = time.perf_counter()\n") == []
    assert _lint("import time\nt = time.monotonic()\n") == []
    # a foreign object with a .time() method is not the time module
    assert _lint("t = sim.time()\n") == []


def test_unordered_set_rule():
    assert _rules(_lint(
        "for x in {1, 2, 3}:\n    print(x)\n"
    )) == ["unordered-set"]
    assert _rules(_lint("xs = list(set(ys))\n")) == ["unordered-set"]
    assert _rules(_lint("xs = [v for v in frozenset(ys)]\n")) == [
        "unordered-set"
    ]
    # sorted(...) fixes the order: no findings
    assert _lint("for x in sorted({1, 2, 3}):\n    print(x)\n") == []
    assert _lint("xs = sorted(set(ys))\n") == []
    # iterating an ordered container is fine
    assert _lint("for x in [1, 2]:\n    print(x)\n") == []


def test_mutable_default_rule():
    found = _lint(
        "def f(a, xs=[], *, m={}):\n"
        "    return a, xs, m\n"
    )
    assert _rules(found) == ["mutable-default", "mutable-default"]
    assert _lint("def g(a=None, b=(), c=0):\n    return a, b, c\n") == []


# ---------------------------------------------------------------------------
# seed-discipline rules (parity with the retired tools/check_seeds.py)
# ---------------------------------------------------------------------------
def test_seed_missing_rule():
    assert _rules(_lint(
        'wl = generate_workload("mixed", 5)\n'
    )) == ["seed-missing"]
    assert _lint('wl = generate_workload("mixed", 5, seed=3)\n') == []
    # positional seed (4th argument) counts
    assert _lint('wl = generate_workload("mixed", 5, 1.0, 7)\n') == []
    # a **splat may carry the seed: give it the benefit of the doubt
    assert _lint('wl = generate_workload("mixed", 5, **kw)\n') == []
    assert _rules(_lint(
        'wl = generate_tiered_workload("mixed", 5, arrival_rate=1.0)\n'
    )) == ["seed-missing"]
    assert _rules(_lint('tr = generate_traces("chain", 50)\n')) == [
        "seed-missing"
    ]
    # quality gates are an RNG stream too: their per-attempt draws are
    # keyed by the gate seed, so call sites must pin it explicitly
    assert _rules(_lint(
        "g = DeterministicGate(strictness=0.7)\n"
    )) == ["seed-missing"]
    assert _lint("g = DeterministicGate(strictness=0.7, seed=3)\n") == []
    assert _lint("g = DeterministicGate(0.7, 3)\n") == []


def test_unseeded_rng_rule():
    assert _rules(_lint(
        "import numpy as np\nrng = np.random.default_rng()\n"
    )) == ["unseeded-rng"]
    assert _rules(_lint(
        "from numpy.random import default_rng\nrng = default_rng()\n"
    )) == ["unseeded-rng"]
    assert _rules(_lint(
        "import jax\nk = jax.random.key()\n"
    )) == ["unseeded-rng"]
    assert _lint("import numpy as np\nrng = np.random.default_rng(0)\n") == []
    assert _lint("import jax\nk = jax.random.key(0)\n") == []
    # a bare `key()` is ambiguous (dict.key? operator?) — never flagged
    assert _lint("k = key()\n") == []


def test_global_rng_rule():
    assert _rules(_lint(
        "import numpy as np\nx = np.random.rand(3)\n"
    )) == ["global-rng"]
    assert _rules(_lint(
        "import random\nx = random.random()\n"
    )) == ["global-rng"]
    # instance-level draws off a constructed Generator are the fix
    assert _lint("x = self.rng.random()\n") == []
    assert _lint("x = rng.choice(xs)\n") == []


# ---------------------------------------------------------------------------
# perf rules
# ---------------------------------------------------------------------------
def test_hot_loop_import_rule_positives():
    assert _rules(_lint(
        "for x in xs:\n"
        "    import json\n"
        "    json.dumps(x)\n"
    )) == ["hot-loop-import"]
    assert _rules(_lint(
        "while run:\n"
        "    from os import path\n"
    )) == ["hot-loop-import"]
    # the shipped bug shape: an import anywhere inside step()
    assert _rules(_lint(
        "class Engine:\n"
        "    def step(self):\n"
        "        if self.sanitize:\n"
        "            from .kernels import check\n"
        "            check()\n"
    )) == ["hot-loop-import"]
    # nested helper defined inside step() is still per-iteration code
    assert _rules(_lint(
        "def _step():\n"
        "    def inner():\n"
        "        import json\n"
        "        return json\n"
        "    return inner()\n"
    )) == ["hot-loop-import"]


def test_hot_loop_import_rule_negatives():
    # module level and function-top lazy imports are intentional idiom
    assert _lint("import json\n") == []
    assert _lint(
        "def build():\n"
        "    import jax\n"
        "    return jax\n"
    ) == []
    # a def inside a loop resets loop context: its body runs when called
    assert _lint(
        "for x in xs:\n"
        "    def cb():\n"
        "        import json\n"
        "        return json\n"
    ) == []


def test_paged_engine_step_has_no_imports():
    """Regression: ``PagedLLMEngine.step`` once imported the bounds
    checker per iteration; the hot path must stay import-free."""
    import ast

    tree = ast.parse(
        (REPO / "src/repro/serving/paged_engine.py").read_text()
    )
    step = next(
        node
        for node in ast.walk(tree)
        if isinstance(node, ast.FunctionDef) and node.name == "step"
    )
    imports = [
        node for node in ast.walk(step)
        if isinstance(node, (ast.Import, ast.ImportFrom))
    ]
    assert imports == [], (
        f"imports inside PagedLLMEngine.step at lines "
        f"{[i.lineno for i in imports]}"
    )


def test_repo_sweep_is_clean():
    """The whole repository lints clean with zero suppressions — the
    same sweep the CI ``analysis`` job runs."""
    paths = [str(REPO / d) for d in ("src", "benchmarks", "examples", "tests")]
    findings = run_paths(paths)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lazy_runtime_exports():
    """`import repro.analysis` exposes the runtime layers lazily."""
    import repro.analysis as analysis

    assert analysis.KVSanitizer is KVSanitizer
    assert analysis.InvariantViolation is InvariantViolation
    with pytest.raises(AttributeError):
        analysis.does_not_exist


# ---------------------------------------------------------------------------
# kernel block-table bounds check
# ---------------------------------------------------------------------------
def test_block_table_bounds_accepts_valid_tables():
    bt = np.array([[1, 2, 3], [4, 0, 0], [0, 0, 0]])
    lens = np.array([17, 3, 0])        # covers 3, 1, 0 pages at ps=8
    check_block_table_bounds(bt, lens, num_pages=8, page_size=8)


def test_block_table_bounds_rejects_out_of_pool():
    bt = np.array([[9, 2]])
    with pytest.raises(ValueError, match="out of pool bounds"):
        check_block_table_bounds(bt, np.array([4]), num_pages=8, page_size=8)
    with pytest.raises(ValueError, match="out of pool bounds"):
        check_block_table_bounds(
            np.array([[-1, 2]]), np.array([4]), num_pages=8, page_size=8
        )


def test_block_table_bounds_rejects_trash_in_covered_range():
    # 9 valid tokens at ps=8: the decode write lands in page index 1,
    # which holds the trash page — a live token was never given KV
    bt = np.array([[5, 0]])
    with pytest.raises(ValueError):
        check_block_table_bounds(bt, np.array([9]), num_pages=8, page_size=8)


def test_block_table_bounds_rejects_short_table():
    bt = np.array([[1, 2]])
    with pytest.raises(ValueError, match="needs"):
        check_block_table_bounds(bt, np.array([25]), num_pages=8, page_size=8)


# ---------------------------------------------------------------------------
# kvsan unit behaviour (shadow state, no engine)
# ---------------------------------------------------------------------------
def test_kvsan_alloc_of_live_page():
    s = KVSanitizer(8, 4)
    s.on_alloc([1, 2], owner=0)
    with pytest.raises(KVSanError, match="non-free page"):
        s.on_alloc([2], owner=1)


def test_kvsan_double_free_vs_underflow_wording():
    s = KVSanitizer(8, 4)
    s.on_alloc([3], owner=0)
    s.on_free([3])
    with pytest.raises(KVSanError, match="double free"):
        s.on_free([3])
    s.on_alloc([4], owner=1)
    # duplicate ids within one call: more frees than live refs
    with pytest.raises(KVSanError, match="refcount underflow"):
        s.on_free([4, 4])
    # the failed call mutated nothing: the single live ref frees cleanly
    s.on_free([4])


def test_kvsan_write_checks():
    s = KVSanitizer(8, 4)
    s.on_alloc([1, 2], owner=0)
    s.note_table(0, [1, 2])
    s.note_write(0, 1)                 # exclusive, registered: fine
    assert s.writes_checked == 1
    with pytest.raises(KVSanError, match="use-after-free"):
        s.note_write(0, 5)             # page 5 is still free
    s.on_alloc([3], owner=9)
    with pytest.raises(KVSanError, match="stray write"):
        s.note_write(0, 3)             # live but not in row 0's table
    s.on_fork([2], owner=1)
    with pytest.raises(KVSanError, match="copy-on-write bypass"):
        s.note_write(0, 2)             # shared page: must CoW first
    s.on_free([2])
    s.on_mark_indexed([1])
    with pytest.raises(KVSanError, match="copy-on-write bypass"):
        s.note_write(0, 1)             # index-registered page


def test_kvsan_block_table_aliasing():
    s = KVSanitizer(8, 4)
    s.on_alloc([1], owner=0)
    s.note_table(0, [1])
    with pytest.raises(KVSanError, match="aliasing"):
        s.note_table(1, [1])           # exclusive page in two tables


def test_kvsan_ticket_drift():
    s = KVSanitizer(8, 4)
    s.on_alloc([1, 2], owner=0)
    s.on_fork([2], owner=1)
    s.validate_ticket([1, 2], [1, 2])  # matches shadow: fine
    s.validate_ticket([1, 2], None)    # legacy ticket without refcounts
    with pytest.raises(KVSanError, match="refcount drift"):
        s.validate_ticket([1, 2], [1, 1])
    with pytest.raises(KVSanError, match="refcounts"):
        s.validate_ticket([1, 2], [1])


def test_kvsan_edf_drain():
    s = KVSanitizer(8, 4)
    s.check_edf_drain(1.0, [2.0, 3.0])
    s.check_edf_drain(float("inf"), [])
    with pytest.raises(KVSanError, match="EDF violation"):
        s.check_edf_drain(5.0, [2.0])


def test_kvsan_crosscheck_divergence():
    a = PageAllocator(8, 4, sanitize=True)
    pages = a.alloc(2, owner=1)
    assert pages is not None
    a.free(pages)
    a.check_no_leaks()                 # shadow and books agree
    pages = a.alloc(1, owner=2)
    a._ref[pages[0]] += 1              # mutate behind the sanitizer's back
    with pytest.raises(KVSanError, match="divergence"):
        a.check_no_leaks()


# ---------------------------------------------------------------------------
# mutation tests: injected bugs must be caught loudly
# ---------------------------------------------------------------------------
def test_mutation_double_free_caught():
    """An injected double free dies at the free site with a journal."""
    a = PageAllocator(16, 8, sanitize=True)
    pages = a.alloc(3, owner=1)
    a.free(pages[:1])
    with pytest.raises(KVSanError, match="double free") as ei:
        a.free(pages)                  # pages[0] already returned
    assert "recent page ops" in str(ei.value)


def _run_trace(cfg, params, prompts, *, sanitize, prefix=True, n_new=6,
               max_steps=600):
    """Drive one paged engine over a staggered arrival trace."""
    eng = PagedLLMEngine(cfg, max_seqs=8, max_len=64, page_size=8,
                         params=params, prefill_chunk=8,
                         prefix_cache=prefix, sanitize=sanitize)
    out = {}
    pending = [
        Request(rid=i, prompt=list(p), max_new_tokens=n_new,
                on_finish=lambda r: out.__setitem__(r.rid, list(r.out_tokens)))
        for i, p in enumerate(prompts)
    ]
    steps = 0
    while (pending or eng.batch_size or eng.waiting) and steps < max_steps:
        if pending and steps % 2 == 0 and eng.can_admit() \
                and eng.admit(pending[0]):
            pending.pop(0)
        eng.step()
        steps += 1
    assert not pending and not eng.batch_size and not eng.waiting
    eng.allocator.check_no_leaks()
    return out, eng


def test_mutation_cow_bypass_caught(cfg, params, monkeypatch):
    """Disabling copy-on-write makes a shared-prefix trace write into a
    shared/index-registered page — the sanitizer must name it."""
    monkeypatch.setattr(
        PagedLLMEngine, "_ensure_exclusive", lambda self, row, pi: True
    )
    shared = [3 + (7 * i) % 29 for i in range(32)]   # 4 pages at ps=8
    prompts = (
        [shared + [50 + i] for i in range(4)]
        + [shared, shared]                           # aligned duplicates
    )
    with pytest.raises(KVSanError, match="copy-on-write bypass"):
        _run_trace(cfg, params, prompts, sanitize=True)


def test_mutation_stale_plan_caught(monkeypatch):
    """A scheduler that stops retracting stale SLO plans decides from
    outdated evidence — check_invariants must refuse the decision."""
    wl = generate_tiered_workload("mixed", 6, arrival_rate=0.9, seed=8)
    jobs = [gj.job for gj in wl]
    sched = _sched(check_invariants=True)
    view = ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)])
    sched.schedule(jobs, view)         # clean round builds the plans
    assert sched._slo_plans

    orig = LLMSched._slo_plan_for

    def never_retract(self, job, v, lo, hi):
        plan = self._slo_plans.get(job.job_id)
        return plan if plan is not None else orig(self, job, v, lo, hi)

    monkeypatch.setattr(LLMSched, "_slo_plan_for", never_retract)
    for j in jobs:
        j.bump_evidence()              # new evidence: plans are now stale
    with pytest.raises(InvariantViolation, match="plan-pinned"):
        sched.schedule(jobs, view)


# ---------------------------------------------------------------------------
# observation-only guarantees
# ---------------------------------------------------------------------------
def test_sanitized_run_is_byte_identical(cfg, params):
    """A clean shared-prefix trace produces identical tokens with the
    sanitizer on and off, and the sanitizer really checked writes."""
    shared = [3 + (7 * i) % 29 for i in range(32)]
    prompts = (
        [shared + [50 + i] for i in range(4)]
        + [shared, shared]
        + [[70, 71, 72]]
    )
    base, _ = _run_trace(cfg, params, prompts, sanitize=False)
    got, eng = _run_trace(cfg, params, prompts, sanitize=True)
    assert got == base
    assert eng.allocator.sanitizer is not None
    assert eng.allocator.sanitizer.writes_checked > 0
    assert eng.prefix_index.hits > 0   # the trace exercised CoW paths


def test_invariant_checking_is_inert():
    """check_invariants=True never perturbs the decision stream on a
    clean tiered-SLO simulation (observation-only)."""
    def run(check):
        wl = generate_tiered_workload("mixed", 12, arrival_rate=1.2, seed=11)
        jid = {gj.job.job_id: i for i, gj in enumerate(wl)}
        sched = _sched(check_invariants=check)
        log = []
        orig = sched.schedule

        def rec(jobs, view):
            dec = orig(jobs, view)
            log.append((
                tuple((jid[t.job_id], t.stage_name, t.index)
                      for t in dec.regular),
                tuple((jid[t.job_id], t.stage_name, t.index)
                      for t in dec.llm),
                tuple(sorted(
                    (jid[j], s, i, e)
                    for (j, s, i), e in dec.placement.items()
                )),
            ))
            return dec

        sched.schedule = rec
        res = ClusterSim(sched, n_regular=4, n_llm=2, max_batch=8,
                         seed=0).run(wl)
        return log, round(res.avg_jct, 9)

    log_off, jct_off = run(False)
    log_on, jct_on = run(True)         # also proves: no false positives
    assert log_on == log_off
    assert jct_on == jct_off


# ---------------------------------------------------------------------------
# invariant units: each predicate fires on a crafted bad decision
# ---------------------------------------------------------------------------
def _view(loads=((0, 8),)):
    return ClusterView(now=0.0, free_regular=4, llm_loads=list(loads))


def test_invariant_no_running_retraction():
    sched = _sched()
    t = Task(job_id=1, stage_name="s", index=0, is_llm=False,
             state=TaskState.RUNNING)
    dec = Decision(regular=[t])
    with pytest.raises(InvariantViolation, match="no-running-retraction"):
        check_decision(sched, [], _view(), dec)


def test_invariant_demoted_unplaced():
    sched = _sched()
    sched._demoted = {7}
    t = Task(job_id=7, stage_name="llm", index=0, is_llm=True)
    dec = Decision(llm=[t])
    dec.place(t, 0)
    with pytest.raises(InvariantViolation, match="demoted-unplaced"):
        check_decision(sched, [], _view(), dec)


def test_invariant_placement_bounds():
    sched = _sched()
    t = Task(job_id=1, stage_name="llm", index=0, is_llm=True)
    dec = Decision(llm=[t])
    dec.place(t, 3)                    # only one replica exists
    with pytest.raises(InvariantViolation, match="placement-bounds"):
        check_decision(sched, [], _view(), dec)
    # overcommit: two placements into one free slot
    t2 = Task(job_id=2, stage_name="llm", index=0, is_llm=True)
    dec = Decision(llm=[t, t2])
    dec.place(t, 0)
    dec.place(t2, 0)
    with pytest.raises(InvariantViolation, match="overcommit"):
        check_decision(sched, [], _view([(7, 8)]), dec)


def test_invariant_edf_urgent_order():
    sched = _sched()
    sched._last_urgent_keys = [(0, 5.0, 10.0, 0.0), (0, 1.0, 3.0, 0.0)]
    with pytest.raises(InvariantViolation, match="edf-urgent-order"):
        check_decision(sched, [], _view(), Decision())


def test_invariant_violations_aggregate():
    """One bad round reports every broken property at once."""
    sched = _sched()
    sched._demoted = {7}
    sched._last_urgent_keys = [(0, 5.0, 10.0, 0.0), (0, 1.0, 3.0, 0.0)]
    t = Task(job_id=7, stage_name="llm", index=0, is_llm=True,
             state=TaskState.RUNNING)
    dec = Decision(llm=[t])
    dec.place(t, 5)
    with pytest.raises(InvariantViolation) as ei:
        check_decision(sched, [], _view(), dec)
    msg = str(ei.value)
    for name in ("no-running-retraction", "demoted-unplaced",
                 "placement-bounds", "edf-urgent-order"):
        assert name in msg
