"""Serving engine + testbed runtime tests."""

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FCFS, LLMSched, ProfileStore
from repro.serving import LLMEngine, Request, ServeConfig, ServingCluster
from repro.sim import generate_traces, generate_workload, get_generators


@pytest.fixture(scope="module")
def engine_cfg():
    return get_smoke_config("stablelm_1_6b")


def test_engine_continuous_batching(engine_cfg):
    eng = LLMEngine(engine_cfg, max_batch=4, max_len=64)
    done = []
    for i in range(4):
        assert eng.admit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=3 + i,
                                 on_finish=lambda r: done.append(r.rid)))
    assert not eng.can_admit()
    steps = 0
    while eng.batch_size and steps < 50:
        eng.step()
        steps += 1
    assert sorted(done) == [0, 1, 2, 3]
    # all tokens produced
    assert steps < 50


def test_engine_admission_midstream(engine_cfg):
    """New requests join between decode steps (iteration-level batching)."""
    eng = LLMEngine(engine_cfg, max_batch=2, max_len=64)
    done = []
    eng.admit(Request(rid=0, prompt=[1], max_new_tokens=6,
                      on_finish=lambda r: done.append(r.rid)))
    eng.step()
    eng.admit(Request(rid=1, prompt=[2], max_new_tokens=2,
                      on_finish=lambda r: done.append(r.rid)))
    steps = 0
    while eng.batch_size and steps < 30:
        eng.step()
        steps += 1
    assert sorted(done) == [0, 1]
    assert done[0] == 1  # the short request finished first


def test_engine_latency_profile(engine_cfg):
    eng = LLMEngine(engine_cfg, max_batch=4, max_len=64)
    for i in range(3):
        eng.admit(Request(rid=i, prompt=[1, 2], max_new_tokens=6))
    while eng.batch_size:
        eng.step()
    prof = eng.latency_profile()
    assert prof is not None
    assert prof.l(1) > 0
    # Eq. 2 calibration is usable
    assert prof.calibrate(10.0, b_r=1, b_t=3) > 0


def test_testbed_cluster_completes_jobs(engine_cfg):
    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces("chain", 150, seed=7))
    wl = generate_workload("chain", 6, arrival_rate=2.0, seed=4)
    cluster = ServingCluster(
        LLMSched(store, epsilon=0.2, seed=0),
        [LLMEngine(engine_cfg, max_batch=4, max_len=96)],
        ServeConfig(n_regular=3, token_scale=30.0, time_scale=30.0),
    )
    res = cluster.run(wl)
    assert len(res.jcts) == 6
    assert res.tokens_generated > 0
    # wall-clock dependent: generous margin for loaded CI runners (the
    # steady-state rounds are single-digit ms; the mean is dominated by
    # the first cold-cache rounds)
    assert res.avg_overhead_ms < 150
