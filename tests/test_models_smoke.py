"""Per-architecture smoke tests (assignment requirement).

Each of the 10 assigned architectures instantiates its REDUCED config and
runs one forward + one train step + prefill/decode on CPU, asserting
output shapes and the absence of NaNs.  A decode-parity test checks that
prefill+decode_step reproduces the full-sequence forward logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.distributed.optimizer import OptConfig
from repro.models import (
    SHAPES,
    decode_step,
    forward,
    init_params,
    lm_loss,
    prefill,
    shape_applicable,
)
from repro.models.zoo import build_train_step, input_specs
from repro.distributed.optimizer import init_opt_state


def _batch(cfg, B=2, S=16):
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab, jnp.int32)
    enc = None
    if cfg.family in ("vlm", "audio"):
        enc = jnp.full((B, cfg.encoder.n_ctx, cfg.d_model), 0.01, jnp.float32)
    return toks, enc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    params, specs = init_params(cfg, jax.random.key(0))
    toks, enc = _batch(cfg)
    logits, _ = forward(params, cfg, toks, enc_input=enc)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_params(cfg, jax.random.key(0))
    opt_cfg = OptConfig(lr=1e-3, state_dtype="float32")
    step = build_train_step(cfg, opt_cfg)
    opt_state = init_opt_state(params, opt_cfg)
    toks, enc = _batch(cfg)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if enc is not None:
        batch["enc_input"] = enc
    new_params, new_state, metrics = jax.jit(step)(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda p, q: float(jnp.abs(p.astype(jnp.float32)
                                       - q.astype(jnp.float32)).sum()),
            params, new_params,
        ),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_no_nan(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_params(cfg, jax.random.key(0))
    toks, enc = _batch(cfg)
    last, cache = prefill(params, cfg, toks, max_len=48, enc_input=enc)
    assert last.shape == (2, cfg.vocab)
    lg, cache = decode_step(params, cfg, cache, jnp.argmax(last, axis=-1))
    assert lg.shape == (2, cfg.vocab)
    assert not np.isnan(np.asarray(lg, np.float32)).any()
    assert int(cache["lengths"][0]) == 17


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "deepseek_v2_lite_16b",
                                  "xlstm_350m", "whisper_tiny"])
def test_decode_parity_with_forward(arch):
    """prefill(t[:n]) + decode steps == forward(t) logits (f32 smoke)."""
    cfg = get_smoke_config(arch).with_(dtype="float32")
    params, _ = init_params(cfg, jax.random.key(1))
    B, S = 1, 12
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab, jnp.int32)
    enc = None
    if cfg.family in ("vlm", "audio"):
        enc = jnp.full((B, cfg.encoder.n_ctx, cfg.d_model), 0.01, jnp.float32)
    full_logits, _ = forward(params, cfg, toks, enc_input=enc)
    n = 8
    last, cache = prefill(params, cfg, toks[:, :n], max_len=32, enc_input=enc)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, n - 1]), atol=2e-3, rtol=2e-3
    )
    # feed the TRUE next tokens and compare logits step by step
    for i in range(n, S):
        lg, cache = decode_step(params, cfg, cache, toks[:, i])
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full_logits[:, i]), atol=2e-3, rtol=2e-3,
            err_msg=f"{arch} step {i}",
        )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_numbers(arch):
    """The FULL configs carry the exact published numbers (no allocation)."""
    cfg = get_config(arch)
    expected = {
        "stablelm_1_6b": (24, 2048, 32, 32, 5632, 100352),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152064),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "llama3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_param_counts_match_published():
    tol = {
        "stablelm_1_6b": (1.6e9, 0.15), "internlm2_20b": (20e9, 0.1),
        "qwen1_5_110b": (111e9, 0.1), "llama3_405b": (405e9, 0.05),
        "llama3_2_vision_90b": (90e9, 0.1),
        "jamba_1_5_large_398b": (398e9, 0.1), "whisper_tiny": (39e6, 2.0),
        "kimi_k2_1t_a32b": (1.04e12, 0.1),
        "deepseek_v2_lite_16b": (15.7e9, 0.1), "xlstm_350m": (350e6, 0.5),
    }
    for arch, (target, rel) in tol.items():
        total, active = get_config(arch).param_count()
        assert abs(total - target) / target <= rel, (arch, total)
        assert active <= total


def test_moe_active_params():
    kimi = get_config("kimi_k2_1t_a32b")
    total, active = kimi.param_count()
    assert active < 0.05 * total  # a32b of 1t
    ds = get_config("deepseek_v2_lite_16b")
    t2, a2 = ds.param_count()
    assert a2 < 0.3 * t2


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                assert shape.name == "long_500k" and not cfg.sub_quadratic
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.kind == "decode":
                assert "cache" in specs
