"""SLO-tiered deadline scheduling: degeneracy, retraction, goodput.

Covers the SLO tentpole end to end:

- **golden-trajectory degeneracy**: the SLO-capable LLMSched on an
  SLO-less workload reproduces the PR 5 decision stream byte-for-byte
  (same hashes the prefix-cache suite pins), and loose-deadline tiered
  workloads leave the stream unchanged too (deadline pressure perturbs
  the JCT-optimal order only when a miss is actually projected);
- **deadline-blind ablation**: ``slo_aware=False`` emits identical
  decisions with and without SLOs on the jobs;
- **retraction invariants**: plans are stable on static evidence
  (repeat calls change nothing and retract nothing), an
  ``evidence_version`` bump retracts exactly the bumped job's plan,
  completed jobs drop their plan state, and decisions never contain
  running tasks;
- **ordering unit behaviour**: tier-ordered urgency boost, best-effort
  never boosted, provably-infeasible demotion behind feasible work
  (counted once per job), demoted jobs left unplaced;
- **goodput property** (hypothesis): under any deadline-blind policy,
  per-job attainment — and therefore goodput — is monotone in deadline
  slack;
- **API consolidation**: unified ``RunMetrics`` aliases, ``ServeConfig``
  validation (legacy kwargs now rejected outright), and
  ``ClusterView.assemble`` gating.
"""

import hashlib
import warnings

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FCFS, LLMSched, ProfileStore, RunMetrics
from repro.core.dag import SLO, SLO_TIERS, TaskState
from repro.core.scheduler import ClusterView
from repro.serving import ServeConfig
from repro.serving import cluster as cluster_mod
from repro.serving.cluster import ServingCluster
from repro.serving.config import build_engines
from repro.sim import generate_traces, generate_workload, get_generators
from repro.sim.simulator import ClusterSim, SimResult
from repro.sim.workloads import assign_slos, generate_tiered_workload

# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------
_STORE = None


def _store():
    global _STORE
    if _STORE is None:
        gens = get_generators()
        apps = [g.template for g in gens.values()]
        _STORE = ProfileStore().fit(apps, generate_traces("mixed", 120, seed=7))
    return _STORE


def _sched(**kw):
    kw.setdefault("epsilon", 0.2)
    kw.setdefault("seed", 0)
    return LLMSched(_store(), **kw)


# ---------------------------------------------------------------------------
# golden-trajectory degeneracy vs PR 5
# ---------------------------------------------------------------------------
# Same capture the prefix-cache suite pins (tests/test_prefix_cache.py):
# SHA-256 of the job-index-normalized LLMSched decision stream on the
# seeded fig7-style trace, plus round count and avg JCT.  The SLO-capable
# scheduler must reproduce these exactly when no job carries an SLO.
_GOLD = {
    "no_kv": ("f0a1535da4df96f382ac82bd79543816d4647d2041c61866eec03a6ea89c2ee2",
              185, 34.531148),
    "kv": ("76ff31e613e53efc6b261452a5a0936094c42b7280ea999d343e3a670e88322a",
           196, 39.830019),
}


def _trajectory(kv, wl, sched, **sim_kw):
    """Run the seeded fig7-style sim, hashing the decision stream."""
    jid = {gj.job.job_id: i for i, gj in enumerate(wl)}
    log = []
    orig = sched.schedule

    def rec(jobs, view):
        dec = orig(jobs, view)
        log.append((
            tuple((jid[t.job_id], t.stage_name, t.index) for t in dec.regular),
            tuple((jid[t.job_id], t.stage_name, t.index) for t in dec.llm),
            tuple(sorted(
                (jid[j], s, i, e) for (j, s, i), e in dec.placement.items()
            )),
        ))
        return dec

    sched.schedule = rec
    sim = ClusterSim(sched, n_regular=4, n_llm=2, max_batch=8,
                     kv_budget_tokens=kv, seed=0, **sim_kw)
    res = sim.run(wl)
    return (hashlib.sha256(repr(log).encode()).hexdigest(), len(log),
            round(res.avg_jct, 6)), res


@pytest.mark.parametrize("tag,kv", [("no_kv", None), ("kv", [3000, 8000])])
def test_sloless_workload_degenerates_to_pr5_golden_trajectory(tag, kv):
    """With no SLO anywhere, the deadline machinery must be inert:
    decisions byte-identical to the PR 5 golden capture."""
    wl = generate_workload("mixed", 20, arrival_rate=1.2, seed=11)
    sig, res = _trajectory(kv, wl, _sched(plan_ahead_s=30.0, slo_aware=True))
    assert sig == _GOLD[tag], (
        f"SLO-capable LLMSched drifted from the PR 5 capture on an "
        f"SLO-less workload ({tag}): {sig} != {_GOLD[tag]}"
    )
    assert res.goodput() is None          # no SLOs -> no goodput
    assert res.retractions == 0


def test_loose_deadlines_preserve_sloless_trajectory():
    """Comfortable slack must not perturb the SRTF/uncertainty order:
    a tiered workload whose deadlines are never at risk produces the
    same decision stream as the SLO-less run."""
    wl = generate_tiered_workload("mixed", 20, arrival_rate=1.2, seed=11,
                                  tightness=0.01)
    assert all(gj.job.slo is not None for gj in wl)
    sig, res = _trajectory(None, wl, _sched(plan_ahead_s=30.0))
    assert sig == _GOLD["no_kv"]
    assert res.goodput() is not None      # SLOs present -> goodput reported


def test_blind_scheduler_ignores_deadlines():
    """``slo_aware=False`` is the deadline-blind ablation: identical
    decisions whether or not jobs carry (tight) SLOs."""
    base = generate_workload("mixed", 20, arrival_rate=1.2, seed=11)
    tiered = generate_tiered_workload("mixed", 20, arrival_rate=1.2,
                                      seed=11, tightness=3.0)
    sig_base, _ = _trajectory(None, base, _sched(slo_aware=False))
    sig_tiered, res = _trajectory(None, tiered, _sched(slo_aware=False))
    assert sig_base == sig_tiered == _GOLD["no_kv"]
    assert res.retractions == 0           # blind mode builds no plans


def test_tiered_generation_does_not_perturb_job_structure():
    """SLO assignment draws from a separate RNG stream: the underlying
    jobs (ids, apps, arrivals, durations) are byte-identical to the
    plain generator's output at the same seed."""
    base = generate_workload("mixed", 15, arrival_rate=1.2, seed=4)
    tiered = generate_tiered_workload("mixed", 15, arrival_rate=1.2, seed=4)
    assert len(base) == len(tiered)
    for b, t in zip(base, tiered):
        # job_id is a process-global counter, so compare structure
        assert b.job.app.name == t.job.app.name
        assert b.job.arrival_time == t.job.arrival_time
        assert b.durations == t.durations
        assert b.job.slo is None and t.job.slo is not None
        assert t.job.slo.tier in SLO_TIERS
        assert t.job.slo.deadline > t.job.arrival_time


# ---------------------------------------------------------------------------
# ordering unit behaviour (_slo_order with crafted bounds)
# ---------------------------------------------------------------------------
def _four_jobs():
    wl = generate_workload("mixed", 4, arrival_rate=0.9, seed=5)
    return [gj.job for gj in wl]


def test_slo_order_boost_demote_and_tier_precedence():
    jobs = _four_jobs()
    a, b, c, d = jobs
    now, view = 0.0, ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)])
    # a: interactive, at risk inside the window        -> boosted first
    # b: batch, at risk inside the window              -> boosted after a
    # c: best_effort, at risk inside the window        -> never boosted
    # d: interactive, provably infeasible (lo > slack) -> demoted last
    a.slo = SLO("interactive", deadline=now + 10.0)
    b.slo = SLO("batch", deadline=now + 10.0)
    c.slo = SLO("best_effort", deadline=now + 10.0)
    d.slo = SLO("interactive", deadline=now + 10.0)
    bounds = {
        a.job_id: (1.0, 100.0),
        b.job_id: (1.0, 100.0),
        c.job_id: (1.0, 100.0),
        d.job_id: (50.0, 100.0),   # optimistic bound already misses
    }
    sched = _sched(epsilon=0.0)
    # feed in an arbitrary (SRTF-stand-in) order with d first
    ordered = sched._slo_order([d, c, b, a], view, bounds)
    assert ordered == [a, b, c, d]
    assert sched.demotions == 1 and d.job_id in sched._demoted
    # repeat on static state: same order, demotion counted once
    assert sched._slo_order([d, c, b, a], view, bounds) == [a, b, c, d]
    assert sched.demotions == 1


def test_slo_order_comfortable_slack_keeps_srtf_position():
    jobs = _four_jobs()
    a, b, c, d = jobs
    view = ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)])
    # deadlines far beyond the plan-ahead window and bounds comfortably
    # inside the slack: nobody is boosted or demoted
    for j in jobs:
        j.slo = SLO("interactive", deadline=1e6)
    bounds = {j.job_id: (1.0, 5.0) for j in jobs}
    sched = _sched(epsilon=0.0, plan_ahead_s=30.0)
    assert sched._slo_order([c, a, d, b], view, bounds) == [c, a, d, b]
    assert sched.demotions == 0


def test_demoted_jobs_are_not_placed():
    """Provably-infeasible jobs reserve no KV: their LLM tasks carry no
    placement hint while feasible jobs' tasks do."""
    wl = generate_tiered_workload("mixed", 8, arrival_rate=1.2, seed=3,
                                  tightness=1e9)   # every deadline hopeless
    jobs = [gj.job for gj in wl]
    sched = _sched(epsilon=0.0)
    view = ClusterView(now=max(j.arrival_time for j in jobs) + 1.0,
                       free_regular=4, llm_loads=[(0, 8), (0, 8)],
                       llm_free_tokens=[4096, 4096])
    dec = sched.schedule(jobs, view)
    assert sched.demotions == len(jobs)
    assert dec.llm                         # still schedulable (no starvation)
    assert all(t.job_id in sched._demoted for t in dec.llm)
    assert dec.placement == {}


# ---------------------------------------------------------------------------
# retraction invariants
# ---------------------------------------------------------------------------
def _static_setup():
    wl = generate_tiered_workload("mixed", 6, arrival_rate=0.9, seed=8,
                                  tightness=1.0)
    jobs = [gj.job for gj in wl]
    sched = _sched(epsilon=0.0)            # no RNG draws between calls
    view = ClusterView(now=0.0, free_regular=4, llm_loads=[(0, 8)])
    return jobs, sched, view


def _dec_sig(dec):
    return (
        tuple((t.job_id, t.stage_name, t.index) for t in dec.regular),
        tuple((t.job_id, t.stage_name, t.index) for t in dec.llm),
        tuple(sorted(dec.placement.items())),
    )


def test_static_evidence_is_stable_and_retracts_nothing():
    jobs, sched, view = _static_setup()
    first = _dec_sig(sched.schedule(jobs, view))
    assert sched.retractions == 0          # first plans are builds, not retractions
    for _ in range(3):
        assert _dec_sig(sched.schedule(jobs, view)) == first
    assert sched.retractions == 0


def test_evidence_bump_retracts_exactly_that_plan():
    jobs, sched, view = _static_setup()
    sched.schedule(jobs, view)
    target = next(j for j in jobs if j.slo is not None)
    old_plan = sched._slo_plans[target.job_id]
    target.bump_evidence()
    sched.schedule(jobs, view)
    assert sched.retractions == 1
    assert sched._slo_plans[target.job_id] is not old_plan
    assert sched._slo_plans[target.job_id].version == target.evidence_version


def test_completion_drops_plan_state():
    jobs, sched, view = _static_setup()
    sched.schedule(jobs, view)
    target = jobs[0]
    assert target.job_id in sched._slo_plans
    sched.observe_completion(target, now=1.0)
    assert target.job_id not in sched._slo_plans
    assert target.job_id not in sched._demoted


def test_running_tasks_are_never_retracted():
    """Decisions only ever contain pending tasks — a dispatched (running)
    task cannot reappear, so retraction can never touch running work."""
    jobs, sched, view = _static_setup()
    dec = sched.schedule(jobs, view)
    victims = (dec.llm or dec.regular)[:1]
    assert victims
    for t in victims:
        t.state = TaskState.RUNNING
        job = next(j for j in jobs if j.job_id == t.job_id)
        job.bump_evidence()                # runtime bumps on dispatch
    dec2 = sched.schedule(jobs, view)
    running = {(t.job_id, t.stage_name, t.index) for t in victims}
    listed = {
        (t.job_id, t.stage_name, t.index) for t in dec2.regular + dec2.llm
    }
    assert not (running & listed)


# ---------------------------------------------------------------------------
# goodput monotonicity (deadline-blind => monotone in slack)
# ---------------------------------------------------------------------------
_FCFS_RUN = None


def _fcfs_run():
    """One seeded FCFS sim; FCFS never reads deadlines, so its finish
    times are a fixed function of the workload."""
    global _FCFS_RUN
    if _FCFS_RUN is None:
        wl = generate_tiered_workload("mixed", 15, arrival_rate=1.2, seed=13,
                                      tightness=1.0)
        sim = ClusterSim(FCFS(), n_regular=4, n_llm=2, max_batch=8, seed=13)
        sim.run(wl)
        _FCFS_RUN = wl
    return _FCFS_RUN


@settings(max_examples=25, deadline=None)
@given(
    lo=st.floats(min_value=0.25, max_value=4.0),
    hi=st.floats(min_value=0.25, max_value=4.0),
)
def test_goodput_monotone_in_slack_for_deadline_blind_policy(lo, hi):
    """Loosening every deadline can only help: for tightness lo <= hi,
    per-job attainment under hi implies attainment under lo, hence
    goodput(lo) >= goodput(hi)."""
    lo, hi = min(lo, hi), max(lo, hi)
    wl = _fcfs_run()

    def attainment(tightness):
        assign_slos(wl, tightness=tightness, seed=13 + 1)
        return {gj.job.job_id: gj.job.met_slo() for gj in wl}

    met_hi, met_lo = attainment(hi), attainment(lo)
    for jid, ok in met_hi.items():
        if ok:
            assert met_lo[jid], (
                f"job {jid} met its deadline at tightness {hi} but not at "
                f"looser tightness {lo}"
            )
    g = [sum(m.values()) / len(m) for m in (met_lo, met_hi)]
    assert g[0] >= g[1]


# ---------------------------------------------------------------------------
# unified RunMetrics
# ---------------------------------------------------------------------------
def test_result_aliases_are_the_unified_schema():
    assert SimResult is RunMetrics
    assert cluster_mod.TestbedResult is RunMetrics


def test_goodput_accounting():
    r = RunMetrics()
    assert r.goodput() is None             # no SLO jobs at all
    r.tier_by_job = {1: "interactive", 2: "interactive", 3: "batch"}
    r.slo_met_by_job = {1: True, 2: False, 3: True}
    assert r.goodput() == pytest.approx(2 / 3)
    assert r.goodput("interactive") == pytest.approx(0.5)
    assert r.goodput("batch") == 1.0
    assert r.goodput("best_effort") is None
    assert r.goodput_by_tier() == {
        "interactive": pytest.approx(0.5), "batch": 1.0
    }


def test_slo_validation_and_attainment():
    with pytest.raises(ValueError):
        SLO("platinum", deadline=1.0)
    wl = generate_workload("mixed", 1, seed=0)
    job = wl[0].job
    assert job.met_slo() is None           # SLO-less
    job.slo = SLO("interactive", deadline=50.0)
    job.finish_time = 40.0
    assert job.met_slo() is True
    assert job.met_slo(time_scale=2.0) is False   # 40 > 50/2


# ---------------------------------------------------------------------------
# ServeConfig + deprecation shim + view assembly
# ---------------------------------------------------------------------------
def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(engine="dense")
    with pytest.raises(ValueError):
        ServeConfig(replicas=0)
    with pytest.raises(ValueError):
        ServeConfig(engine="paged", replicas=2, kv_pages=(13,))
    with pytest.raises(ValueError):
        ServeConfig(shared_prompt_tokens=96, max_len=96)
    cfg = ServeConfig(engine="paged", replicas=2, kv_pages=[13.0, 49])
    assert cfg.kv_pages == (13, 49)        # coerced + frozen


def test_build_engines_rejects_slot_migration_and_prefix_cache():
    with pytest.raises(ValueError):
        build_engines(None, ServeConfig(engine="slot", migrate=True))
    with pytest.raises(ValueError):
        build_engines(None, ServeConfig(engine="slot", prefix_cache=True))


def test_legacy_kwargs_rejected():
    # the one-release deprecation shim is gone: pre-ServeConfig kwargs
    # now fail fast instead of warning
    with pytest.raises(TypeError):
        ServingCluster(FCFS(), engines=[], n_regular=2, token_scale=16.0)
    assert not hasattr(ServeConfig, "from_legacy_kwargs")
    # explicit config passes through untouched, no warning
    cfg = ServeConfig(n_regular=7)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cluster = ServingCluster(FCFS(), engines=[], config=cfg)
    assert cluster.config is cfg


def test_cluster_view_assemble_gates_partial_signals():
    v = ClusterView.assemble(
        now=1.0, free_regular=2, llm_loads=[(0, 4), (1, 4)],
        llm_free_tokens=[128, None],           # one replica can't report
        llm_prefix_hit_tokens=[16, 32],
    )
    assert v.llm_free_tokens is None           # collapses fleet-wide
    assert v.llm_prefix_hit_tokens == [16, 32]
    v2 = ClusterView.assemble(now=0.0, free_regular=0, llm_loads=[])
    assert v2.llm_free_tokens is None and v2.llm_prefix_hit_tokens is None


def test_cluster_view_assemble_rejects_length_mismatch():
    """Regression: a per-replica signal list of the wrong length was
    passed through silently, misaligning every replica's score with a
    neighbour's KV headroom.  Now it fails fast."""
    with pytest.raises(ValueError):
        ClusterView.assemble(now=0.0, free_regular=1, llm_loads=[(0, 4)],
                             llm_free_tokens=[128, 256])
    with pytest.raises(ValueError):
        ClusterView.assemble(now=0.0, free_regular=1,
                             llm_loads=[(0, 4), (1, 4)],
                             llm_model_costs=[1e-7])


def test_cluster_view_assemble_gates_cost_signal():
    """Mixed per-replica cost signals (some replicas unpriced, or a
    non-finite price) must gate the whole cost term off — a partially
    priced fleet cannot be routed by cost."""
    v = ClusterView.assemble(now=0.0, free_regular=0,
                             llm_loads=[(0, 4), (1, 4)],
                             llm_model_costs=[1e-7, None])
    assert v.llm_model_costs is None
    v2 = ClusterView.assemble(now=0.0, free_regular=0,
                              llm_loads=[(0, 4), (1, 4)],
                              llm_model_costs=[1e-7, float("nan")])
    assert v2.llm_model_costs is None
    v3 = ClusterView.assemble(now=0.0, free_regular=0,
                              llm_loads=[(0, 4), (1, 4)],
                              llm_model_costs=[1e-7, 2e-7])
    assert v3.llm_model_costs == [1e-7, 2e-7]


def test_goodput_by_tier_reports_zero_for_unfinished_tier():
    """Regression: a tier whose jobs all went unfinished (no entries in
    ``slo_met_by_job``) was silently omitted from ``goodput_by_tier``,
    so benchmark aggregations mistook "all missed" for "tier absent"."""
    r = RunMetrics()
    r.tier_by_job = {1: "interactive", 2: "batch"}
    r.slo_met_by_job = {1: True}          # the batch job never finished
    assert r.goodput_by_tier() == {"interactive": 1.0, "batch": 0.0}


def test_uniform_tier_pool_preserves_golden_trajectory(monkeypatch):
    """A homogeneous *priced* pool must gate the cost signal off: the
    decision stream matches the unpriced PR 5 golden byte-for-byte
    (latency_scale pinned to 1.0 so tier economics are the only delta),
    while cost accounting still runs."""
    from repro.models import zoo
    monkeypatch.setitem(
        zoo.MODEL_TIERS, "unit_tier", zoo.TierSpec(0.10, 0.99, 1.0)
    )
    wl = generate_workload("mixed", 20, arrival_rate=1.2, seed=11)
    sig, res = _trajectory(
        None, wl, _sched(plan_ahead_s=30.0, slo_aware=True),
        model_tiers=("unit_tier", "unit_tier"),
    )
    assert sig == _GOLD["no_kv"], (
        "uniform per-replica costs perturbed the placement score: "
        f"{sig} != {_GOLD['no_kv']}"
    )
    assert res.total_cost > 0.0            # accounting ran regardless
    assert res.cost_efficiency() is not None
