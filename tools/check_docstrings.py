#!/usr/bin/env python
"""Strict docstring-presence checker (stdlib-only; runs offline).

Fails when any module, public class, or public function/method in the
given files lacks a docstring.  Used by the CI docs job alongside
ruff's pydocstyle rules so the documented scheduler/serving surfaces
cannot rot silently.

Usage: python tools/check_docstrings.py FILE [FILE ...]
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path


def _public(name: str) -> bool:
    return not name.startswith("_")


def check_file(path: Path) -> list:
    """Return a list of ``(lineno, description)`` violations."""
    tree = ast.parse(path.read_text(), filename=str(path))
    missing = []
    if not ast.get_docstring(tree):
        missing.append((1, "module docstring"))

    def walk(node, prefix: str, in_class: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _public(child.name) and not ast.get_docstring(child):
                    missing.append(
                        (child.lineno, f"function {prefix}{child.name}")
                    )
                # nested defs are implementation details: skip
            elif isinstance(child, ast.ClassDef):
                if _public(child.name):
                    if not ast.get_docstring(child):
                        missing.append(
                            (child.lineno, f"class {prefix}{child.name}")
                        )
                    walk(child, f"{prefix}{child.name}.", True)
    walk(tree, "", False)
    return missing


def main(argv) -> int:
    """Check every argument file; print violations; return exit code."""
    if not argv:
        print(__doc__)
        return 2
    bad = 0
    for arg in argv:
        path = Path(arg)
        for lineno, what in check_file(path):
            print(f"{path}:{lineno}: missing docstring: {what}")
            bad += 1
    if bad:
        print(f"{bad} missing docstring(s)")
        return 1
    print(f"docstrings OK ({len(argv)} file(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
