#!/usr/bin/env python
"""Relative-link checker for the repo's markdown docs (stdlib-only).

Walks the given markdown files/directories, extracts inline links and
images (``[text](target)``), and verifies every *relative* target
resolves to an existing file or directory (anchors are stripped;
``http(s)``/``mailto`` targets are skipped — no network access).

Usage: python tools/check_links.py README.md docs benchmarks/README.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_md_files(args) -> list:
    """Expand file/directory arguments into a list of markdown paths."""
    out = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            out.append(p)
        else:
            print(f"warning: skipping non-markdown argument {a}")
    return out


def check(md: Path) -> list:
    """Return ``(lineno, target)`` for every broken relative link."""
    broken = []
    in_code = False
    for lineno, line in enumerate(md.read_text().splitlines(), start=1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                broken.append((lineno, target))
    return broken


def main(argv) -> int:
    """Check all markdown under the given paths; return exit code."""
    files = iter_md_files(argv or ["README.md", "docs"])
    bad = 0
    for md in files:
        for lineno, target in check(md):
            print(f"{md}:{lineno}: broken link -> {target}")
            bad += 1
    if bad:
        print(f"{bad} broken link(s)")
        return 1
    print(f"links OK ({len(files)} markdown file(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
