#!/usr/bin/env python
"""CI driver for the repro.analysis determinism/seed lint.

Runs every registered AST checker over the given files/directories and
prints one ``path:line: [rule] message`` finding per violation.  Exits
non-zero when any unsuppressed finding remains — the repo is kept
suppress-free, so CI failing here means a real nondeterminism source
(or a new rule that needs a reviewed ``# analysis: ignore[rule]``).

Usage:

    python tools/run_analysis.py                      # src benchmarks examples tests
    python tools/run_analysis.py src/repro/core       # narrow the sweep
    python tools/run_analysis.py --rules wall-clock,seed-missing
    python tools/run_analysis.py --list-rules

Stdlib-only (no numpy/jax): safe for the dependency-free CI job.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import iter_py_files, rule_catalog, run_paths  # noqa: E402

DEFAULT_PATHS = ["src", "benchmarks", "examples", "tests"]


def main(argv=None) -> int:
    """Run the lint; return the process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "paths", nargs="*", default=None,
        help=f"files/directories to analyze (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument(
        "--rules", default=None,
        help="comma-separated rule ids to restrict the sweep to",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(rule_catalog().items()):
            print(f"{rule:16s} {desc}")
        return 0

    paths = args.paths or [str(ROOT / p) for p in DEFAULT_PATHS]
    rules = (
        {r.strip() for r in args.rules.split(",") if r.strip()}
        if args.rules
        else None
    )
    findings = run_paths(paths, rules)
    for f in findings:
        print(f)
    n_files = sum(1 for _ in iter_py_files(paths))
    if findings:
        print(f"\n{len(findings)} finding(s) across {n_files} files")
        return 1
    print(f"analysis clean: 0 findings across {n_files} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
