#!/usr/bin/env python
"""Benchmark seed audit (stdlib-only; runs offline).

Every benchmark script must thread an **explicit seed** into each
randomness source it touches, so artifacts are reproducible and two
modes of one comparison (cache off/on, migration off/on) see the same
trace.  This audit parses the given files (default: ``benchmarks/*.py``)
and fails when:

- ``generate_workload`` / ``generate_tiered_workload`` / ``assign_slos``
  / ``generate_traces`` / ``simulate`` is called without a ``seed=``
  keyword (or the corresponding positional for the generators);
- ``numpy.random.default_rng`` is called with no argument (an OS-seeded
  RNG makes the run unreproducible);
- ``jax.random.key`` / ``jax.random.PRNGKey`` is called with no
  argument (cannot happen legally, but guards refactors);
- a bare ``random.random()`` / ``np.random.<dist>()`` module-level RNG
  is used at all (the global RNG's state is shared and unseedable per
  call site).

Usage: python tools/check_seeds.py [FILE ...]
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

# calls that must carry an explicit seed argument
SEED_KW_FUNCS = {
    "generate_workload", "generate_traces", "simulate",
    "generate_tiered_workload", "assign_slos",
}
# positional index at which the generators accept seed
SEED_POS = {
    "generate_workload": 3,
    "generate_traces": 2,
    "generate_tiered_workload": 3,
    "assign_slos": 4,
}
# calls that must receive at least one (seed) argument
NONEMPTY_FUNCS = {"default_rng", "key", "PRNGKey"}
# module-level global-RNG attributes that are banned outright
BANNED_NP_RANDOM = {
    "rand", "randn", "randint", "random", "choice", "shuffle",
    "permutation", "uniform", "normal", "exponential", "poisson",
}


def _call_name(node: ast.Call) -> str:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def _attr_chain(node: ast.AST) -> list:
    out = []
    while isinstance(node, ast.Attribute):
        out.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        out.append(node.id)
    return list(reversed(out))


def check_file(path: Path) -> list:
    """Return ``(lineno, message)`` seed violations for one file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    bad = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        kwargs = {kw.arg for kw in node.keywords}
        if name in SEED_KW_FUNCS:
            has_kw = "seed" in kwargs or None in kwargs  # None: **kw splat
            has_pos = len(node.args) > SEED_POS.get(name, 99)
            if not (has_kw or has_pos):
                bad.append(
                    (node.lineno, f"{name}(...) without an explicit seed")
                )
        elif name in NONEMPTY_FUNCS:
            chain = _attr_chain(node.func)
            # attribute calls must come off a `random` module; bare
            # names (``from numpy.random import default_rng``) count
            # too when the name is unambiguous (`key` alone is not)
            if isinstance(node.func, ast.Attribute):
                relevant = "random" in chain
            else:
                relevant = name in ("default_rng", "PRNGKey")
            if relevant and not node.args and not node.keywords:
                bad.append(
                    (node.lineno, f"{'.'.join(chain)}() without a seed")
                )
        elif isinstance(node.func, ast.Attribute):
            chain = _attr_chain(node.func)
            if (
                len(chain) >= 3
                and chain[0] in ("np", "numpy")
                and chain[1] == "random"
                and chain[2] in BANNED_NP_RANDOM
            ):
                bad.append(
                    (node.lineno,
                     f"global RNG {'.'.join(chain)}() — use "
                     "default_rng(seed) instead")
                )
    return bad


def main(argv: list) -> int:
    """CLI entry point; returns a non-zero status on violations."""
    paths = [Path(a) for a in argv] or sorted(
        Path(__file__).resolve().parent.parent.glob("benchmarks/*.py")
    )
    failed = False
    for path in paths:
        for lineno, msg in check_file(path):
            print(f"{path}:{lineno}: {msg}")
            failed = True
    if failed:
        print("\nseed audit FAILED — thread an explicit seed (see module "
              "docstring)")
        return 1
    print(f"seed audit OK ({len(paths)} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
