"""Flash attention (prefill/train) as a Pallas TPU kernel.

TPU adaptation of the FlashAttention tiling: the grid walks
(batch·kv_head, q_blocks, kv_blocks) with the kv dimension innermost and
sequential ("arbitrary" dimension semantics), carrying running max / sum /
accumulator in VMEM scratch.  Block shapes are MXU-aligned (multiples of
128 on the lane dim, head_dim padded by BlockSpec).  GQA is handled by
folding the q-head group into the q rows of each (batch, kv_head) program
so the MXU sees (block_q·group, head_dim) @ (head_dim, block_k) matmuls.

Causal masking skips fully-masked kv blocks via a grid predicate (the
`when` guard on the accumulation), matching the memory-bandwidth win of
the original paper on the TPU memory hierarchy (HBM→VMEM instead of
HBM→SRAM).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref,        # (1, block_q * g, hd)
    k_ref,        # (1, block_k, hd)
    v_ref,        # (1, block_k, hd_v)
    o_ref,        # (1, block_q * g, hd_v)
    m_scr,        # (block_q * g, 1) running max
    l_scr,        # (block_q * g, 1) running sum
    acc_scr,      # (block_q * g, hd_v)
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    g: int,
    kv_len: Optional[int],
    s_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level causal skip: kv block strictly after the q block's end
    q_start = qi * block_q                       # token rows (pre-group)
    k_start = ki * block_k

    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq*g, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (bq*g, bk)
        # causal mask at token granularity
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g + q_start
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + k_start
        s = jnp.where(cols < s_k, s, NEG_INF)   # ragged tail (block padding)
        if causal:
            s = jnp.where(cols <= rows, s, NEG_INF)
        if kv_len is not None:
            s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq*g, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq*g, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                   # (bk, hd_v)
        # sanitize padded tail rows of v (uninitialized block padding):
        # p is 0 there but 0*NaN = NaN, so replace via where
        vrow = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0) + k_start
        v = jnp.where(vrow < s_k, v, 0.0)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    if causal:
        # skip kv blocks that start beyond the last row of this q block
        q_last = q_start + block_q - 1
        pl.when(k_start <= q_last)(_accumulate)
    else:
        _accumulate()

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "q_offset", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,                 # (B, S_q, H, hd)
    k: jax.Array,                 # (B, S_k, K, hd)
    v: jax.Array,                 # (B, S_k, K, hd_v)
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Pallas flash attention with GQA; matches kernels/ref.attention_ref."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, hd = q.shape
    _, Sk, K, hd_v = k.shape[0], k.shape[1], k.shape[2], v.shape[3]
    g = H // k.shape[2]
    K = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    if kv_len is not None:
        raise NotImplementedError("per-batch kv_len: use ops.attention impl='ref'")
    if causal and q_offset != 0:
        raise NotImplementedError("q_offset with causal prefill not needed here")

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    # layout: fold heads into the grid; group dim rides with q rows
    # q -> (B*K, Sq*g, hd) with rows ordered [token-major, group-minor]
    qr = q.reshape(B, Sq, K, g, hd).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(B * K, Sq * g, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd_v)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        g=g,
        kv_len=None,
        s_k=Sk,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B * K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q * g, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd_v), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q * g, hd_v), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, Sq * g, hd_v), q.dtype),
        scratch_shapes=[
            _vmem((block_q * g, 1)),
            _vmem((block_q * g, 1)),
            _vmem((block_q * g, hd_v)),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(qr, kr, vr)

    out = out.reshape(B, K, Sq, g, hd_v).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Sq, H, hd_v)


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _compiler_params():
    import jax.experimental.pallas.tpu as pltpu

    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    except (AttributeError, TypeError):  # older naming
        return pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
