"""Pallas TPU kernels for the LLM-executor hot spots.

Each kernel ships three files per the repo contract:
- ``<name>.py`` — pl.pallas_call + explicit BlockSpec VMEM tiling;
- ``ops.py``    — jit'd dispatch (pallas on TPU, oracle elsewhere);
- ``ref.py``    — pure-jnp oracle, the semantics ground truth.

Kernels: flash_attention (prefill/train), decode_attention (serving decode
hot spot, slot caches), paged_attention (serving decode over paged KV
pools with block tables), rmsnorm (fused norm), ssm_scan (Mamba selective
scan).
"""

from . import ops, ref

__all__ = ["ops", "ref"]
