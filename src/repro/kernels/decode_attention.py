"""GQA decode attention over a slot KV cache — the serving hot spot.

One new query token per request attends to its full cache.  Decode is
memory-bound (the cache streams HBM→VMEM once), so the kernel's job is to
keep that stream dense: grid = (batch·kv_head, kv_blocks) with the kv
dimension sequential, flash-style running max/sum in VMEM scratch, and the
whole q-head group (g rows) processed per program so each cache block is
read exactly once for all grouped heads.

Per-request valid lengths are applied inside the kernel (slot caches are
allocated at S_max), prefetching `lengths` as a scalar operand.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(
    len_ref,      # (B,) int32 in SMEM — valid cache lengths
    q_ref,        # (1, g, hd)
    k_ref,        # (1, block_k, hd)
    v_ref,        # (1, block_k, hd_v)
    o_ref,        # (1, g, hd_v)
    m_scr,        # (g, 1)
    l_scr,        # (g, 1)
    acc_scr,      # (g, hd_v)
    *,
    scale: float,
    block_k: int,
    n_kv_heads: int,
):
    bh = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)
    b = bh // n_kv_heads
    length = len_ref[b]
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale           # (g, hd)
        k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # (g, bk)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + k_start
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        # sanitize padded tail rows (p is 0 there, but 0*NaN = NaN)
        vrow = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0) + k_start
        v = jnp.where(vrow < length, v, 0.0)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    # skip cache blocks entirely beyond this request's length
    pl.when(k_start < length)(_accumulate)

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,            # (B, H, hd)
    k_cache: jax.Array,      # (B, S, K, hd)
    v_cache: jax.Array,      # (B, S, K, hd_v)
    lengths: jax.Array,      # (B,) int32
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, hd = q.shape
    _, S, K, hd_v = (
        k_cache.shape[0], k_cache.shape[1], k_cache.shape[2], v_cache.shape[3]
    )
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    block_k = min(block_k, S)
    nk = pl.cdiv(S, block_k)

    qr = q.reshape(B, K, g, hd).reshape(B * K, g, hd)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd_v)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_kv_heads=K
    )

    import jax.experimental.pallas.tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * K, nk),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda b, j, lens: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, lens: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd_v), lambda b, j, lens: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd_v), lambda b, j, lens: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd_v), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, g, hd_v), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qr, kr, vr)
    return out.reshape(B, K, g, hd_v).reshape(B, H, hd_v)
