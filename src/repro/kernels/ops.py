"""Jitted dispatch layer over the Pallas kernels and their jnp oracles.

Models call these entry points; ``impl`` selects:
- "ref"      : pure-jnp oracle (CPU smoke tests, SPMD dry-run — Mosaic
               lowering requires a real TPU backend);
- "pallas"   : pl.pallas_call kernel (TPU target; interpret=True on CPU
               inside the kernel tests);
- "auto"     : pallas on TPU backends, ref elsewhere.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pick(impl: str) -> str:
    return _default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[jax.Array] = None,
    impl: str = "auto",
) -> jax.Array:
    """Multi-head (GQA) attention — flash kernel on TPU, oracle elsewhere."""
    if _pick(impl) == "pallas":
        from .flash_attention import flash_attention

        return flash_attention(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset, kv_len=kv_len
        )
    return _ref.attention_ref(
        q, k, v, causal=causal, scale=scale, q_offset=q_offset, kv_len=kv_len
    )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> jax.Array:
    if _pick(impl) == "pallas":
        from .decode_attention import decode_attention as _da

        return _da(q, k_cache, v_cache, lengths, scale=scale)
    return _ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale)


def paged_decode_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    scale: Optional[float] = None,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    impl: str = "auto",
) -> jax.Array:
    """Decode attention over a paged KV pool via per-request block tables.

    With ``k_scales``/``v_scales`` the pools are int8 and dequantized
    per page inside the kernel (oracle: dequantize-then-attend).
    """
    if _pick(impl) == "pallas":
        from .paged_attention import paged_decode_attention as _pda

        return _pda(
            q, k_pages, v_pages, block_tables, lengths, scale=scale,
            k_scales=k_scales, v_scales=v_scales,
        )
    return _ref.paged_decode_attention_ref(
        q, k_pages, v_pages, block_tables, lengths, scale=scale,
        k_scales=k_scales, v_scales=v_scales,
    )


def paged_prefill_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_table: jax.Array,
    past: int,
    scale: Optional[float] = None,
    k_scales: Optional[jax.Array] = None,
    v_scales: Optional[jax.Array] = None,
    impl: str = "auto",
) -> jax.Array:
    """Fused chunked-prefill attention over one request's block table.

    The chunk's K/V must already be scattered into the pools; queries
    attend causally to paged history + the in-chunk segment.  With
    ``k_scales``/``v_scales`` the pools are int8 (see decode).
    """
    if _pick(impl) == "pallas":
        from .paged_attention import paged_prefill_attention as _ppa

        return _ppa(
            q, k_pages, v_pages, block_table, past, scale=scale,
            k_scales=k_scales, v_scales=v_scales,
        )
    return _ref.paged_prefill_attention_ref(
        q, k_pages, v_pages, block_table, past, scale=scale,
        k_scales=k_scales, v_scales=v_scales,
    )


def quantize_kv(x: jax.Array):
    """Symmetric int8 KV quantization (per token, per kv head).

    Pure elementwise math — one spec shared by the paged engine's
    quantize-on-scatter and the oracles, so there is nothing to
    dispatch; see :func:`repro.kernels.ref.quantize_kv_ref`.
    """
    return _ref.quantize_kv_ref(x)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5,
            impl: str = "auto") -> jax.Array:
    if _pick(impl) == "pallas":
        from .rmsnorm import rmsnorm as _rn

        return _rn(x, gamma, eps=eps)
    return _ref.rmsnorm_ref(x, gamma, eps=eps)


def ssm_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    h0: Optional[jax.Array] = None,
    impl: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    if _pick(impl) == "pallas":
        from .ssm_scan import ssm_scan as _ss

        return _ss(x, dt, A, Bm, Cm, D, h0=h0)
    return _ref.ssm_scan_ref(x, dt, A, Bm, Cm, D, h0=h0)
