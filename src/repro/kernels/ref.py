"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics ground truth: kernels/tests assert allclose
against these, and they double as the portable CPU path used by smoke
tests and the 512-device dry-run (Mosaic lowering needs real TPUs).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (prefill/train): GQA + causal
# ---------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,            # (B, S_q, H, hd)
    k: jax.Array,            # (B, S_k, K, hd)
    v: jax.Array,            # (B, S_k, K, hd)
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,       # absolute position of q[0] (cached prefix len)
    kv_len: Optional[jax.Array] = None,  # (B,) valid kv length per batch
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    hd_v = v.shape[-1]            # MLA: v head dim may differ from q/k
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    qf = qf.reshape(B, Sq, K, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    mask = jnp.zeros((B, 1, 1, Sq, Sk), dtype=jnp.float32)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        mask = mask + jnp.where(kpos > qpos, NEG_INF, 0.0)[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]
        mask = mask + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    w = jax.nn.softmax(logits + mask, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention: single query token vs KV cache with valid lengths
# ---------------------------------------------------------------------------
def decode_attention_ref(
    q: jax.Array,            # (B, H, hd)
    k_cache: jax.Array,      # (B, S, K, hd)
    v_cache: jax.Array,      # (B, S, K, hd)
    lengths: jax.Array,      # (B,) int32 — valid cache entries
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, hd = q.shape
    _, S, K, _ = k_cache.shape
    hd_v = v_cache.shape[-1]      # MLA: v head dim may differ from q/k
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, K, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < lengths[:, None]          # (B, S)
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged decode attention: single query token vs a paged KV pool addressed
# through per-request block tables (vLLM-style PagedAttention)
# ---------------------------------------------------------------------------
def gather_pages(
    pages: jax.Array,         # (P, page_size, K, hd) physical page pool
    block_tables: jax.Array,  # (B, pages_per_seq) int32 page ids
) -> jax.Array:
    """Materialize the dense (B, S, K, hd) view a block table describes.

    Token t of request b lives at (block_tables[b, t // ps], t % ps);
    gathering page-by-page therefore reconstructs positions in order.
    """
    P, ps, K, hd = pages.shape
    B, npp = block_tables.shape
    flat = pages.reshape(P * ps, K, hd)
    tok = block_tables[:, :, None] * ps + jnp.arange(ps)[None, None, :]
    return flat[tok.reshape(B, npp * ps)]


def paged_decode_attention_ref(
    q: jax.Array,             # (B, H, hd)
    k_pages: jax.Array,       # (P, page_size, K, hd)
    v_pages: jax.Array,       # (P, page_size, K, hd_v)
    block_tables: jax.Array,  # (B, pages_per_seq) int32
    lengths: jax.Array,       # (B,) int32 — valid tokens (incl. current)
    scale: Optional[float] = None,
    k_scales: Optional[jax.Array] = None,  # (P, page_size, K) f32
    v_scales: Optional[jax.Array] = None,
) -> jax.Array:
    if k_scales is not None:
        k_pages = dequantize_pages_ref(k_pages, k_scales)
        v_pages = dequantize_pages_ref(v_pages, v_scales)
    k_dense = gather_pages(k_pages, block_tables)
    v_dense = gather_pages(v_pages, block_tables)
    return decode_attention_ref(q, k_dense, v_dense, lengths, scale=scale)


# ---------------------------------------------------------------------------
# Paged chunked-prefill attention: C chunk queries of a single request vs
# the context pages named by its block table (history + in-chunk segment,
# both already scattered into the pool)
# ---------------------------------------------------------------------------
def paged_prefill_attention_ref(
    q: jax.Array,            # (C, H, hd) — one request's chunk queries
    k_pages: jax.Array,      # (P, page_size, K, hd)
    v_pages: jax.Array,      # (P, page_size, K, hd_v)
    block_table: jax.Array,  # (pages_per_seq,) int32
    past: int,               # prompt tokens already prefilled (chunk offset)
    scale: Optional[float] = None,
    k_scales: Optional[jax.Array] = None,  # (P, page_size, K) f32
    v_scales: Optional[jax.Array] = None,
) -> jax.Array:
    if k_scales is not None:
        k_pages = dequantize_pages_ref(k_pages, k_scales)
        v_pages = dequantize_pages_ref(v_pages, v_scales)
    C = q.shape[0]
    ps = k_pages.shape[1]
    ctx = past + C
    n_ctx_pages = -(-ctx // ps)
    bt = block_table[None, :n_ctx_pages]
    k_ctx = gather_pages(k_pages, bt)            # (1, n_ctx_pages*ps, K, hd)
    v_ctx = gather_pages(v_pages, bt)
    kv_len = jnp.array([ctx], jnp.int32)
    out = attention_ref(
        q[None], k_ctx, v_ctx, causal=True, scale=scale,
        q_offset=past, kv_len=kv_len,
    )
    return out[0]


# ---------------------------------------------------------------------------
# int8 KV-page quantization: symmetric per (token-slot, kv-head) scales,
# stored page-major alongside the pools ("per-page scale pools")
# ---------------------------------------------------------------------------
def quantize_kv_ref(x: jax.Array):
    """Quantize K/V values to int8 with per (…, kv-head) symmetric scales.

    ``x`` is ``(..., K, hd)``; returns ``(q int8 (..., K, hd),
    scales f32 (..., K))`` with ``scale = max(|x|, 1e-8) / 127`` over the
    head dim — the same spec as the slot cache's ``_q8_kv``.  Each token
    is quantized exactly once, at write time, from its exact value, so
    page contents are a pure function of the tokens they hold (chunk
    boundaries, prefix-cache adoption, and migration cannot change the
    bits — the differential token-equality suites rely on this).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_pages_ref(
    pages: jax.Array,    # (P, page_size, K, hd) int8
    scales: jax.Array,   # (P, page_size, K) f32
) -> jax.Array:
    """Reconstruct float32 pages from an int8 pool and its scale pool."""
    return pages.astype(jnp.float32) * scales[..., None]


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba selective scan (SSM):  h_t = dA_t ⊙ h_{t-1} + dB_t x_t ;  y = C_t·h
# ---------------------------------------------------------------------------
def ssm_scan_ref(
    x: jax.Array,       # (B, T, D)  post-conv activations
    dt: jax.Array,      # (B, T, D)  softplus'd step sizes
    A: jax.Array,       # (D, N)     negative decay matrix
    Bm: jax.Array,      # (B, T, N)  input matrix
    Cm: jax.Array,      # (B, T, N)  output matrix
    D: jax.Array,       # (D,)       skip
    h0: Optional[jax.Array] = None,  # (B, D, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,T,D), h_T (B,D,N)). float32 state math."""
    Bsz, T, Dd = x.shape
    N = A.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((Bsz, Dd, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,D), (B,D), (B,N), (B,N)
        dA = jnp.exp(dtt[..., None] * Af[None])          # (B, D, N)
        dBx = (dtt * xt)[..., None] * Bt[:, None, :]     # (B, D, N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None, :]
    return y.astype(x.dtype), h
