"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics ground truth: kernels/tests assert allclose
against these, and they double as the portable CPU path used by smoke
tests and the 512-device dry-run (Mosaic lowering needs real TPUs).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention (prefill/train): GQA + causal
# ---------------------------------------------------------------------------
def attention_ref(
    q: jax.Array,            # (B, S_q, H, hd)
    k: jax.Array,            # (B, S_k, K, hd)
    v: jax.Array,            # (B, S_k, K, hd)
    causal: bool = True,
    scale: Optional[float] = None,
    q_offset: int = 0,       # absolute position of q[0] (cached prefix len)
    kv_len: Optional[jax.Array] = None,  # (B,) valid kv length per batch
) -> jax.Array:
    B, Sq, H, hd = q.shape
    _, Sk, K, _ = k.shape
    hd_v = v.shape[-1]            # MLA: v head dim may differ from q/k
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32) * scale
    qf = qf.reshape(B, Sq, K, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    mask = jnp.zeros((B, 1, 1, Sq, Sk), dtype=jnp.float32)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        mask = mask + jnp.where(kpos > qpos, NEG_INF, 0.0)[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]
        mask = mask + jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
    w = jax.nn.softmax(logits + mask, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention: single query token vs KV cache with valid lengths
# ---------------------------------------------------------------------------
def decode_attention_ref(
    q: jax.Array,            # (B, H, hd)
    k_cache: jax.Array,      # (B, S, K, hd)
    v_cache: jax.Array,      # (B, S, K, hd)
    lengths: jax.Array,      # (B,) int32 — valid cache entries
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, hd = q.shape
    _, S, K, _ = k_cache.shape
    hd_v = v_cache.shape[-1]      # MLA: v head dim may differ from q/k
    g = H // K
    scale = scale if scale is not None else hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, K, g, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < lengths[:, None]          # (B, S)
    logits = logits + jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd_v).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged decode attention: single query token vs a paged KV pool addressed
# through per-request block tables (vLLM-style PagedAttention)
# ---------------------------------------------------------------------------
def gather_pages(
    pages: jax.Array,         # (P, page_size, K, hd) physical page pool
    block_tables: jax.Array,  # (B, pages_per_seq) int32 page ids
) -> jax.Array:
    """Materialize the dense (B, S, K, hd) view a block table describes.

    Token t of request b lives at (block_tables[b, t // ps], t % ps);
    gathering page-by-page therefore reconstructs positions in order.
    """
    P, ps, K, hd = pages.shape
    B, npp = block_tables.shape
    flat = pages.reshape(P * ps, K, hd)
    tok = block_tables[:, :, None] * ps + jnp.arange(ps)[None, None, :]
    return flat[tok.reshape(B, npp * ps)]


def paged_decode_attention_ref(
    q: jax.Array,             # (B, H, hd)
    k_pages: jax.Array,       # (P, page_size, K, hd)
    v_pages: jax.Array,       # (P, page_size, K, hd_v)
    block_tables: jax.Array,  # (B, pages_per_seq) int32
    lengths: jax.Array,       # (B,) int32 — valid tokens (incl. current)
    scale: Optional[float] = None,
) -> jax.Array:
    k_dense = gather_pages(k_pages, block_tables)
    v_dense = gather_pages(v_pages, block_tables)
    return decode_attention_ref(q, k_dense, v_dense, lengths, scale=scale)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba selective scan (SSM):  h_t = dA_t ⊙ h_{t-1} + dB_t x_t ;  y = C_t·h
# ---------------------------------------------------------------------------
def ssm_scan_ref(
    x: jax.Array,       # (B, T, D)  post-conv activations
    dt: jax.Array,      # (B, T, D)  softplus'd step sizes
    A: jax.Array,       # (D, N)     negative decay matrix
    Bm: jax.Array,      # (B, T, N)  input matrix
    Cm: jax.Array,      # (B, T, N)  output matrix
    D: jax.Array,       # (D,)       skip
    h0: Optional[jax.Array] = None,  # (B, D, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,T,D), h_T (B,D,N)). float32 state math."""
    Bsz, T, Dd = x.shape
    N = A.shape[1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((Bsz, Dd, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,D), (B,D), (B,N), (B,N)
        dA = jnp.exp(dtt[..., None] * Af[None])          # (B, D, N)
        dBx = (dtt * xt)[..., None] * Bt[:, None, :]     # (B, D, N)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    h, ys = jax.lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None, :]
    return y.astype(x.dtype), h
