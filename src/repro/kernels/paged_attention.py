"""Paged GQA decode attention — vLLM-style PagedAttention in Pallas.

The KV cache lives in a pool of fixed-size pages; each request owns a
block table mapping its logical token positions to physical pages.  One
new query token per request attends to its full (paged) history.

Kernel shape: grid = (batch · kv_head, pages_per_seq) with the page
dimension sequential.  The block table and valid lengths ride in scalar
prefetch; the K/V *index maps read the block table*, so each program DMAs
exactly one physical page — the gather never materializes a dense cache.
Flash-style running max/sum scratch accumulates across pages, and the
whole q-head group (g rows) is processed per program so every page is
streamed HBM→VMEM exactly once for all grouped heads.

Pages past a request's length are skipped (the DMA still runs — index
maps are unconditional — but the FLOPs and the accumulator update are
predicated off, and freed/garbage page contents are masked to ±NEG_INF /
zero so recycled pages can never leak into another request's output).

Layout note: pools are stored token-major, ``(P, page_size, K, hd)`` —
the layout the engine's scatter-writes want — and transposed to
``(K, P, page_size, hd)`` at call time so the kernel's trailing two dims
are (page_size, head_dim), which tiles cleanly on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_decode_kernel(
    bt_ref,       # (B, npp) int32 in SMEM — block tables
    len_ref,      # (B,) int32 in SMEM — valid lengths (incl. current token)
    q_ref,        # (1, g, hd)
    k_ref,        # (1, 1, page_size, hd) — the page this program visits
    v_ref,        # (1, 1, page_size, hd_v)
    o_ref,        # (1, g, hd_v)
    m_scr,        # (g, 1)
    l_scr,        # (g, 1)
    acc_scr,      # (g, hd_v)
    *,
    scale: float,
    page_size: int,
    n_kv_heads: int,
):
    bh = pl.program_id(0)
    pi = pl.program_id(1)
    npp = pl.num_programs(1)
    b = bh // n_kv_heads
    length = len_ref[b]
    t_start = pi * page_size

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale              # (g, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (ps, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # (g, ps)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + t_start
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)                   # (ps, hd_v)
        # sanitize rows past `length` (p is 0 there, but 0*NaN = NaN)
        vrow = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0) + t_start
        v = jnp.where(vrow < length, v, 0.0)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    # pages wholly beyond this request's history contribute nothing
    pl.when(t_start < length)(_accumulate)

    @pl.when(pi == npp - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(
    q: jax.Array,             # (B, H, hd)
    k_pages: jax.Array,       # (P, page_size, K, hd) physical page pool
    v_pages: jax.Array,       # (P, page_size, K, hd_v)
    block_tables: jax.Array,  # (B, pages_per_seq) int32 page ids
    lengths: jax.Array,       # (B,) int32 — valid tokens (incl. current)
    scale: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, hd = q.shape
    P, page_size, K, hd_v = (
        k_pages.shape[0], k_pages.shape[1], k_pages.shape[2], v_pages.shape[3]
    )
    npp = block_tables.shape[1]
    g = H // K
    scale = scale if scale is not None else hd ** -0.5

    qr = q.reshape(B, K, g, hd).reshape(B * K, g, hd)
    kr = k_pages.transpose(2, 0, 1, 3)   # (K, P, ps, hd)
    vr = v_pages.transpose(2, 0, 1, 3)   # (K, P, ps, hd_v)

    kernel = functools.partial(
        _paged_decode_kernel,
        scale=scale,
        page_size=page_size,
        n_kv_heads=K,
    )

    import jax.experimental.pallas.tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (block_tables, lengths)
        grid=(B * K, npp),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda bh, j, bt, lens: (bh, 0, 0)),
            # the paged gather: the page index comes from the block table
            pl.BlockSpec(
                (1, 1, page_size, hd),
                lambda bh, j, bt, lens: (bh % K, bt[bh // K, j], 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, page_size, hd_v),
                lambda bh, j, bt, lens: (bh % K, bt[bh // K, j], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, g, hd_v), lambda bh, j, bt, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd_v), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, g, hd_v), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qr, kr, vr)
    return out.reshape(B, K, g, hd_v).reshape(B, H, hd_v)


def check_block_table_bounds(
    block_tables,
    lengths,
    num_pages: int,
    page_size: int,
    trash_page: int = 0,
) -> None:
    """Host-side static bounds check of a decode call's block tables.

    The Pallas kernel's index maps are *unconditional*: every
    ``bt[b, j]`` entry is used as a DMA source page, valid or not.
    Out-of-range indices would read (and the engine's scatter-write
    would write) outside the pool, and a trash entry inside a row's
    covered range means a live token's KV was never given a real page.
    This check runs on the host arrays immediately before the kernel
    (under ``REPRO_SANITIZE``/``sanitize=True``) and in unit tests with
    adversarial tables.

    Parameters
    ----------
    block_tables : array_like, shape (B, pages_per_seq)
        Physical page ids per row (``trash_page`` marks padding).
    lengths : array_like, shape (B,)
        Valid tokens per row *excluding* the token being decoded (the
        engine's convention: the incoming token writes at position
        ``lengths[b]``); 0 marks a padding row.
    num_pages : int
        The allocator's pool size.
    page_size : int
        Tokens per page.
    trash_page : int, optional
        The reserved padding page id.

    Raises
    ------
    ValueError
        Naming the offending row/entry on any out-of-range index or
        any trash entry within a live row's covered page range.
    """
    import numpy as np

    bt = np.asarray(block_tables)
    lens = np.asarray(lengths)
    if bt.ndim != 2 or lens.shape != (bt.shape[0],):
        raise ValueError(
            f"shape mismatch: block_tables {bt.shape} vs lengths {lens.shape}"
        )
    bad = (bt < 0) | (bt >= num_pages)
    if bad.any():
        b, j = map(int, np.argwhere(bad)[0])
        raise ValueError(
            f"block-table entry out of pool bounds: bt[{b}, {j}] = "
            f"{int(bt[b, j])} not in [0, {num_pages})"
        )
    # a live row writes at position lengths[b]: pages 0..lengths[b]//ps
    # inclusive must be real pages
    cov = np.where(lens > 0, lens // page_size + 1, 0)
    if (cov > bt.shape[1]).any():
        b = int(np.argmax(cov > bt.shape[1]))
        raise ValueError(
            f"row {b} needs {int(cov[b])} pages for length {int(lens[b])} "
            f"but the block table holds only {bt.shape[1]}"
        )
    pos = np.arange(bt.shape[1])[None, :]
    covered_trash = (pos < cov[:, None]) & (bt == trash_page)
    if covered_trash.any():
        b, j = map(int, np.argwhere(covered_trash)[0])
        raise ValueError(
            f"trash page inside covered range: bt[{b}, {j}] is the trash "
            f"page but row {b} has length {int(lens[b])} "
            f"(covers {int(cov[b])} pages)"
        )
