"""Paged GQA attention — vLLM-style PagedAttention in Pallas.

The KV cache lives in a pool of fixed-size pages; each request owns a
block table mapping its logical token positions to physical pages.  Two
kernels share the pool layout:

- **decode** (:func:`paged_decode_attention`): one new query token per
  request attends to its full (paged) history.  Grid =
  (batch · kv_head, pages_per_seq) with the page dimension sequential.
- **chunked prefill** (:func:`paged_prefill_attention`): a whole prompt
  chunk of C query tokens for a *single* request attends causally to
  the already-paged history plus the in-chunk segment (the chunk's own
  K/V are scattered into the pool before the call, so the kernel only
  ever reads pages).  Grid = (kv_head, ctx_pages), pages sequential.

In both, the block table rides in scalar prefetch and the K/V *index
maps read the block table*, so each program DMAs exactly one physical
page — the gather never materializes a dense cache.  Flash-style running
max/sum scratch accumulates across pages, and the whole q-head group is
processed per program so every page is streamed HBM→VMEM exactly once
for all grouped heads.

Pages past a request's length are skipped (the DMA still runs — index
maps are unconditional — but the FLOPs and the accumulator update are
predicated off, and freed/garbage page contents are masked to ±NEG_INF /
zero so recycled pages can never leak into another request's output).

**Quantized pages**: both kernels take optional per-page scale pools
(``(P, page_size, K)`` float32 — one symmetric scale per token slot per
KV head, stored page-major alongside the int8 K/V pools).  Scales are
dequantized *inside* the kernel (``int8 → f32 × scale``) right after the
page DMA, so the pool stays int8 in HBM and effective KV capacity per
byte roughly quadruples versus fp32 pages.

Layout note: pools are stored token-major, ``(P, page_size, K, hd)`` —
the layout the engine's scatter-writes want — and transposed to
``(K, P, page_size, hd)`` at call time so the kernel's trailing two dims
are (page_size, head_dim), which tiles cleanly on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_decode_kernel(
    bt_ref,       # (B, npp) int32 in SMEM — block tables
    len_ref,      # (B,) int32 in SMEM — valid lengths (incl. current token)
    q_ref,        # (1, g, hd)
    k_ref,        # (1, 1, page_size, hd) — the page this program visits
    v_ref,        # (1, 1, page_size, hd_v)
    *rest,        # [ks_ref, vs_ref (1, 1, page_size, 1)] o_ref, scratch×3
    scale: float,
    page_size: int,
    n_kv_heads: int,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    bh = pl.program_id(0)
    pi = pl.program_id(1)
    npp = pl.num_programs(1)
    b = bh // n_kv_heads
    length = len_ref[b]
    t_start = pi * page_size

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32) * scale              # (g, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)                   # (ps, hd_v)
        if quantized:
            k = k * ks_ref[0, 0]                              # (ps, 1) bcast
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # (g, ps)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + t_start
        s = jnp.where(cols < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        # sanitize rows past `length` (p is 0 there, but 0*NaN = NaN)
        vrow = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0) + t_start
        v = jnp.where(vrow < length, v, 0.0)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    # pages wholly beyond this request's history contribute nothing
    pl.when(t_start < length)(_accumulate)

    @pl.when(pi == npp - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(
    q: jax.Array,             # (B, H, hd)
    k_pages: jax.Array,       # (P, page_size, K, hd) physical page pool
    v_pages: jax.Array,       # (P, page_size, K, hd_v)
    block_tables: jax.Array,  # (B, pages_per_seq) int32 page ids
    lengths: jax.Array,       # (B,) int32 — valid tokens (incl. current)
    scale: Optional[float] = None,
    interpret: Optional[bool] = None,
    k_scales: Optional[jax.Array] = None,  # (P, page_size, K) f32 (int8 pools)
    v_scales: Optional[jax.Array] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    quantized = k_scales is not None
    B, H, hd = q.shape
    P, page_size, K, hd_v = (
        k_pages.shape[0], k_pages.shape[1], k_pages.shape[2], v_pages.shape[3]
    )
    npp = block_tables.shape[1]
    g = H // K
    scale = scale if scale is not None else hd ** -0.5

    qr = q.reshape(B, K, g, hd).reshape(B * K, g, hd)
    kr = k_pages.transpose(2, 0, 1, 3)   # (K, P, ps, hd)
    vr = v_pages.transpose(2, 0, 1, 3)   # (K, P, ps, hd_v)

    kernel = functools.partial(
        _paged_decode_kernel,
        scale=scale,
        page_size=page_size,
        n_kv_heads=K,
        quantized=quantized,
    )

    import jax.experimental.pallas.tpu as pltpu

    page_spec = lambda bh, j, bt, lens: (bh % K, bt[bh // K, j], 0, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, g, hd), lambda bh, j, bt, lens: (bh, 0, 0)),
        # the paged gather: the page index comes from the block table
        pl.BlockSpec((1, 1, page_size, hd), page_spec),
        pl.BlockSpec((1, 1, page_size, hd_v), page_spec),
    ]
    operands = [qr, kr, vr]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, page_size, 1), page_spec)] * 2
        operands += [
            k_scales.transpose(2, 0, 1).reshape(K, P, page_size, 1),
            v_scales.transpose(2, 0, 1).reshape(K, P, page_size, 1),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (block_tables, lengths)
        grid=(B * K, npp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, g, hd_v), lambda bh, j, bt, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd_v), jnp.float32),
        ],
    )
    out_dtype = jnp.float32 if quantized else q.dtype
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, g, hd_v), out_dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    return out.reshape(B, K, g, hd_v).reshape(B, H, hd_v).astype(q.dtype)


def _paged_prefill_kernel(
    bt_ref,       # (npp,) int32 in SMEM — this request's block table
    q_ref,        # (1, C·g, hd) — all grouped query rows for one kv head
    k_ref,        # (1, 1, page_size, hd)
    v_ref,        # (1, 1, page_size, hd_v)
    *rest,        # [ks_ref, vs_ref (1, 1, page_size, 1)] o_ref, scratch×3
    scale: float,
    page_size: int,
    past: int,
    ctx: int,
    group: int,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    pi = pl.program_id(1)
    npp = pl.num_programs(1)
    t_start = pi * page_size

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale                  # (C·g, hd)
    k = k_ref[0, 0].astype(jnp.float32)                       # (ps, hd)
    v = v_ref[0, 0].astype(jnp.float32)                       # (ps, hd_v)
    if quantized:
        k = k * ks_ref[0, 0]                                  # (ps, 1) bcast
        v = v * vs_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (C·g, ps)
    # row r holds query token past + r//g; causal + context masking in one
    qpos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group + past
    kpos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + t_start
    mask = kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # rows whose causal window hasn't started keep m == NEG_INF; exp(s-m)
    # would be exp(0)=1 there, so zero the masked weights explicitly
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    # sanitize rows past the context (p is 0 there, but 0*NaN = NaN)
    vrow = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0) + t_start
    v = jnp.where(vrow < ctx, v, 0.0)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(pi == npp - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("past", "scale", "interpret")
)
def paged_prefill_attention(
    q: jax.Array,            # (C, H, hd) — one request's chunk queries
    k_pages: jax.Array,      # (P, page_size, K, hd) physical page pool
    v_pages: jax.Array,      # (P, page_size, K, hd_v)
    block_table: jax.Array,  # (pages_per_seq,) int32 page ids
    past: int,               # prompt tokens already prefilled (chunk offset)
    scale: Optional[float] = None,
    interpret: Optional[bool] = None,
    k_scales: Optional[jax.Array] = None,  # (P, page_size, K) f32 (int8 pools)
    v_scales: Optional[jax.Array] = None,
) -> jax.Array:
    """Fused chunked-prefill attention over block tables.

    The chunk's K/V must already be scattered into the pools (positions
    ``past .. past+C``); its queries attend causally to the
    ``ceil((past+C)/page_size)`` context pages named by the block table.
    Returns ``(C, H, hd_v)``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    quantized = k_scales is not None
    C, H, hd = q.shape
    P, page_size, K, hd_v = (
        k_pages.shape[0], k_pages.shape[1], k_pages.shape[2], v_pages.shape[3]
    )
    g = H // K
    ctx = past + C
    n_ctx_pages = -(-ctx // page_size)
    scale = scale if scale is not None else hd ** -0.5

    qr = q.reshape(C, K, g, hd).transpose(1, 0, 2, 3).reshape(K, C * g, hd)
    kr = k_pages.transpose(2, 0, 1, 3)   # (K, P, ps, hd)
    vr = v_pages.transpose(2, 0, 1, 3)   # (K, P, ps, hd_v)

    kernel = functools.partial(
        _paged_prefill_kernel,
        scale=scale,
        page_size=page_size,
        past=past,
        ctx=ctx,
        group=g,
        quantized=quantized,
    )

    import jax.experimental.pallas.tpu as pltpu

    page_spec = lambda kk, j, bt: (kk, bt[j], 0, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, C * g, hd), lambda kk, j, bt: (kk, 0, 0)),
        pl.BlockSpec((1, 1, page_size, hd), page_spec),
        pl.BlockSpec((1, 1, page_size, hd_v), page_spec),
    ]
    operands = [qr, kr, vr]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, page_size, 1), page_spec)] * 2
        operands += [
            k_scales.transpose(2, 0, 1).reshape(K, P, page_size, 1),
            v_scales.transpose(2, 0, 1).reshape(K, P, page_size, 1),
        ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # (block_table,)
        grid=(K, n_ctx_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, C * g, hd_v), lambda kk, j, bt: (kk, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C * g, 1), jnp.float32),
            pltpu.VMEM((C * g, 1), jnp.float32),
            pltpu.VMEM((C * g, hd_v), jnp.float32),
        ],
    )
    out_dtype = jnp.float32 if quantized else q.dtype
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, C * g, hd_v), out_dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), *operands)
    out = out.reshape(K, C, g, hd_v).transpose(1, 0, 2, 3)
    return out.reshape(C, H, hd_v).astype(q.dtype)


def check_block_table_bounds(
    block_tables,
    lengths,
    num_pages: int,
    page_size: int,
    trash_page: int = 0,
) -> None:
    """Host-side static bounds check of a decode call's block tables.

    The Pallas kernel's index maps are *unconditional*: every
    ``bt[b, j]`` entry is used as a DMA source page, valid or not.
    Out-of-range indices would read (and the engine's scatter-write
    would write) outside the pool, and a trash entry inside a row's
    covered range means a live token's KV was never given a real page.
    This check runs on the host arrays immediately before the kernel
    (under ``REPRO_SANITIZE``/``sanitize=True``) and in unit tests with
    adversarial tables.

    Parameters
    ----------
    block_tables : array_like, shape (B, pages_per_seq)
        Physical page ids per row (``trash_page`` marks padding).
    lengths : array_like, shape (B,)
        Valid tokens per row *excluding* the token being decoded (the
        engine's convention: the incoming token writes at position
        ``lengths[b]``); 0 marks a padding row.
    num_pages : int
        The allocator's pool size.
    page_size : int
        Tokens per page.
    trash_page : int, optional
        The reserved padding page id.

    Raises
    ------
    ValueError
        Naming the offending row/entry on any out-of-range index or
        any trash entry within a live row's covered page range.
    """
    import numpy as np

    bt = np.asarray(block_tables)
    lens = np.asarray(lengths)
    if bt.ndim != 2 or lens.shape != (bt.shape[0],):
        raise ValueError(
            f"shape mismatch: block_tables {bt.shape} vs lengths {lens.shape}"
        )
    bad = (bt < 0) | (bt >= num_pages)
    if bad.any():
        b, j = map(int, np.argwhere(bad)[0])
        raise ValueError(
            f"block-table entry out of pool bounds: bt[{b}, {j}] = "
            f"{int(bt[b, j])} not in [0, {num_pages})"
        )
    # a live row writes at position lengths[b]: pages 0..lengths[b]//ps
    # inclusive must be real pages
    cov = np.where(lens > 0, lens // page_size + 1, 0)
    if (cov > bt.shape[1]).any():
        b = int(np.argmax(cov > bt.shape[1]))
        raise ValueError(
            f"row {b} needs {int(cov[b])} pages for length {int(lens[b])} "
            f"but the block table holds only {bt.shape[1]}"
        )
    pos = np.arange(bt.shape[1])[None, :]
    covered_trash = (pos < cov[:, None]) & (bt == trash_page)
    if covered_trash.any():
        b, j = map(int, np.argwhere(covered_trash)[0])
        raise ValueError(
            f"trash page inside covered range: bt[{b}, {j}] is the trash "
            f"page but row {b} has length {int(lens[b])} "
            f"(covers {int(cov[b])} pages)"
        )


def check_scale_pool_finite(
    k_scales,
    v_scales,
    block_tables,
    lengths,
    page_size: int,
) -> None:
    """Host-side check that quantized pages' scales are finite and positive.

    A corrupted scale entry (NaN/inf/non-positive) inside a live row's
    covered range would poison every logit that touches the page — and
    unlike garbage K/V *values* (masked to softmax weight 0), a bad
    scale multiplies *valid* dequantized history.  Runs on host arrays
    under ``REPRO_SANITIZE``/``sanitize=True`` alongside
    :func:`check_block_table_bounds`.

    Parameters
    ----------
    k_scales, v_scales : array_like, shape (P, page_size, K)
        Per-page scale pools (float32).
    block_tables : array_like, shape (B, pages_per_seq)
        Physical page ids per row.
    lengths : array_like, shape (B,)
        Valid tokens per row *excluding* the token being decoded.
    page_size : int
        Tokens per page.

    Raises
    ------
    ValueError
        Naming the offending (row, page, slot) on the first bad scale
        covering a live token.
    """
    import numpy as np

    bt = np.asarray(block_tables)
    lens = np.asarray(lengths)
    for name, scales in (("k_scales", k_scales), ("v_scales", v_scales)):
        sc = np.asarray(scales)
        bad = ~np.isfinite(sc) | (sc <= 0)
        if not bad.any():
            continue
        # bad entries only matter where a live token's KV lives
        for b in range(bt.shape[0]):
            n = int(lens[b])
            for t in range(n):
                page, slot = int(bt[b, t // page_size]), t % page_size
                if bad[page, slot].any():
                    kh = int(np.argmax(bad[page, slot]))
                    raise ValueError(
                        f"{name}[{page}, {slot}, {kh}] = "
                        f"{float(sc[page, slot, kh])!r} covers live token "
                        f"{t} of row {b}: scales must be finite and > 0"
                    )
