"""Fused RMSNorm Pallas kernel.

Single HBM pass: each program normalizes a (block_rows, d) tile in VMEM —
mean-of-squares, rsqrt, scale by gamma — instead of the 3-pass unfused
jnp version (square+mean, rsqrt, multiply).  Rows = flattened (B, S).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    gamma: jax.Array,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out.reshape(orig_shape)
