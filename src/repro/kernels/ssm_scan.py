"""Mamba selective-scan Pallas kernel (chunked recurrence).

The recurrence h_t = exp(Δ_t·A)⊙h_{t-1} + (Δ_t·x_t)·B_t is sequential in
t, so the TPU-native layout makes t the innermost (sequential) grid dim in
chunks while (batch, channel-block) parallelize the outer grid.  The state
h (d_block, N) lives in VMEM scratch across the whole t-sweep — it never
touches HBM between chunks, which is the entire point: the GPU version
leans on warp-level scans in SRAM, the TPU version keeps the carried state
VMEM-resident and streams only x/Δ/B/C tiles.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(
    x_ref,    # (1, chunk, d_blk)
    dt_ref,   # (1, chunk, d_blk)
    a_ref,    # (d_blk, N)
    b_ref,    # (1, chunk, N)
    c_ref,    # (1, chunk, N)
    dskip_ref,  # (d_blk,)
    h0_ref,   # (1, d_blk, N)
    y_ref,    # (1, chunk, d_blk)
    hT_ref,   # (1, d_blk, N)
    h_scr,    # (d_blk, N) VMEM carry
    *,
    chunk: int,
):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)        # (chunk, d_blk)
    dt = dt_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)      # (d_blk, N)
    Bm = b_ref[0].astype(jnp.float32)       # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry
        dA = jnp.exp(dt[t][:, None] * A)                  # (d_blk, N)
        h = dA * h + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        y = jnp.sum(h * Cm[t][None, :], axis=1)           # (d_blk,)
        y_ref[0, t, :] = (
            y + x[t] * dskip_ref[...].astype(jnp.float32)
        ).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _out():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "d_block", "interpret")
)
def ssm_scan(
    x: jax.Array,       # (B, T, D)
    dt: jax.Array,      # (B, T, D)
    A: jax.Array,       # (D, N)
    Bm: jax.Array,      # (B, T, N)
    Cm: jax.Array,      # (B, T, N)
    D: jax.Array,       # (D,)
    h0: Optional[jax.Array] = None,
    chunk: int = 128,
    d_block: int = 512,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, Dd = x.shape
    N = A.shape[1]
    chunk = min(chunk, T)
    d_block = min(d_block, Dd)
    if T % chunk or Dd % d_block:
        # fall back to the oracle for ragged shapes
        from .ref import ssm_scan_ref

        return ssm_scan_ref(x, dt, A, Bm, Cm, D, h0=h0)
    h0 = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((B, Dd, N), jnp.float32)
    )
    nd = Dd // d_block
    nt = T // chunk

    y, hT = pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, chunk, d_block), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((d_block, N), lambda b, d, t: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, t: (b, t, 0)),
            pl.BlockSpec((d_block,), lambda b, d, t: (d,)),
            pl.BlockSpec((1, d_block, N), lambda b, d, t: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b, d, t: (b, t, d)),
            pl.BlockSpec((1, d_block, N), lambda b, d, t: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, Dd), x.dtype),
            jax.ShapeDtypeStruct((B, Dd, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((d_block, N))],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D, h0)
    return y, hT


def _vmem(shape):
    import jax.experimental.pallas.tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)


def _compiler_params():
    import jax.experimental.pallas.tpu as pltpu

    try:
        # jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
    except (AttributeError, TypeError):
        return pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        )
