"""Batching-aware duration calibration (paper §IV-B, Eq. 2).

The duration of an LLM task depends on the number of concurrently batched
requests on its executor.  The paper profiles the average per-token decode
latency ``l(b)`` at each batch size b and rescales a duration estimate
``d_r`` recorded at batch size ``b_r`` to a target batch size ``b_t``:

    d_t = d_r * l(b_t) / l(b_r)                                  (Eq. 2)

On TPU the profile is a roofline effect: decode is memory-bound, so a step
reads the full weight set + the batch's KV cache once per token.  Batching
amortizes the weight reads across requests:

    l(b) ≈ (W_bytes + b * KV_bytes) / (b * HBM_bw)   (per-request·token)

We support both a measured profile (from the serving engine / testbed) and
this analytic roofline profile (used by the simulator and for archs we
cannot run at full size).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional, Sequence

import numpy as np


@dataclass
class LatencyProfile:
    """Per-token decode latency l(b) for batch sizes 1..max_batch."""

    batch_sizes: np.ndarray
    latency: np.ndarray  # seconds per generated token, per request

    def __post_init__(self) -> None:
        self.batch_sizes = np.asarray(self.batch_sizes, dtype=np.int64)
        self.latency = np.asarray(self.latency, dtype=np.float64)
        order = np.argsort(self.batch_sizes)
        self.batch_sizes = self.batch_sizes[order]
        self.latency = self.latency[order]

    def l(self, b: int) -> float:
        """l(b) with linear interpolation / edge clamping."""
        b = max(1, int(b))
        return float(np.interp(b, self.batch_sizes, self.latency))

    def calibrate(self, d_r: float, b_r: int, b_t: int) -> float:
        """Eq. (2): rescale duration d_r observed at batch b_r to batch b_t."""
        lr = self.l(b_r)
        if lr <= 0:
            return d_r
        return d_r * self.l(b_t) / lr


def roofline_profile(
    weight_bytes: float,
    kv_bytes_per_request: float,
    hbm_bw: float = 819e9,
    max_batch: int = 256,
    step_overhead_s: float = 2e-5,
) -> LatencyProfile:
    """Analytic l(b) for a memory-bound decode step on one TPU v5e chip.

    One decode step streams all weights once plus each request's KV cache;
    per-token latency for a single request in a batch of b is the step time
    (weights amortized over the batch, KV not amortized).
    """
    bs = np.arange(1, max_batch + 1)
    step_time = (weight_bytes + bs * kv_bytes_per_request) / hbm_bw + step_overhead_s
    return LatencyProfile(batch_sizes=bs, latency=step_time)


def measured_profile(samples: Mapping[int, Sequence[float]]) -> LatencyProfile:
    """Build a profile from measured {batch_size: [per-token latencies]}."""
    bs = sorted(samples)
    lat = [float(np.mean(samples[b])) for b in bs]
    return LatencyProfile(batch_sizes=np.array(bs), latency=np.array(lat))
