"""Quality gates + cascade-escalation model shared by sim and testbed.

LLM-Modulo-style verifier gating: every finished LLM stage output is
checked by a pluggable :class:`QualityGate`; a rejected output is
*escalated* — the task re-enters the pending queue with its
``tier_floor`` raised one cost rank above the tier that failed, so the
retry provably runs on a more capable model (the prompt re-enters
through the normal admission path and hits the destination replica's
prefix cache where pages are compatible).  A rejection on the fleet's
top tier cannot escalate: the output is kept and the job is marked
quality-failed in ``RunMetrics.quality_by_job``.

The reference gate is *deterministic*: whether attempt ``k`` of stage
``(job, stage, index)`` passes on a tier of quality ``q`` is a pure
function of ``(seed, app, stage, index, attempt, q, strictness)`` —
no shared RNG stream is consumed, so enabling the gate never perturbs
the simulator's arrival/failure draws, and replays are byte-stable
regardless of event order.  The pass rule is

``fail  ⇔  difficulty(app, stage) > q  and  draw < strictness``

with ``draw`` a per-attempt uniform derived by hashing the identity
tuple.  Because the draw is shared across strictness values, the set of
failing attempts grows monotonically with strictness — which makes the
total cascade cost monotone in strictness (property-tested).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "QualityGate",
    "DeterministicGate",
    "stage_difficulty",
    "cascade_cost",
    "fleet_ranks",
]


def stage_difficulty(app: str, stage: str) -> float:
    """Ground-truth difficulty of a stage type, in [0, 1).

    A stable hash of the ``(application, stage)`` template names — the
    same stage is equally hard in every job, seed, and runtime, which
    keeps sim↔testbed gate outcomes comparable.  Hidden from the
    scheduler (like true durations): only the gate consults it.

    Parameters
    ----------
    app : str
        Application template name (e.g. ``"WebSearch"``).
    stage : str
        Stage template name within the app.

    Returns
    -------
    float
        Difficulty in ``[0, 1)`` — compared against a tier's
        ``quality`` by the gate.
    """
    h = zlib.crc32(f"{app}\x1f{stage}".encode())
    return (h % 10_000) / 10_000.0


def _attempt_draw(
    seed: int, app: str, stage: str, index: int, attempt: int
) -> float:
    """Deterministic uniform in [0, 1) for one gate evaluation."""
    h = zlib.crc32(
        f"{seed}\x1f{app}\x1f{stage}\x1f{index}\x1f{attempt}".encode()
    )
    return float(np.random.default_rng(h).random())


class QualityGate:
    """Pluggable verifier over LLM stage outputs.

    Subclasses implement :meth:`passes`; runtimes call it once per
    completed LLM attempt and escalate on ``False`` (when a higher tier
    exists).  Implementations must be pure in their arguments — the
    runtimes may re-evaluate during replay.
    """

    def passes(
        self, app: str, stage: str, index: int, attempt: int, quality: float
    ) -> bool:
        """Judge one stage output.

        Parameters
        ----------
        app, stage, index : str, str, int
            Identity of the stage output under judgment.
        attempt : int
            0 for the first execution, +1 per cascade escalation.
        quality : float
            The serving tier's quality score in [0, 1]
            (``TierSpec.quality``).

        Returns
        -------
        bool
            True to accept the output.
        """
        raise NotImplementedError


@dataclass(frozen=True)
class DeterministicGate(QualityGate):
    """The reference hash-seeded gate (see module docstring).

    Parameters
    ----------
    strictness : float
        In [0, 1]: the probability that an out-of-depth output
        (difficulty above the tier's quality) is rejected.  ``0``
        accepts everything (gate provably inert); ``1`` rejects every
        out-of-depth output.
    seed : int
        Domain-separates the per-attempt draws from every other RNG
        stream in a run.
    """

    strictness: float
    seed: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.strictness <= 1.0:
            raise ValueError(
                f"strictness must be in [0, 1], got {self.strictness}"
            )

    def passes(
        self, app: str, stage: str, index: int, attempt: int, quality: float
    ) -> bool:
        """Accept unless the stage is out of depth and the draw condemns it.

        Parameters
        ----------
        app, stage, index, attempt : str, str, int, int
            Identity tuple keying the deterministic draw.
        quality : float
            Serving tier's quality in [0, 1].

        Returns
        -------
        bool
            True to accept; shared-draw construction makes the set of
            rejections monotone in :attr:`strictness`.
        """
        if stage_difficulty(app, stage) <= quality:
            return True
        return _attempt_draw(
            self.seed, app, stage, index, attempt
        ) >= self.strictness


def cascade_cost(
    app: str,
    stage: str,
    index: int,
    tokens: int,
    tiers: Sequence[Tuple[float, float]],
    gate: QualityGate,
    start_rank: int = 0,
) -> Tuple[float, int, bool]:
    """Walk one stage up the cascade and total its serving cost.

    The closed-form escalation model the runtimes implement
    event-by-event: run the stage on ``tiers[start_rank]``; on gate
    rejection move one rank up and retry (attempt counter
    incrementing), paying every visited tier's price for the stage's
    tokens; a top-rank rejection terminates without acceptance.

    Parameters
    ----------
    app, stage, index : str, str, int
        Stage identity (keys the gate's deterministic draws).
    tokens : int
        Generated tokens per attempt.
    tiers : sequence of (float, float)
        ``(cost_per_token, quality)`` per tier, cheapest first
        (ascending cost rank).
    gate : QualityGate
        The verifier.
    start_rank : int, optional
        Tier rank of the first attempt.

    Returns
    -------
    (float, int, bool)
        Total cost over all attempts, number of escalations, and
        whether the final output was accepted.
    """
    cost = 0.0
    escalations = 0
    rank = start_rank
    for attempt in range(len(tiers) - start_rank):
        c, q = tiers[rank]
        cost += tokens * c
        if gate.passes(app, stage, index, attempt, q):
            return cost, escalations, True
        if rank + 1 >= len(tiers):
            return cost, escalations, False
        rank += 1
        escalations += 1
    return cost, escalations, False


def fleet_ranks(costs: Sequence[float]) -> List[int]:
    """Dense cost ranks of a replica fleet (0 = cheapest tier).

    Parameters
    ----------
    costs : sequence of float
        Per-replica cost per generated token.

    Returns
    -------
    list of int
        Rank of each replica's tier; replicas with equal cost share a
        rank.  The same rule the scheduler applies to
        ``ClusterView.llm_model_costs``, so runtime escalation floors
        and scheduler placement agree.
    """
    order = sorted(set(costs))
    return [order.index(c) for c in costs]
