"""DAG-based model for compound LLM applications (paper §IV-A).

A compound LLM application is a DAG whose nodes are *stages* and whose
edges are input→output dependencies.  Three stage types:

- ``REGULAR``  : one or more non-LLM tasks, run on regular executors.
- ``LLM``      : one or more LLM inference tasks, run on LLM executors
                 (batched, up to the executor's max batch size).
- ``DYNAMIC``  : placeholder for LLM-generated stages + dependencies,
                 realized at runtime from a *candidate set* once the
                 preceding LLM stage completes.

Chain-like applications are *padded* to their maximum iteration count
(paper §IV-A); stages of skipped iterations simply never execute (their
duration is 0 — the BN models this with a dedicated "not executed" bin).
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple


class StageType(enum.Enum):
    REGULAR = "regular"
    LLM = "llm"
    DYNAMIC = "dynamic"


#: Recognized SLO tiers, strictest first.  ``interactive`` jobs are
#: deadline-boosted whenever their deadline falls inside the scheduler's
#: plan-ahead window; ``batch`` jobs only once their worst-case duration
#: bound projects a miss; ``best_effort`` jobs are never boosted (their
#: deadline only matters for goodput accounting and infeasibility
#: demotion).
SLO_TIERS = ("interactive", "batch", "best_effort")


@dataclass(frozen=True)
class SLO:
    """Service-level objective attached to a :class:`Job`.

    Attributes
    ----------
    tier : str
        One of :data:`SLO_TIERS` — controls how aggressively the
        scheduler boosts the job as its deadline approaches.
    deadline : float
        Absolute completion deadline in workload seconds (same clock as
        ``Job.arrival_time``).  A job *meets* its SLO when
        ``finish_time <= deadline``.
    """

    tier: str
    deadline: float

    def __post_init__(self) -> None:
        if self.tier not in SLO_TIERS:
            raise ValueError(
                f"unknown SLO tier {self.tier!r}; expected one of {SLO_TIERS}"
            )


@dataclass
class StageTemplate:
    """Static description of a stage inside an application template."""

    name: str
    stype: StageType
    num_tasks: int = 1
    # For DYNAMIC stages: candidate stage names the planner LLM may emit,
    # and the possible edges between them.
    candidates: Tuple[str, ...] = ()
    candidate_edges: Tuple[Tuple[str, str], ...] = ()
    # Marginal execution probability (used for entropy of regular stages
    # and for padding chains); refined by the BN profiler from history.
    exec_prob: float = 1.0


@dataclass
class ApplicationTemplate:
    """An application = template DAG over stage templates.

    ``edges`` are (parent_name, child_name) pairs.  Stage IDs are assigned
    in topological order (paper Fig. 4 numbering).
    """

    name: str
    stages: List[StageTemplate]
    edges: List[Tuple[str, str]]

    def __post_init__(self) -> None:
        self._by_name: Dict[str, StageTemplate] = {s.name: s for s in self.stages}
        if len(self._by_name) != len(self.stages):
            raise ValueError(f"duplicate stage names in template {self.name}")
        for u, v in self.edges:
            if u not in self._by_name or v not in self._by_name:
                raise ValueError(f"edge ({u},{v}) references unknown stage")
        self._topo = self._topo_sort()
        self.stage_ids: Dict[str, int] = {n: i for i, n in enumerate(self._topo)}

    # -- graph helpers -----------------------------------------------------
    def _topo_sort(self) -> List[str]:
        indeg = {s.name: 0 for s in self.stages}
        adj: Dict[str, List[str]] = {s.name: [] for s in self.stages}
        for u, v in self.edges:
            adj[u].append(v)
            indeg[v] += 1
        # Stable Kahn: preserve declaration order among ready nodes.
        order: List[str] = []
        ready = [s.name for s in self.stages if indeg[s.name] == 0]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in adj[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self.stages):
            raise ValueError(f"cycle detected in template {self.name}")
        return order

    def parents(self, name: str) -> List[str]:
        return [u for u, v in self.edges if v == name]

    def children(self, name: str) -> List[str]:
        return [v for u, v in self.edges if u == name]

    def stage(self, name: str) -> StageTemplate:
        return self._by_name[name]

    def topo_order(self) -> List[str]:
        return list(self._topo)

    def descendants(self, name: str) -> Set[str]:
        out: Set[str] = set()
        frontier = [name]
        while frontier:
            n = frontier.pop()
            for c in self.children(n):
                if c not in out:
                    out.add(c)
                    frontier.append(c)
        return out


class TaskState(enum.Enum):
    PENDING = 0
    RUNNING = 1
    DONE = 2


@dataclass
class Task:
    """A runtime task — the schedulable unit."""

    job_id: int
    stage_name: str
    index: int
    is_llm: bool
    # Ground-truth duration at batch size 1 (sim) / realized at runtime
    # (testbed).  Hidden from the scheduler until completion.
    true_duration: float = 0.0
    state: TaskState = TaskState.PENDING
    start_time: float = -1.0
    finish_time: float = -1.0
    # Populated for LLM tasks: number of output tokens (drives batching-
    # aware calibration in the simulator).
    out_tokens: int = 0
    # Cascade state (heterogeneous pools only; inert defaults elsewhere):
    # a failed quality gate re-enqueues the task with its minimum model-
    # tier *cost rank* raised one above the tier that failed, and bumps
    # the attempt counter that keys the gate's deterministic draws.
    tier_floor: int = 0
    attempt: int = 0


@dataclass
class Stage:
    """Runtime instance of a stage template within a job."""

    job_id: int
    template: StageTemplate
    tasks: List[Task] = field(default_factory=list)
    # Whether this stage will actually execute in this job (chains may stop
    # early; dynamic stages may not select a candidate).  Hidden from the
    # scheduler until revealed.
    will_execute: bool = True
    revealed: bool = False          # structure known to the scheduler?
    dispatched_tasks: int = 0       # how many tasks handed to executors

    @property
    def name(self) -> str:
        return self.template.name

    @property
    def stype(self) -> StageType:
        return self.template.stype

    def done(self) -> bool:
        """Ground-truth completion (simulator/runtime internal)."""
        return self.will_execute is False or (
            len(self.tasks) > 0 and all(t.state is TaskState.DONE for t in self.tasks)
        )

    def obs_done(self) -> bool:
        """Observable completion — what a scheduler may act on.  A stage
        that will never execute counts only once that fact is *revealed*;
        otherwise skipped-at-runtime chains would leak their length."""
        if self.revealed and not self.will_execute:
            return True
        return len(self.tasks) > 0 and all(
            t.state is TaskState.DONE for t in self.tasks
        )

    def running(self) -> bool:
        return any(t.state is TaskState.RUNNING for t in self.tasks)

    def pending_tasks(self) -> List[Task]:
        return [t for t in self.tasks if t.state is TaskState.PENDING]

    def duration(self) -> float:
        """Realized duration (max finish - min start over tasks); 0 if skipped."""
        if not self.will_execute:
            return 0.0
        ts = [t for t in self.tasks if t.state is TaskState.DONE]
        if not ts:
            return 0.0
        return max(t.finish_time for t in ts) - min(t.start_time for t in ts)


_job_counter = itertools.count()


@dataclass
class Job:
    """Runtime instance of an application with a specific user input."""

    app: ApplicationTemplate
    arrival_time: float
    job_id: int = field(default_factory=lambda: next(_job_counter))
    stages: Dict[str, Stage] = field(default_factory=dict)
    # Realized dynamic-stage expansions: stage name -> (chosen candidates,
    # chosen edges).  Populated by the workload generator; revealed to the
    # scheduler only when the parent LLM stage finishes.
    dynamic_realization: Dict[str, Tuple[Tuple[str, ...], Tuple[Tuple[str, str], ...]]] = field(
        default_factory=dict
    )
    # Parents of stages created at runtime (dynamic-stage expansion) and
    # extra parents grafted onto template stages (e.g. a dynamic stage's
    # children must wait for the expanded inner stages).
    extra_parents: Dict[str, List[str]] = field(default_factory=dict)
    # trigger stage name -> stage names whose existence it reveals (chains)
    reveal_rules: Dict[str, List[str]] = field(default_factory=dict)
    # Optional service-level objective (tier + absolute deadline).  None
    # (default) keeps the job deadline-blind: SLO-aware schedulers must
    # emit byte-identical decisions for workloads where every job is
    # SLO-less (golden-trajectory guarded).
    slo: Optional[SLO] = None
    finish_time: float = -1.0
    # Monotonic counter bumped by the runtime on every event that changes
    # this job's *observable* state (task dispatch/completion, stage
    # reveal, dynamic expansion, failure requeue).  Incremental schedulers
    # key their cross-round caches on it: while the version is unchanged,
    # BN evidence, remaining-duration bases, duration bounds, and
    # uncertainty-reduction scores are all provably stale-free.
    evidence_version: int = 0

    def bump_evidence(self) -> None:
        """Record an observable-state change (invalidates cached estimates)."""
        self.evidence_version += 1

    # -- dependency/readiness ---------------------------------------------
    def parents_of(self, name: str) -> List[str]:
        tpl = self.app.parents(name) if name in self.app.stage_ids else []
        return tpl + [p for p in self.extra_parents.get(name, []) if p not in tpl]

    def stage_ready(self, name: str, now_done: Optional[Set[str]] = None) -> bool:
        """A stage is ready when every parent that *will execute* is done.

        Stages whose existence has not yet been revealed (chain iterations
        beyond the frontier, unexpanded dynamic stages) are never ready —
        the scheduler cannot see work it does not know exists.
        """
        st = self.stages[name]
        if st.done() or not st.will_execute or not st.revealed:
            return False
        if not st.pending_tasks():  # fully dispatched (possibly still running)
            return False
        for p in self.parents_of(name):
            ps = self.stages.get(p)
            if ps is None:
                continue
            if ps.will_execute and not ps.done():
                return False
        return True

    def _stage_order(self) -> List[str]:
        tpl = [n for n in self.app.topo_order() if n in self.stages]
        extra = [n for n in self.stages if n not in self.app.stage_ids]
        return tpl + extra

    def ready_stages(self) -> List[Stage]:
        return [self.stages[n] for n in self._stage_order() if self.stage_ready(n)]

    def unfinished_stages(self) -> List[Stage]:
        return [
            s for s in self.stages.values() if s.will_execute and not s.done()
        ]

    def done(self) -> bool:
        return all(s.done() for s in self.stages.values())

    def jct(self) -> float:
        return self.finish_time - self.arrival_time

    def met_slo(self, time_scale: float = 1.0) -> Optional[bool]:
        """Whether the finished job met its deadline.

        ``time_scale`` maps workload-clock deadlines onto a compressed
        runtime clock (the testbed divides arrivals by its time scale);
        the simulator uses the workload clock directly (scale 1).
        Returns ``None`` for SLO-less jobs.
        """
        if self.slo is None:
            return None
        return self.finish_time >= 0 and (
            self.finish_time <= self.slo.deadline / time_scale
        )

    # -- observable state for the scheduler --------------------------------
    def completed_durations(self) -> Dict[str, float]:
        """Evidence set E: batch-1-normalized durations of (partially)
        completed stages.

        LLM task durations observed at runtime are stretched by batching
        and queueing; the BN is trained on batch-1 service durations, so
        evidence uses the token-derived b=1 equivalent (out_tokens × l(1),
        carried as ``true_duration``).  Stages with *some* finished tasks
        contribute provisional evidence — this is what makes the paper's
        task-sampling exploration (ratio r) informative before the whole
        stage completes.
        """
        out = {}
        for n, s in self.stages.items():
            if not s.revealed or not s.will_execute or not s.tasks:
                continue
            done = [t for t in s.tasks if t.state is TaskState.DONE]
            if done:
                out[n] = float(sum(t.true_duration for t in done) / len(done))
        return out

    def observed_skips(self) -> Dict[str, bool]:
        """Stages revealed as will-not-execute (chains that stopped)."""
        return {
            n: False
            for n, s in self.stages.items()
            if s.revealed and not s.will_execute
        }


def make_job(app: ApplicationTemplate, arrival_time: float) -> Job:
    """Instantiate a job skeleton (all stages, nothing revealed yet)."""
    job = Job(app=app, arrival_time=arrival_time)
    for st in app.stages:
        stage = Stage(job_id=job.job_id, template=st)
        stage.tasks = [
            Task(
                job_id=job.job_id,
                stage_name=st.name,
                index=i,
                is_llm=(st.stype is StageType.LLM),
            )
            for i in range(st.num_tasks)
        ]
        job.stages[st.name] = stage
    return job
