"""Unified run-metrics schema shared by the simulator and the testbed.

Before the SLO PR the two runtimes kept hand-synchronized result shapes
(``SimResult`` in :mod:`repro.sim.simulator`, ``TestbedResult`` in
:mod:`repro.serving.cluster`) whose fields drifted one kwarg at a time.
:class:`RunMetrics` is the single schema both now return (the old names
remain as aliases), so the parity canaries and the fig7/fig8/fig9
benchmark writers consume one type instead of two.

SLO accounting: when jobs carry :class:`repro.core.dag.SLO` objectives,
the runtimes record per-job tier/deadline/attainment and
:meth:`RunMetrics.goodput` reports the paper-style *goodput* —
the fraction of finished jobs that met their deadline — overall and per
tier.  SLO-less runs leave the SLO fields empty and ``goodput`` returns
``None``, keeping pre-SLO artifacts byte-compatible.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class RunMetrics:
    """Aggregate outcome of one simulator or testbed run.

    Attributes
    ----------
    jcts : list of float
        Per-job completion times (finish − arrival) in runtime seconds.
    jct_by_job : dict
        ``job_id → JCT`` for cross-run rank comparisons.
    sched_overhead_s : list of float
        Seconds spent inside ``scheduler.schedule`` per round.
    makespan : float
        Total runtime seconds from start to last completion.
    preemptions : int
        Evictions/requeues (KV overflow, executor failure).
    reissues : int
        Speculative straggler re-issues (simulator only).
    migrations : int
        Live cross-replica LLM-task/KV moves.
    tokens_generated : int
        Decoded tokens across all engines (testbed only).
    prefill_tokens : float
        Prompt tokens actually run through prefill.
    prefill_saved_tokens : float
        Prompt tokens skipped via shared-prefix KV reuse.
    prefill_by_job : dict
        ``job_id → prefilled tokens`` (sim↔testbed cache parity).
    tier_by_job : dict
        ``job_id → SLO tier`` for jobs that carried an SLO.
    deadline_by_job : dict
        ``job_id → absolute deadline`` (workload clock).
    slo_met_by_job : dict
        ``job_id → bool`` — deadline attainment of finished SLO jobs.
    retractions : int
        Queued-but-undispatched scheduling decisions revisited after an
        evidence-version bump (SLO-aware schedulers only).
    cost_by_job : dict
        ``job_id → accumulated serving cost`` in cost units (per-token
        tier price × generated tokens, summed over every completed LLM
        attempt *including* attempts a quality gate rejected — wasted
        spend is real spend).  Empty when the fleet has no tier table.
    quality_by_job : dict
        ``job_id → bool`` — whether every gated LLM stage of the job
        was ultimately accepted by the quality gate (a stage that
        exhausts the cascade at the top tier and still fails marks the
        job ``False``).  Empty when no gate ran.
    escalations : int
        Cascade retries: gate-rejected stages re-enqueued one model
        tier up.
    """

    jcts: List[float] = field(default_factory=list)
    jct_by_job: Dict[int, float] = field(default_factory=dict)
    sched_overhead_s: List[float] = field(default_factory=list)
    makespan: float = 0.0
    preemptions: int = 0
    reissues: int = 0
    migrations: int = 0
    tokens_generated: int = 0
    prefill_tokens: float = 0.0
    prefill_saved_tokens: float = 0.0
    prefill_by_job: Dict[int, float] = field(default_factory=dict)
    # --- SLO / deadline bookkeeping (empty for SLO-less runs) ---------
    tier_by_job: Dict[int, str] = field(default_factory=dict)
    deadline_by_job: Dict[int, float] = field(default_factory=dict)
    slo_met_by_job: Dict[int, bool] = field(default_factory=dict)
    retractions: int = 0
    # --- cost / cascade bookkeeping (empty for single-tier runs) ------
    cost_by_job: Dict[int, float] = field(default_factory=dict)
    quality_by_job: Dict[int, bool] = field(default_factory=dict)
    escalations: int = 0

    @property
    def avg_jct(self) -> float:
        """Mean job completion time in seconds (0.0 when empty)."""
        return float(np.mean(self.jcts)) if self.jcts else 0.0

    @property
    def p95_jct(self) -> float:
        """95th-percentile job completion time in seconds."""
        return float(np.percentile(self.jcts, 95)) if self.jcts else 0.0

    @property
    def avg_overhead_ms(self) -> float:
        """Mean scheduler invocation latency in milliseconds."""
        return (
            1e3 * float(np.mean(self.sched_overhead_s))
            if self.sched_overhead_s
            else 0.0
        )

    def goodput(self, tier: Optional[str] = None) -> Optional[float]:
        """SLO attainment: fraction of SLO jobs that met their deadline.

        Parameters
        ----------
        tier : str, optional
            Restrict to one tier (``interactive`` / ``batch`` /
            ``best_effort``); ``None`` aggregates every SLO job.

        Returns
        -------
        float or None
            Attainment in [0, 1], or ``None`` when no (matching) job
            carried an SLO — distinguishing "no SLOs" from "all missed".
        """
        ids = [
            j
            for j in self.slo_met_by_job
            if tier is None or self.tier_by_job.get(j) == tier
        ]
        if not ids:
            return None
        return float(np.mean([self.slo_met_by_job[j] for j in ids]))

    def goodput_by_tier(self) -> Dict[str, float]:
        """Per-tier SLO attainment over the tiers present in this run.

        Every tier that appears in ``tier_by_job`` appears in the
        result.  A tier whose jobs all went unfinished (preempted,
        demoted, still queued at cutoff) has attained nothing —
        it reports ``0.0`` rather than being silently omitted, so
        benchmark aggregations never mistake "all missed" for
        "tier absent".
        """
        tiers = sorted(set(self.tier_by_job.values()))
        out: Dict[str, float] = {}
        for t in tiers:
            g = self.goodput(t)
            out[t] = 0.0 if g is None else g
        return out

    @property
    def total_cost(self) -> float:
        """Summed serving cost across jobs (0.0 without a tier table)."""
        return float(sum(self.cost_by_job.values()))

    def cost_efficiency(self) -> Optional[float]:
        """Quality-accepted finished jobs per unit of serving cost.

        The numerator counts finished jobs whose every gated stage was
        ultimately accepted (all finished jobs when no gate ran), so a
        pool that is merely cheap cannot win by emitting rejected
        output; the denominator is :attr:`total_cost`.

        Returns
        -------
        float or None
            Accepted jobs per cost unit, or ``None`` when the run
            recorded no cost (no tier table — efficiency undefined).
        """
        total = self.total_cost
        if total <= 0.0:
            return None
        if self.quality_by_job:
            ok = sum(
                1
                for j in self.jct_by_job
                if self.quality_by_job.get(j, True)
            )
        else:
            ok = len(self.jct_by_job)
        return ok / total
