"""Uncertainty-aware scheduler — Algorithm 1 of the paper (§IV-D).

Schedulers are shared between the discrete-event simulator (`repro.sim`)
and the real serving runtime (`repro.serving`): both call
:meth:`Scheduler.schedule` with the current unfinished jobs and a
:class:`ClusterView`, and dispatch tasks greedily from the returned
preference lists (``T_r`` for regular executors, ``T_l`` for LLM
executors) onto free capacity.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .calibration import LatencyProfile
from .dag import Job, Stage, StageType, Task
from .profiler import ProfileStore


@dataclass
class ClusterView:
    """What the scheduler may observe about the cluster."""

    now: float
    free_regular: int
    # per-LLM-executor (running batch size, max batch size)
    llm_loads: List[Tuple[int, int]]
    latency_profile: Optional[LatencyProfile] = None

    def llm_free_slots(self) -> int:
        return sum(max(0, mb - b) for b, mb in self.llm_loads)

    def current_batch(self) -> int:
        return max((b for b, _ in self.llm_loads), default=0)

    def target_batch(self) -> int:
        """Batch size an incoming task is likely to run at (for Eq. 2)."""
        if not self.llm_loads:
            return 1
        b, mb = min(self.llm_loads, key=lambda t: t[0])
        return min(b + 1, mb)


@dataclass
class Decision:
    """Ordered scheduling preference lists (Algorithm 1 output)."""

    regular: List[Task] = field(default_factory=list)
    llm: List[Task] = field(default_factory=list)


class Scheduler:
    name = "base"

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        raise NotImplementedError

    # Hook for schedulers that learn online (Decima).
    def observe_completion(self, job: Job, now: float) -> None:  # pragma: no cover
        pass


# ---------------------------------------------------------------------------
# LLMSched (Algorithm 1)
# ---------------------------------------------------------------------------
class LLMSched(Scheduler):
    """ε-greedy combination of uncertainty reduction and SRTF.

    ``use_bn=False``           → "LLMSched w/o BN" ablation (historical means).
    ``epsilon=0``              → "LLMSched w/o uncertainty" ablation (pure SRTF).
    ``incremental=True``       → cross-round caching: per-job BN evidence,
    remaining-duration bases, duration bounds, and uncertainty scores are
    memoized against ``Job.evidence_version`` and recomputed only for jobs
    the runtime reported events for (stage completion, dispatch, reveal).
    Emits decisions identical to ``incremental=False``; the flag only
    moves work out of the per-round hot path.
    """

    name = "llmsched"

    def __init__(
        self,
        profiles: ProfileStore,
        epsilon: float = 0.3,
        sampling_ratio: float = 0.3,
        use_bn: bool = True,
        seed: int = 0,
        incremental: bool = True,
    ) -> None:
        self.profiles = profiles
        self.epsilon = float(epsilon)
        self.sampling_ratio = float(sampling_ratio)
        self.use_bn = use_bn
        self.incremental = bool(incremental)
        self.rng = np.random.default_rng(seed)
        # caches invalidated per-call; uncertainty scores are reused across
        # ε draws within one invocation.
        self._ur_cache: Dict[Tuple[int, str], float] = {}
        # calibration-context tracking: the latency profile object only
        # changes identity when new measurements arrive, so (epoch, b_t)
        # keys the batching-calibrated remaining-duration cache.
        self._last_profile = None
        self._calib_epoch = 0
        # cross-round ready-stage cache (readiness is pure within a
        # job's evidence version: it only changes on dispatch/completion/
        # reveal events, all of which bump the version)
        self._ready_cache: Dict[int, Tuple[int, List[Stage]]] = {}

    # -- helpers -------------------------------------------------------------
    def _version(self, job: Job) -> Optional[int]:
        return job.evidence_version if self.incremental else None

    def _ready_stages(self, job: Job) -> List[Stage]:
        if not self.incremental:
            return job.ready_stages()
        v = job.evidence_version
        hit = self._ready_cache.get(job.job_id)
        if hit is not None and hit[0] == v:
            return hit[1]
        rs = job.ready_stages()
        self._ready_cache[job.job_id] = (v, rs)
        return rs

    def _calibrator(self, view: ClusterView) -> Callable[[Stage, float], float]:
        prof = view.latency_profile
        if prof is None:
            return lambda stage, est: est

        b_t = view.target_batch()

        def cal(stage: Stage, est: float) -> float:
            if stage.stype is StageType.LLM:
                # historical estimates are recorded at batch size 1
                return prof.calibrate(est, b_r=1, b_t=b_t)
            return est

        return cal

    def _calib_sig(self, view: ClusterView) -> Tuple:
        """Hashable token capturing everything the calibrator depends on."""
        prof = view.latency_profile
        if prof is None:
            return ("none",)
        if prof is not self._last_profile:
            self._last_profile = prof
            self._calib_epoch += 1
        return (self._calib_epoch, view.target_batch())

    def est_rd(self, job: Job, view: ClusterView) -> float:
        p = self.profiles.get(job.app.name)
        if p is None:
            return float("inf")
        return p.est_remaining(
            job,
            view.now,
            calibrate=self._calibrator(view),
            use_bn=self.use_bn,
            version=self._version(job),
            calib_key=self._calib_sig(view),
        )

    def _uncert(self, job: Job, stage: Stage) -> float:
        return self._uncert_batch(job, [stage])[0]

    def _uncert_batch(self, job: Job, stages: Sequence[Stage]) -> List[float]:
        """R(stage) for several ready stages of one job, with one BN pass."""
        miss = [s for s in stages if (job.job_id, s.name) not in self._ur_cache]
        if miss:
            p = self.profiles.get(job.app.name)
            if p is None:
                vals = [0.0] * len(miss)
            else:
                vals = p.stage_uncertainty_reductions(
                    job, [s.name for s in miss], version=self._version(job)
                )
            for s, v in zip(miss, vals):
                self._ur_cache[(job.job_id, s.name)] = v
        return [self._ur_cache[(job.job_id, s.name)] for s in stages]

    @staticmethod
    def non_overlapping_sets(
        bounds: List[Tuple[float, float, Job]]
    ) -> List[List[Job]]:
        """Group jobs whose duration intervals overlap (line 5).

        Jobs within a group cannot be ordered with certainty; between
        groups the ordering is certain.  Groups come back ordered by lower
        bound.
        """
        if not bounds:
            return []
        los = np.asarray([b[0] for b in bounds], dtype=np.float64)
        his = np.asarray([b[1] for b in bounds], dtype=np.float64)
        return LLMSched._group_by_overlap(los, his, [b[2] for b in bounds])

    @staticmethod
    def _group_by_overlap(
        los: np.ndarray, his: np.ndarray, jobs: List[Job]
    ) -> List[List[Job]]:
        """Vectorized interval grouping: sort by (lo, hi), then break a
        group wherever an interval's lo exceeds the running max of hi."""
        n = len(jobs)
        if n == 0:
            return []
        order = np.lexsort((his, los))  # stable; primary lo, secondary hi
        slo = los[order]
        cummax = np.maximum.accumulate(his[order])
        starts = np.empty(n, dtype=bool)
        starts[0] = True
        if n > 1:
            starts[1:] = slo[1:] > cummax[:-1]
        gid = np.cumsum(starts) - 1
        groups: List[List[Job]] = [[] for _ in range(int(gid[-1]) + 1)]
        for k in range(n):
            groups[int(gid[k])].append(jobs[int(order[k])])
        return groups

    # -- Algorithm 1 -----------------------------------------------------------
    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        self._ur_cache.clear()
        jobs = [j for j in jobs if not j.done()]
        if not jobs:
            return Decision()

        # ready stages once per job per round (reused for S_t and S_u;
        # cached across rounds for jobs without new events)
        ready: Dict[int, List[Stage]] = {
            j.job_id: self._ready_stages(j) for j in jobs
        }

        # lines 1-4: S_t — ready stages in SRTF order of their job
        j_t = sorted(jobs, key=lambda j: (self.est_rd(j, view), j.arrival_time))
        s_t: List[Stage] = []
        for job in j_t:
            s_t.extend(ready[job.job_id])

        # lines 5-10: S_u — stages by uncertainty reduction within
        # non-overlapping job groups (bounds gathered into numpy arrays)
        n = len(jobs)
        los = np.empty(n, dtype=np.float64)
        his = np.empty(n, dtype=np.float64)
        for i, job in enumerate(jobs):
            p = self.profiles.get(job.app.name)
            lo, hi = (
                p.job_bounds(job, use_bn=self.use_bn, version=self._version(job))
                if p
                else (0.0, math.inf)
            )
            los[i] = lo
            his[i] = hi
        s_u: List[Stage] = []
        for group in self._group_by_overlap(los, his, list(jobs)):
            # only genuinely uncertainty-reducing stages are exploration
            # candidates (paper §IV-B: stages correlated with ≥1 other)
            scored: List[Tuple[float, Stage]] = []
            for job in group:
                rs = ready[job.job_id]
                if rs:
                    scored.extend(zip(self._uncert_batch(job, rs), rs))
            scored = [(r, s) for r, s in scored if r > 0.0]
            scored.sort(key=lambda t: -t[0])
            s_u.extend(s for _, s in scored)

        # lines 11-20: ε-greedy merge
        return self._merge(s_t, s_u)

    def observe_completion(self, job: Job, now: float) -> None:
        """Evict the finished job's slots from the cross-round caches."""
        self._ready_cache.pop(job.job_id, None)
        p = self.profiles.get(job.app.name)
        if p is not None:
            p.forget_job(job.job_id)

    def _merge(self, s_t: List[Stage], s_u: List[Stage]) -> Decision:
        dec = Decision()
        taken: set = set()
        deferred: List[Task] = []
        s_t = list(s_t)
        s_u = list(s_u)

        def pop_next(lst: List[Stage]) -> Optional[Stage]:
            while lst:
                s = lst.pop(0)
                if id(s) not in taken:
                    return s
            return None

        def attach(tasks: List[Task]) -> None:
            for t in tasks:
                (dec.llm if t.is_llm else dec.regular).append(t)

        while s_t and s_u:
            st = pop_next(s_t)
            su = pop_next(s_u)
            if st is None and su is None:
                break
            p = self.rng.random()
            if p < self.epsilon and su is not None:
                taken.add(id(su))
                pending = su.pending_tasks()
                if su is st:
                    # exploration pick coincides with the SRTF head: run it
                    # fully — sampling would only defer the exploit choice.
                    attach(pending)
                    continue
                k = max(1, math.ceil(self.sampling_ratio * len(pending)))
                attach(pending[:k])
                deferred.extend(pending[k:])
                if st is not None:
                    s_t.insert(0, st)  # not consumed this round
            elif st is not None:
                taken.add(id(st))
                attach(st.pending_tasks())
                if su is not None:
                    s_u.insert(0, su)
            elif su is not None:
                taken.add(id(su))
                attach(su.pending_tasks())

        # line 21: whatever list still has stages + sampled remainders
        for s in s_t + s_u:
            if id(s) not in taken:
                taken.add(id(s))
                attach(s.pending_tasks())
        attach(deferred)
        return dec
