"""Uncertainty-aware scheduler — Algorithm 1 of the paper (§IV-D).

Schedulers are shared between the discrete-event simulator (`repro.sim`)
and the real serving runtime (`repro.serving`): both call
:meth:`Scheduler.schedule` with the current unfinished jobs and a
:class:`ClusterView`, and dispatch tasks greedily from the returned
preference lists (``T_r`` for regular executors, ``T_l`` for LLM
executors) onto free capacity.

Since the multi-replica PR, :class:`ClusterView` additionally carries
per-replica KV headroom (``llm_free_tokens``) and :class:`Decision`
carries a *placement* map assigning each LLM task to a specific engine
replica.  :class:`LLMSched` fills the map with an uncertainty- and
fragmentation-aware score (high-entropy jobs land where KV headroom is
largest); runtimes that ignore the map — and schedulers that never fill
it — keep the historical least-loaded behaviour.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .calibration import LatencyProfile
from .dag import SLO_TIERS, Job, Stage, StageType, Task
from .profiler import ProfileStore

# Key type of Decision.placement: (job_id, stage_name, task index).
TaskKey = Tuple[int, str, int]


def task_key(task: Task) -> TaskKey:
    """Return the stable identity of ``task`` used by placement maps.

    Parameters
    ----------
    task : Task
        Any runtime task.

    Returns
    -------
    tuple of (int, str, int)
        ``(job_id, stage_name, index)`` — unique within a workload and
        stable across scheduling rounds (unlike ``id(task)``).
    """
    return (task.job_id, task.stage_name, task.index)


@dataclass
class ClusterView:
    """What the scheduler may observe about the cluster.

    Attributes
    ----------
    now : float
        Current (simulated or wall-clock) time in seconds.
    free_regular : int
        Number of idle regular-executor slots.
    llm_loads : list of (int, int)
        Per-LLM-replica ``(running batch size, max batch size)``.
    latency_profile : LatencyProfile, optional
        Measured ``l(b)`` per-token decode latency, for Eq. 2 batching
        calibration.  ``None`` before any measurement exists.
    llm_free_tokens : list of int, optional
        Per-LLM-replica free KV capacity in *tokens* (free pages ×
        page size for paged engines).  ``None`` when the runtime has no
        paged KV accounting (e.g. the simulator or the slot engine);
        placement then falls back to pure load balancing.
    llm_prefix_hit_tokens : list of int, optional
        Per-LLM-replica estimate of reusable prefix KV in *tokens*
        (the radix index's resident cached tokens for paged engines
        with prefix caching; the modeled shared-prompt residency in the
        simulator).  A task landing on a replica with more resident
        prefix tokens is more likely to skip prefill work.  ``None``
        when no replica runs a prefix cache — the placement score then
        degenerates exactly to its cache-blind form (an all-zero list
        degenerates identically).
    llm_model_costs : list of float, optional
        Per-LLM-replica serving cost in *cost units per generated
        token* (the model-zoo tier table,
        :func:`repro.models.zoo.cost_per_token`).  Present only when
        every replica's model resolves to a known tier; a homogeneous
        fleet (all costs equal) contributes nothing to the placement
        score, so cost-blind trajectories are byte-identical.
    """

    now: float
    free_regular: int
    # per-LLM-executor (running batch size, max batch size)
    llm_loads: List[Tuple[int, int]]
    latency_profile: Optional[LatencyProfile] = None
    # per-LLM-executor free KV capacity in tokens (None: not paged)
    llm_free_tokens: Optional[List[int]] = None
    # per-LLM-executor resident reusable-prefix tokens (None: no cache)
    llm_prefix_hit_tokens: Optional[List[int]] = None
    # per-LLM-executor cost per generated token (None: single-tier or
    # unresolved models)
    llm_model_costs: Optional[List[float]] = None

    def llm_free_slots(self) -> int:
        """Return the total number of free batch slots across replicas.

        Returns
        -------
        int
            Sum over replicas of ``max_batch - batch``.
        """
        return sum(max(0, mb - b) for b, mb in self.llm_loads)

    def current_batch(self) -> int:
        """Return the largest running batch size across replicas.

        Returns
        -------
        int
            ``max(batch)`` over replicas, 0 when there are none.
        """
        return max((b for b, _ in self.llm_loads), default=0)

    def target_batch(self) -> int:
        """Return the batch size an incoming task is likely to run at.

        Used as ``b_t`` in the paper's Eq. 2 batching-aware latency
        calibration: the least-loaded replica's batch plus one.

        Returns
        -------
        int
            Expected batch size for the next admitted task (≥ 1).
        """
        if not self.llm_loads:
            return 1
        b, mb = min(self.llm_loads, key=lambda t: t[0])
        return min(b + 1, mb)

    @classmethod
    def assemble(
        cls,
        now: float,
        free_regular: int,
        llm_loads: Sequence[Tuple[int, int]],
        latency_profile: Optional[LatencyProfile] = None,
        llm_free_tokens: Optional[Sequence[Optional[int]]] = None,
        llm_prefix_hit_tokens: Optional[Sequence[Optional[int]]] = None,
        llm_model_costs: Optional[Sequence[Optional[float]]] = None,
    ) -> "ClusterView":
        """Build a view — the single construction point for both runtimes.

        ``ServingCluster`` and ``ClusterSim`` used to assemble the field
        list by hand, which is how optional per-replica fields can
        silently drift between the two.  This helper owns the shared
        gating rule: an optional per-replica list containing *any*
        ``None`` or non-finite entry (some replica cannot report the
        signal, or reports garbage) collapses to ``None`` for the whole
        fleet, so schedulers never see a partially-populated signal; a
        list whose length disagrees with ``llm_loads`` raises — a
        misaligned signal would silently score replica *i* with replica
        *j*'s headroom, which is worse than no signal at all.

        Parameters
        ----------
        now : float
            Current runtime time in seconds.
        free_regular : int
            Idle regular-executor slots.
        llm_loads : sequence of (int, int)
            Per-replica ``(batch, max_batch)``.
        latency_profile : LatencyProfile, optional
            Measured/modeled ``l(b)``.
        llm_free_tokens : sequence of int or None, optional
            Per-replica free KV tokens (entries may be ``None``).
        llm_prefix_hit_tokens : sequence of int or None, optional
            Per-replica resident prefix tokens (entries may be ``None``).
        llm_model_costs : sequence of float or None, optional
            Per-replica cost per generated token (entries may be
            ``None`` for replicas whose model has no tier entry).

        Returns
        -------
        ClusterView
            The gated, fully-constructed view.

        Raises
        ------
        ValueError
            When an optional per-replica list is not one-entry-per-
            replica.
        """
        llm_loads = list(llm_loads)

        def gate(name, vals):
            if vals is None:
                return None
            vals = list(vals)
            if len(vals) != len(llm_loads):
                raise ValueError(
                    f"{name} has {len(vals)} entries for "
                    f"{len(llm_loads)} replicas — per-replica signals "
                    "must align with llm_loads"
                )
            if any(v is None or not math.isfinite(v) for v in vals):
                return None
            return vals

        return cls(
            now=now,
            free_regular=free_regular,
            llm_loads=llm_loads,
            latency_profile=latency_profile,
            llm_free_tokens=gate("llm_free_tokens", llm_free_tokens),
            llm_prefix_hit_tokens=gate(
                "llm_prefix_hit_tokens", llm_prefix_hit_tokens
            ),
            llm_model_costs=gate("llm_model_costs", llm_model_costs),
        )


@dataclass
class Decision:
    """Ordered scheduling preference lists (Algorithm 1 output).

    Attributes
    ----------
    regular : list of Task
        Tasks for regular executors, most-preferred first.
    llm : list of Task
        Tasks for LLM executors, most-preferred first.
    placement : dict
        Optional map from :func:`task_key` to a replica index in
        ``ClusterView.llm_loads``.  Runtimes treat it as a *hint*: a
        task whose placed replica cannot admit it falls back to the
        least-loaded admissible replica.  Schedulers that never call
        :meth:`place` leave it empty (historical behaviour).
    """

    regular: List[Task] = field(default_factory=list)
    llm: List[Task] = field(default_factory=list)
    placement: Dict[TaskKey, int] = field(default_factory=dict)

    def place(self, task: Task, replica: int) -> None:
        """Record that ``task`` should run on LLM replica ``replica``.

        Parameters
        ----------
        task : Task
            An LLM task present in :attr:`llm`.
        replica : int
            Index into ``ClusterView.llm_loads``.
        """
        self.placement[task_key(task)] = replica

    def replica_for(self, task: Task) -> Optional[int]:
        """Return the placed replica index for ``task``.

        Parameters
        ----------
        task : Task
            The task being dispatched.

        Returns
        -------
        int or None
            The replica hint, or ``None`` when the scheduler did not
            place this task (caller should use its own fallback).
        """
        return self.placement.get(task_key(task))


@dataclass
class _SloPlan:
    """One job's plan-ahead snapshot, pinned to an evidence version.

    The calibrated remaining-duration bounds are frozen when the plan is
    made; only the runtime clock advances against them between evidence
    events.  Slack therefore shrinks *monotonically* on static evidence,
    which is what makes retraction convergent (a job can move toward
    urgent/infeasible as time passes but never oscillate back without a
    new-evidence bump).
    """

    version: int          # Job.evidence_version the bounds were cached at
    calib: Tuple          # calibration signature (profile epoch, b_t)
    lo_raw: float         # batch-1 optimistic remaining duration (s) —
                          # the true best case, used for the provable-miss
                          # (infeasibility) test so batching slowdown can
                          # never falsely condemn a winnable job
    lo_cal: float         # calibrated optimistic remaining duration (s)
    hi_cal: float         # calibrated pessimistic remaining duration (s)


class Scheduler:
    """Abstract scheduler interface shared by the sim and the testbed."""

    name = "base"

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        """Produce ordered dispatch preference lists for one round.

        Parameters
        ----------
        jobs : sequence of Job
            All unfinished jobs currently known to the runtime.
        view : ClusterView
            Observable cluster state at this scheduling instant.

        Returns
        -------
        Decision
            Preference-ordered task lists (and optional placement).
        """
        raise NotImplementedError

    # Hook for schedulers that learn online (Decima).
    def observe_completion(self, job: Job, now: float) -> None:  # pragma: no cover
        """Notify the scheduler that ``job`` finished at time ``now``.

        Parameters
        ----------
        job : Job
            The job that just completed.
        now : float
            Completion time in runtime seconds.
        """
        pass


# ---------------------------------------------------------------------------
# LLMSched (Algorithm 1)
# ---------------------------------------------------------------------------
class LLMSched(Scheduler):
    """ε-greedy combination of uncertainty reduction and SRTF.

    ``use_bn=False``           → "LLMSched w/o BN" ablation (historical means).
    ``epsilon=0``              → "LLMSched w/o uncertainty" ablation (pure SRTF).
    ``incremental=True``       → cross-round caching: per-job BN evidence,
    remaining-duration bases, duration bounds, and uncertainty scores are
    memoized against ``Job.evidence_version`` and recomputed only for jobs
    the runtime reported events for (stage completion, dispatch, reveal).
    Emits decisions identical to ``incremental=False``; the flag only
    moves work out of the per-round hot path.

    Multi-replica placement: after building the preference lists, each
    LLM task is assigned a replica with the score

    ``score(e) = w_u · kv_headroom(e) − (1 − w_u) · load(e)
    + w_c · prefix_hit(e) − w_m · (1 − ρ) · cost(e)``

    where ``w_u = 0.25 + 0.5·u`` and ``u ∈ [0, 1]`` is the job's
    normalized duration-bound width (entropy proxy).  Certain jobs
    (``u → 0``) weight the load term — they bin-pack tightly for low
    decode latency; uncertain jobs (``u → 1``) weight KV headroom —
    their unpredictable expansion needs room to grow without triggering
    eviction.  ``prefix_hit(e)`` is the replica's resident reusable-
    prefix tokens (``ClusterView.llm_prefix_hit_tokens``) normalized by
    the fleet maximum, weighted by the fixed cache weight ``w_cache``:
    compound-app tasks steered to the replica already holding their
    shared prompt's KV skip that prefill entirely.  When the view
    carries no prefix info (``None``) the term is omitted, and when it
    is all-zero the term contributes exactly ``0.0`` to every
    candidate — either way the score is bit-identical to the
    cache-blind form, so seeded trajectories are unchanged.  When the
    view has no KV accounting (``llm_free_tokens is None``), placement
    degenerates to least-loaded-by-absolute-batch (prefix residency
    breaking ties ahead of the index when known, which the all-zero
    and ``None`` cases again leave byte-identical) — including
    heterogeneous ``max_batch`` fleets — preserving the historical
    dispatcher behaviour byte-for-byte.

    Cost-aware model routing (heterogeneous pools): when the view
    carries per-replica per-token costs (``llm_model_costs``, from the
    model-zoo tier table) *and* they differ across the fleet, the score
    gains ``− w_m · (1 − ρ) · ĉ(e)`` where ``ĉ(e)`` is the replica's
    cost normalized by the fleet maximum and ``ρ ∈ [0, 1]`` is the
    stage's routing signal — the mean of the job's duration-bound
    uncertainty ``u`` and the stage's cached BN uncertainty reduction
    ``R̂`` normalized by this round's maximum.  The term is a price
    penalty scaled by how *routine* the stage is: stages expected to
    reduce much uncertainty (or belonging to wide-bound jobs) have
    ``ρ → 1`` and place cost-indifferently — the evidence they produce
    is worth the premium — while routine stages (``ρ → 0``) crowd onto
    the cheap tiers, keeping premium capacity free for work that earns
    it.  That is uncertainty-reduction-per-cost routing.  A homogeneous fleet (costs absent or all equal)
    contributes exactly nothing, so single-tier trajectories are
    byte-identical to the cost-blind scheduler.  Cascade re-admission:
    a task whose ``tier_floor`` was raised by a failed quality gate is
    only placed on replicas whose cost *rank* meets the floor — the
    retry provably runs one tier up.  Floors are also *learned* per
    (app, stage template): once a stage type has been escalated to
    rank ``r``, later first attempts of the same type start at ``r``
    directly, skipping the attempts a deterministic gate is guaranteed
    to reject (cost-aware routing only — the ``w_model = 0`` ablation
    keeps paying them, which is exactly the frontier gap fig10
    measures).

    SLO-tiered deadline scheduling: jobs carrying a
    :class:`repro.core.dag.SLO` are scheduled against their absolute
    deadline with three mechanisms (all inert — byte-identical decisions
    — when no job in the system carries an SLO):

    - **plan-ahead** — each SLO job's remaining-duration bounds
      (``AppProfile.job_bounds``, cached per evidence version) are
      calibrated by the measured ``l(b)`` latency model and projected
      against its deadline over the next ``plan_ahead_s`` seconds;
    - **deadline-miss-aware ordering** — provably infeasible jobs (the
      *optimistic* bound already overshoots the deadline) are demoted
      below all feasible work so they stop claiming KV pages first;
      tight-slack ``interactive`` jobs whose deadline falls inside the
      window are boosted ahead of the SRTF order (EDF among
      themselves), and ``batch`` jobs are boosted only once their
      *pessimistic* bound projects a miss; ``best_effort`` jobs are
      never boosted.  Placement still uses the uncertainty/KV score —
      boosted jobs simply reserve headroom first, and demoted jobs are
      left unplaced (no KV reservation);
    - **retraction** — the plan snapshot is pinned per
      ``Job.evidence_version``: when the runtime bumps a job's version
      (stage completion, reveal, dispatch), the queued-but-undispatched
      plan is *retracted* and rebuilt from the tightened bounds
      (``retractions`` counts these).  Running tasks are never
      retracted — preference lists only ever contain pending tasks, so
      token-equality and migration invariants are untouched.

    Parameters
    ----------
    profiles : ProfileStore
        Fitted per-application BN profiles (duration + structure).
    epsilon : float, optional
        Exploration probability of Algorithm 1's ε-greedy merge.
    sampling_ratio : float, optional
        Fraction of an explored stage's tasks dispatched immediately.
    use_bn : bool, optional
        Use Bayesian-network posteriors (``False``: historical means).
    seed : int, optional
        Seed of the exploration RNG.
    incremental : bool, optional
        Enable cross-round caching keyed by ``Job.evidence_version``.
    plan_ahead_s : float, optional
        Plan-ahead window W in seconds: only deadlines within
        ``now + W`` can trigger an urgency boost.  Infeasibility
        demotion applies regardless of the window.
    slo_aware : bool, optional
        Gate the SLO machinery entirely.  ``False`` makes the scheduler
        deadline-blind even on SLO-carrying workloads (identical
        decisions to an SLO-less run) — the ablation baseline the
        goodput benchmark compares against.
    check_invariants : bool, optional
        Validate every decision against the declarative invariant
        catalog in :mod:`repro.analysis.invariants` (no running-task
        retraction, demoted jobs unplaced, placement bounds, plan
        snapshots pinned to current evidence/calibration, EDF order of
        the urgent bucket), raising
        :class:`~repro.analysis.invariants.InvariantViolation` on the
        first bad round.  Observation-only: the decision stream is
        identical with checking on or off.
    """

    name = "llmsched"

    #: Tokens of KV headroom assumed consumed by one placed-but-not-yet-
    #: running LLM task (the scheduler cannot see true output lengths,
    #: which are ground truth hidden until completion).
    kv_reserve_tokens = 64

    #: Weight of the cache-affinity term in the placement score.  Small
    #: relative to the uncertainty/load terms: prefix reuse is a cost
    #: saving, not a correctness constraint, and must not override KV
    #: headroom for high-uncertainty jobs.
    w_cache = 0.2

    #: Weight of the cost-aware routing term on heterogeneous pools.
    #: ``0.0`` yields the cost-blind router ablation (placement ignores
    #: tier prices; tier floors from cascade escalation still bind).
    w_model = 0.3

    def __init__(
        self,
        profiles: ProfileStore,
        epsilon: float = 0.3,
        sampling_ratio: float = 0.3,
        use_bn: bool = True,
        seed: int = 0,
        incremental: bool = True,
        plan_ahead_s: float = 30.0,
        slo_aware: bool = True,
        check_invariants: bool = False,
    ) -> None:
        self.profiles = profiles
        self.epsilon = float(epsilon)
        self.sampling_ratio = float(sampling_ratio)
        self.use_bn = use_bn
        self.incremental = bool(incremental)
        self.plan_ahead_s = float(plan_ahead_s)
        self.slo_aware = bool(slo_aware)
        self.check_invariants = bool(check_invariants)
        # urgent-bucket sort keys of the latest round, recorded for the
        # EDF invariant (None until _slo_order runs with checking on)
        self._last_urgent_keys: Optional[List[Tuple]] = None
        self.rng = np.random.default_rng(seed)
        # SLO plan-ahead state: per-job plan snapshots pinned to the
        # job's evidence version (see _SloPlan), plus public counters.
        self._slo_plans: Dict[int, _SloPlan] = {}
        self._demoted: set = set()
        #: queued plans revisited after an evidence/calibration change
        self.retractions = 0
        #: jobs newly classified provably deadline-infeasible
        self.demotions = 0
        # caches invalidated per-call; uncertainty scores are reused across
        # ε draws within one invocation.
        self._ur_cache: Dict[Tuple[int, str], float] = {}
        # calibration-context tracking: the latency profile object only
        # changes identity when new measurements arrive, so (epoch, b_t)
        # keys the batching-calibrated remaining-duration cache.
        self._last_profile = None
        self._calib_epoch = 0
        # cross-round ready-stage cache (readiness is pure within a
        # job's evidence version: it only changes on dispatch/completion/
        # reveal events, all of which bump the version)
        self._ready_cache: Dict[int, Tuple[int, List[Stage]]] = {}
        # learned cascade floors: (app, stage template) → the highest
        # tier rank a gate rejection has forced that stage type up to.
        # Future first attempts of the same type start there instead of
        # re-paying the doomed cheap attempts (cost-aware routing only;
        # stays empty on homogeneous or unpriced fleets).
        self._tier_prior: Dict[Tuple[str, str], int] = {}
        self._app_by_job: Dict[int, str] = {}

    # -- helpers -------------------------------------------------------------
    def _version(self, job: Job) -> Optional[int]:
        return job.evidence_version if self.incremental else None

    def _ready_stages(self, job: Job) -> List[Stage]:
        if not self.incremental:
            return job.ready_stages()
        v = job.evidence_version
        hit = self._ready_cache.get(job.job_id)
        if hit is not None and hit[0] == v:
            return hit[1]
        rs = job.ready_stages()
        self._ready_cache[job.job_id] = (v, rs)
        return rs

    def _calibrator(self, view: ClusterView) -> Callable[[Stage, float], float]:
        prof = view.latency_profile
        if prof is None:
            return lambda stage, est: est

        b_t = view.target_batch()

        def cal(stage: Stage, est: float) -> float:
            if stage.stype is StageType.LLM:
                # historical estimates are recorded at batch size 1
                return prof.calibrate(est, b_r=1, b_t=b_t)
            return est

        return cal

    def _calib_sig(self, view: ClusterView) -> Tuple:
        """Hashable token capturing everything the calibrator depends on."""
        prof = view.latency_profile
        if prof is None:
            return ("none",)
        if prof is not self._last_profile:
            self._last_profile = prof
            self._calib_epoch += 1
        return (self._calib_epoch, view.target_batch())

    def est_rd(self, job: Job, view: ClusterView) -> float:
        """Estimate ``job``'s remaining duration (SRTF key).

        Parameters
        ----------
        job : Job
            The job to estimate.
        view : ClusterView
            Cluster state — supplies ``now`` and the batching-aware
            latency calibration context (Eq. 2).

        Returns
        -------
        float
            Expected remaining seconds; ``inf`` when the application
            has no fitted profile.
        """
        p = self.profiles.get(job.app.name)
        if p is None:
            return float("inf")
        return p.est_remaining(
            job,
            view.now,
            calibrate=self._calibrator(view),
            use_bn=self.use_bn,
            version=self._version(job),
            calib_key=self._calib_sig(view),
        )

    def _uncert(self, job: Job, stage: Stage) -> float:
        return self._uncert_batch(job, [stage])[0]

    def _uncert_batch(self, job: Job, stages: Sequence[Stage]) -> List[float]:
        """R(stage) for several ready stages of one job, with one BN pass."""
        miss = [s for s in stages if (job.job_id, s.name) not in self._ur_cache]
        if miss:
            p = self.profiles.get(job.app.name)
            if p is None:
                vals = [0.0] * len(miss)
            else:
                vals = p.stage_uncertainty_reductions(
                    job, [s.name for s in miss], version=self._version(job)
                )
            for s, v in zip(miss, vals):
                self._ur_cache[(job.job_id, s.name)] = v
        return [self._ur_cache[(job.job_id, s.name)] for s in stages]

    @staticmethod
    def non_overlapping_sets(
        bounds: List[Tuple[float, float, Job]]
    ) -> List[List[Job]]:
        """Group jobs whose duration intervals overlap (line 5).

        Jobs within a group cannot be ordered with certainty; between
        groups the ordering is certain.

        Parameters
        ----------
        bounds : list of (float, float, Job)
            Per-job ``(lower, upper)`` remaining-duration bounds.

        Returns
        -------
        list of list of Job
            Overlap groups, ordered by lower bound.
        """
        if not bounds:
            return []
        los = np.asarray([b[0] for b in bounds], dtype=np.float64)
        his = np.asarray([b[1] for b in bounds], dtype=np.float64)
        return LLMSched._group_by_overlap(los, his, [b[2] for b in bounds])

    @staticmethod
    def _group_by_overlap(
        los: np.ndarray, his: np.ndarray, jobs: List[Job]
    ) -> List[List[Job]]:
        """Vectorized interval grouping: sort by (lo, hi), then break a
        group wherever an interval's lo exceeds the running max of hi."""
        n = len(jobs)
        if n == 0:
            return []
        order = np.lexsort((his, los))  # stable; primary lo, secondary hi
        slo = los[order]
        cummax = np.maximum.accumulate(his[order])
        starts = np.empty(n, dtype=bool)
        starts[0] = True
        if n > 1:
            starts[1:] = slo[1:] > cummax[:-1]
        gid = np.cumsum(starts) - 1
        groups: List[List[Job]] = [[] for _ in range(int(gid[-1]) + 1)]
        for k in range(n):
            groups[int(gid[k])].append(jobs[int(order[k])])
        return groups

    # -- Algorithm 1 -----------------------------------------------------------
    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        """Run Algorithm 1 and return placement-annotated preferences.

        Parameters
        ----------
        jobs : sequence of Job
            All unfinished jobs.
        view : ClusterView
            Observable cluster state.

        Returns
        -------
        Decision
            SRTF/uncertainty ε-greedy merged task lists; every LLM task
            additionally carries a replica placement hint (see class
            docstring for the placement score).
        """
        self._ur_cache.clear()
        if self.check_invariants:
            self._last_urgent_keys = None
        jobs = [j for j in jobs if not j.done()]
        if not jobs:
            return Decision()

        # ready stages once per job per round (reused for S_t and S_u;
        # cached across rounds for jobs without new events)
        ready: Dict[int, List[Stage]] = {
            j.job_id: self._ready_stages(j) for j in jobs
        }

        # per-job remaining-duration bounds (Algorithm 1 line 5; also the
        # SLO plan-ahead input).  Cached per evidence version, so hoisting
        # the computation above the SRTF sort changes nothing numerically.
        n = len(jobs)
        los = np.empty(n, dtype=np.float64)
        his = np.empty(n, dtype=np.float64)
        for i, job in enumerate(jobs):
            p = self.profiles.get(job.app.name)
            lo, hi = (
                p.job_bounds(job, use_bn=self.use_bn, version=self._version(job))
                if p
                else (0.0, math.inf)
            )
            los[i] = lo
            his[i] = hi

        # lines 1-4: S_t — ready stages in SRTF order of their job;
        # SLO-aware deadline ordering reshuffles the *job* order (boost /
        # demote) only when at least one job actually carries an SLO —
        # SLO-less workloads keep the historical order byte-for-byte.
        j_t = sorted(jobs, key=lambda j: (self.est_rd(j, view), j.arrival_time))
        if self.slo_aware and any(j.slo is not None for j in jobs):
            j_t = self._slo_order(j_t, view, dict(zip(
                (j.job_id for j in jobs), zip(los, his)
            )))
        s_t: List[Stage] = []
        for job in j_t:
            s_t.extend(ready[job.job_id])

        # lines 5-10: S_u — stages by uncertainty reduction within
        # non-overlapping job groups (bounds gathered into numpy arrays)
        s_u: List[Stage] = []
        for group in self._group_by_overlap(los, his, list(jobs)):
            # only genuinely uncertainty-reducing stages are exploration
            # candidates (paper §IV-B: stages correlated with ≥1 other)
            scored: List[Tuple[float, Stage]] = []
            for job in group:
                rs = ready[job.job_id]
                if rs:
                    scored.extend(zip(self._uncert_batch(job, rs), rs))
            scored = [(r, s) for r, s in scored if r > 0.0]
            scored.sort(key=lambda t: -t[0])
            s_u.extend(s for _, s in scored)

        # lines 11-20: ε-greedy merge
        dec = self._merge(s_t, s_u)

        # multi-replica placement: duration-bound width as the entropy
        # proxy (same arrays that drove the grouping above)
        self._app_by_job = {j.job_id: j.app.name for j in jobs}
        self._place_llm(dec, view, self._job_uncertainty(jobs, los, his))

        if self.check_invariants:
            # imported lazily: the analysis package must stay optional
            # on the scheduling hot path
            from ..analysis.invariants import check_decision

            check_decision(self, jobs, view, dec)
        return dec

    # -- SLO plan-ahead / retraction ----------------------------------------
    def _slo_plan_for(
        self, job: Job, view: ClusterView, lo: float, hi: float
    ) -> _SloPlan:
        """Return the job's plan snapshot, retracting a stale one.

        The snapshot pins the calibrated duration bounds to the job's
        current ``evidence_version`` and calibration context.  A cached
        plan made under an older version (or a different measured
        ``l(b)`` epoch / target batch) is *retracted*: the queued
        decision it backed is revisited with fresh bounds.  Running
        tasks are untouched — plans only shape the ordering of pending
        tasks.

        Parameters
        ----------
        job : Job
            An unfinished job carrying an SLO.
        view : ClusterView
            Supplies the l(b) calibration context.
        lo, hi : float
            Raw (batch-1) remaining-duration bounds from the profile.

        Returns
        -------
        _SloPlan
            The current (possibly freshly rebuilt) snapshot.
        """
        sig = self._calib_sig(view)
        plan = self._slo_plans.get(job.job_id)
        if (
            plan is not None
            and plan.version == job.evidence_version
            and plan.calib == sig
        ):
            return plan
        if plan is not None:
            self.retractions += 1
        prof = view.latency_profile
        stretch = (
            prof.calibrate(1.0, b_r=1, b_t=view.target_batch())
            if prof is not None
            else 1.0
        )
        plan = _SloPlan(
            version=job.evidence_version,
            calib=sig,
            lo_raw=lo,
            lo_cal=lo * stretch,
            hi_cal=hi * stretch,
        )
        self._slo_plans[job.job_id] = plan
        return plan

    def _slo_order(
        self,
        j_t: List[Job],
        view: ClusterView,
        bounds: Dict[int, Tuple[float, float]],
    ) -> List[Job]:
        """Deadline-aware reorder of the SRTF job list (boost / demote).

        Three buckets, each preserving SRTF order internally unless
        stated: **urgent** SLO jobs — deadline inside the plan-ahead
        window AND at risk (the calibrated pessimistic bound projects a
        miss); interactive/batch only, never best-effort — move to the
        front in (tier, pessimistic-slack, deadline) order.  Jobs with
        comfortable slack stay in SRTF order even inside the window, so
        deadline pressure perturbs the JCT-optimal order no more than
        necessary.  **Infeasible** jobs (the *batch-1 optimistic* bound
        already overshoots the deadline — a provable miss even in the
        best case) move behind all feasible work; everything else keeps
        its SRTF position.

        Parameters
        ----------
        j_t : list of Job
            Jobs in SRTF order (the historical ordering).
        view : ClusterView
            Supplies ``now`` and the calibration context.
        bounds : dict
            ``job_id → (lo, hi)`` raw remaining-duration bounds.

        Returns
        -------
        list of Job
            The reordered job list.
        """
        now = view.now
        window_end = now + self.plan_ahead_s
        urgent: List[Tuple[int, float, float, float, Job]] = []
        normal: List[Job] = []
        infeasible: List[Job] = []
        demoted_now: set = set()
        for job in j_t:
            slo = job.slo
            if slo is None:
                normal.append(job)
                continue
            lo, hi = bounds[job.job_id]
            plan = self._slo_plan_for(job, view, lo, hi)
            remaining = slo.deadline - now
            if plan.lo_raw > remaining:
                # provable miss: even the batch-1 optimistic bound
                # overshoots — stop spending prime capacity (and KV
                # pages) on it
                demoted_now.add(job.job_id)
                if job.job_id not in self._demoted:
                    self.demotions += 1
                infeasible.append(job)
                continue
            at_risk = plan.hi_cal > remaining
            boost = (
                slo.deadline <= window_end
                and at_risk
                and slo.tier != "best_effort"
            )
            if boost:
                urgent.append((
                    SLO_TIERS.index(slo.tier),
                    remaining - plan.hi_cal,   # pessimistic slack
                    slo.deadline,
                    job.arrival_time,
                    job,
                ))
            else:
                normal.append(job)
        urgent.sort(key=lambda t: t[:4])
        self._demoted = demoted_now
        if self.check_invariants:
            self._last_urgent_keys = [t[:4] for t in urgent]
        return [t[4] for t in urgent] + normal + infeasible

    @staticmethod
    def _job_uncertainty(
        jobs: Sequence[Job], los: np.ndarray, his: np.ndarray
    ) -> Dict[int, float]:
        """Normalize duration-bound widths to per-job u ∈ [0, 1]."""
        widths = his - los
        finite = widths[np.isfinite(widths)]
        wmax = float(finite.max()) if finite.size else 0.0
        out: Dict[int, float] = {}
        for job, w in zip(jobs, widths):
            if not math.isfinite(w):
                out[job.job_id] = 1.0
            elif wmax <= 0.0:
                out[job.job_id] = 0.0
            else:
                out[job.job_id] = min(1.0, max(0.0, float(w) / wmax))
        return out

    def _place_llm(
        self,
        dec: Decision,
        view: ClusterView,
        uncertainty: Dict[int, float],
    ) -> None:
        """Assign each LLM task a replica via the routing score.

        Projects batch occupancy and KV headroom forward as tasks are
        placed, so one round's placements never overcommit a replica.
        Without ``llm_free_tokens`` *and* without differing per-replica
        costs the score reduces to least-loaded (prefix residency
        breaks ties when known, then lowest index) — identical to the
        pre-placement dispatchers whenever the view carries no (or
        all-zero) prefix info, keeping seeded single/multi-replica sim
        trajectories unchanged.  Tasks carrying a cascade
        ``tier_floor`` are restricted to replicas whose cost rank meets
        the floor whenever the fleet's tiers are known; on cost-aware
        heterogeneous fleets the floor a retry carries is also
        remembered per (app, stage template), so later first attempts
        of a proven-hard stage type start at the proven tier.
        """
        n = len(view.llm_loads)
        if n == 0 or not dec.llm:
            return
        proj_b = [b for b, _ in view.llm_loads]
        mbs = [mb for _, mb in view.llm_loads]
        free_tok = (
            list(view.llm_free_tokens)
            if view.llm_free_tokens is not None
            else None
        )
        hit_tok = view.llm_prefix_hit_tokens
        hit_norm = (
            [h / max(max(hit_tok), 1) for h in hit_tok]
            if hit_tok is not None
            else [0.0] * n
        )
        # cost signal: dense rank per replica (0 = cheapest tier) plus a
        # fleet-max-normalized cost.  A homogeneous fleet (or a view
        # without costs) gates the routing term off entirely — not
        # merely uniformly — so such runs are byte-identical to the
        # cost-blind score.
        costs = view.llm_model_costs
        cost_norm: Optional[List[float]] = None
        ranks = [0] * n
        if costs is not None and len(set(costs)) > 1:
            cmax = max(costs)
            order = sorted(set(costs))
            ranks = [order.index(c) for c in costs]
            if cmax > 0.0 and self.w_model != 0.0:
                cost_norm = [c / cmax for c in costs]
        tiers_known = costs is not None
        # round-max of the cached stage uncertainty reductions: the
        # normalizer of the routing signal ρ
        ur_max = max(self._ur_cache.values(), default=0.0)
        for t in dec.llm:
            if t.job_id in self._demoted:
                # provably deadline-infeasible: runs only on leftover
                # capacity — reserve no KV headroom for it (the set is
                # empty for SLO-less workloads, keeping this a no-op)
                continue
            floor = getattr(t, "tier_floor", 0)
            if cost_norm is not None:
                # escalation-floor learning (cost-aware routing only):
                # a cascade retry proves its stage *type* out-of-depth
                # below its floor, so future first attempts of the same
                # (app, stage) start at the proven tier instead of
                # re-paying the doomed cheap attempts
                key = (self._app_by_job.get(t.job_id, ""), t.stage_name)
                if floor > 0:
                    if floor > self._tier_prior.get(key, 0):
                        self._tier_prior[key] = floor
                else:
                    prior = self._tier_prior.get(key, 0)
                    if prior:
                        floor = t.tier_floor = prior  # runtimes honour it
            u = uncertainty.get(t.job_id, 0.5)
            w = 0.25 + 0.5 * u
            best = None
            if free_tok is None and cost_norm is None:
                # no KV accounting: exact least-loaded by absolute batch
                # (decode latency is l(b) in the absolute batch size) —
                # byte-identical to the historical dispatchers, including
                # heterogeneous max_batch fleets; resident prefix tokens
                # (when reported) only break exact-load ties
                cands = [
                    e for e in range(n)
                    if proj_b[e] < mbs[e]
                    and not (tiers_known and ranks[e] < floor)
                ]
                if cands:
                    best = min(
                        cands, key=lambda e: (proj_b[e], -hit_norm[e], e)
                    )
            else:
                if cost_norm is not None:
                    ur = self._ur_cache.get((t.job_id, t.stage_name), 0.0)
                    rhat = ur / ur_max if ur_max > 0.0 else 0.0
                    rho = 0.5 * (u + rhat)
                best_score = -math.inf
                for e in range(n):
                    if mbs[e] <= 0 or proj_b[e] >= mbs[e]:
                        continue
                    if tiers_known and ranks[e] < floor:
                        continue  # cascade retry must run one tier up
                    if free_tok is not None and free_tok[e] <= 0:
                        continue  # no KV left: placing guarantees refusal
                    load = proj_b[e] / mbs[e]
                    kv = (
                        free_tok[e] / max(max(free_tok), 1)
                        if free_tok is not None
                        else 0.0
                    )
                    score = (
                        w * kv
                        - (1.0 - w) * load
                        + self.w_cache * hit_norm[e]
                    )
                    if cost_norm is not None:
                        # premium capacity costs score in proportion to
                        # how *routine* the stage is: high-ρ stages are
                        # cost-indifferent (their evidence is worth the
                        # premium), routine ones crowd onto cheap tiers
                        score -= (
                            self.w_model * (1.0 - rho) * cost_norm[e]
                        )
                    if score > best_score + 1e-12:
                        best, best_score = e, score
            if best is None:
                continue  # every replica projected full; runtime retries
            dec.place(t, best)
            proj_b[best] += 1
            if free_tok is not None:
                free_tok[best] = max(0, free_tok[best] - self.kv_reserve_tokens)

    def observe_completion(self, job: Job, now: float) -> None:
        """Evict the finished job's slots from the cross-round caches.

        Parameters
        ----------
        job : Job
            The job that just completed.
        now : float
            Completion time (unused; interface parity).
        """
        self._ready_cache.pop(job.job_id, None)
        self._slo_plans.pop(job.job_id, None)
        self._demoted.discard(job.job_id)
        p = self.profiles.get(job.app.name)
        if p is not None:
            p.forget_job(job.job_id)

    def _merge(self, s_t: List[Stage], s_u: List[Stage]) -> Decision:
        dec = Decision()
        taken: set = set()
        deferred: List[Task] = []
        s_t = list(s_t)
        s_u = list(s_u)

        def pop_next(lst: List[Stage]) -> Optional[Stage]:
            while lst:
                s = lst.pop(0)
                if id(s) not in taken:
                    return s
            return None

        def attach(tasks: List[Task]) -> None:
            for t in tasks:
                (dec.llm if t.is_llm else dec.regular).append(t)

        while s_t and s_u:
            st = pop_next(s_t)
            su = pop_next(s_u)
            if st is None and su is None:
                break
            p = self.rng.random()
            if p < self.epsilon and su is not None:
                taken.add(id(su))
                pending = su.pending_tasks()
                if su is st:
                    # exploration pick coincides with the SRTF head: run it
                    # fully — sampling would only defer the exploit choice.
                    attach(pending)
                    continue
                k = max(1, math.ceil(self.sampling_ratio * len(pending)))
                attach(pending[:k])
                deferred.extend(pending[k:])
                if st is not None:
                    s_t.insert(0, st)  # not consumed this round
            elif st is not None:
                taken.add(id(st))
                attach(st.pending_tasks())
                if su is not None:
                    s_u.insert(0, su)
            elif su is not None:
                taken.add(id(su))
                attach(su.pending_tasks())

        # line 21: whatever list still has stages + sampled remainders
        for s in s_t + s_u:
            if id(s) not in taken:
                taken.add(id(s))
                attach(s.pending_tasks())
        attach(deferred)
        return dec
