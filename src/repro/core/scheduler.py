"""Uncertainty-aware scheduler — Algorithm 1 of the paper (§IV-D).

Schedulers are shared between the discrete-event simulator (`repro.sim`)
and the real serving runtime (`repro.serving`): both call
:meth:`Scheduler.schedule` with the current unfinished jobs and a
:class:`ClusterView`, and dispatch tasks greedily from the returned
preference lists (``T_r`` for regular executors, ``T_l`` for LLM
executors) onto free capacity.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .calibration import LatencyProfile
from .dag import Job, Stage, StageType, Task
from .profiler import ProfileStore


@dataclass
class ClusterView:
    """What the scheduler may observe about the cluster."""

    now: float
    free_regular: int
    # per-LLM-executor (running batch size, max batch size)
    llm_loads: List[Tuple[int, int]]
    latency_profile: Optional[LatencyProfile] = None

    def llm_free_slots(self) -> int:
        return sum(max(0, mb - b) for b, mb in self.llm_loads)

    def current_batch(self) -> int:
        return max((b for b, _ in self.llm_loads), default=0)

    def target_batch(self) -> int:
        """Batch size an incoming task is likely to run at (for Eq. 2)."""
        if not self.llm_loads:
            return 1
        b, mb = min(self.llm_loads, key=lambda t: t[0])
        return min(b + 1, mb)


@dataclass
class Decision:
    """Ordered scheduling preference lists (Algorithm 1 output)."""

    regular: List[Task] = field(default_factory=list)
    llm: List[Task] = field(default_factory=list)


class Scheduler:
    name = "base"

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        raise NotImplementedError

    # Hook for schedulers that learn online (Decima).
    def observe_completion(self, job: Job, now: float) -> None:  # pragma: no cover
        pass


# ---------------------------------------------------------------------------
# LLMSched (Algorithm 1)
# ---------------------------------------------------------------------------
class LLMSched(Scheduler):
    """ε-greedy combination of uncertainty reduction and SRTF.

    ``use_bn=False``           → "LLMSched w/o BN" ablation (historical means).
    ``epsilon=0``              → "LLMSched w/o uncertainty" ablation (pure SRTF).
    """

    name = "llmsched"

    def __init__(
        self,
        profiles: ProfileStore,
        epsilon: float = 0.3,
        sampling_ratio: float = 0.3,
        use_bn: bool = True,
        seed: int = 0,
    ) -> None:
        self.profiles = profiles
        self.epsilon = float(epsilon)
        self.sampling_ratio = float(sampling_ratio)
        self.use_bn = use_bn
        self.rng = np.random.default_rng(seed)
        # caches invalidated per-call; uncertainty scores are reused across
        # ε draws within one invocation.
        self._ur_cache: Dict[Tuple[int, str], float] = {}

    # -- helpers -------------------------------------------------------------
    def _calibrator(self, view: ClusterView) -> Callable[[Stage, float], float]:
        prof = view.latency_profile
        if prof is None:
            return lambda stage, est: est

        b_t = view.target_batch()

        def cal(stage: Stage, est: float) -> float:
            if stage.stype is StageType.LLM:
                # historical estimates are recorded at batch size 1
                return prof.calibrate(est, b_r=1, b_t=b_t)
            return est

        return cal

    def est_rd(self, job: Job, view: ClusterView) -> float:
        p = self.profiles.get(job.app.name)
        if p is None:
            return float("inf")
        return p.est_remaining(
            job, view.now, calibrate=self._calibrator(view), use_bn=self.use_bn
        )

    def _uncert(self, job: Job, stage: Stage) -> float:
        key = (job.job_id, stage.name)
        if key not in self._ur_cache:
            p = self.profiles.get(job.app.name)
            self._ur_cache[key] = (
                p.stage_uncertainty_reduction(job, stage.name) if p else 0.0
            )
        return self._ur_cache[key]

    @staticmethod
    def non_overlapping_sets(
        bounds: List[Tuple[float, float, Job]]
    ) -> List[List[Job]]:
        """Group jobs whose duration intervals overlap (line 5).

        Jobs within a group cannot be ordered with certainty; between
        groups the ordering is certain.  Groups come back ordered by lower
        bound.
        """
        if not bounds:
            return []
        bounds = sorted(bounds, key=lambda t: (t[0], t[1]))
        groups: List[List[Job]] = [[bounds[0][2]]]
        cur_hi = bounds[0][1]
        for lo, hi, job in bounds[1:]:
            if lo <= cur_hi:  # overlaps current group
                groups[-1].append(job)
                cur_hi = max(cur_hi, hi)
            else:
                groups.append([job])
                cur_hi = hi
        return groups

    # -- Algorithm 1 -----------------------------------------------------------
    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        self._ur_cache.clear()
        jobs = [j for j in jobs if not j.done()]
        if not jobs:
            return Decision()

        # lines 1-4: S_t — ready stages in SRTF order of their job
        j_t = sorted(jobs, key=lambda j: (self.est_rd(j, view), j.arrival_time))
        s_t: List[Stage] = []
        for job in j_t:
            s_t.extend(job.ready_stages())

        # lines 5-10: S_u — stages by uncertainty reduction within
        # non-overlapping job groups
        bounds = []
        for job in jobs:
            p = self.profiles.get(job.app.name)
            lo, hi = p.job_bounds(job, use_bn=self.use_bn) if p else (0.0, math.inf)
            bounds.append((lo, hi, job))
        s_u: List[Stage] = []
        for group in self.non_overlapping_sets(bounds):
            stages = []
            for job in group:
                stages.extend(job.ready_stages())
            # only genuinely uncertainty-reducing stages are exploration
            # candidates (paper §IV-B: stages correlated with ≥1 other)
            scored = [(self._uncert_for(s, jobs), s) for s in stages]
            scored = [(r, s) for r, s in scored if r > 0.0]
            scored.sort(key=lambda t: -t[0])
            s_u.extend(s for _, s in scored)

        # lines 11-20: ε-greedy merge
        return self._merge(s_t, s_u)

    def _uncert_for(self, stage: Stage, jobs: Sequence[Job]) -> float:
        job = next(j for j in jobs if j.job_id == stage.job_id)
        return self._uncert(job, stage)

    def _merge(self, s_t: List[Stage], s_u: List[Stage]) -> Decision:
        dec = Decision()
        taken: set = set()
        deferred: List[Task] = []
        s_t = list(s_t)
        s_u = list(s_u)

        def pop_next(lst: List[Stage]) -> Optional[Stage]:
            while lst:
                s = lst.pop(0)
                if id(s) not in taken:
                    return s
            return None

        def attach(tasks: List[Task]) -> None:
            for t in tasks:
                (dec.llm if t.is_llm else dec.regular).append(t)

        while s_t and s_u:
            st = pop_next(s_t)
            su = pop_next(s_u)
            if st is None and su is None:
                break
            p = self.rng.random()
            if p < self.epsilon and su is not None:
                taken.add(id(su))
                pending = su.pending_tasks()
                if su is st:
                    # exploration pick coincides with the SRTF head: run it
                    # fully — sampling would only defer the exploit choice.
                    attach(pending)
                    continue
                k = max(1, math.ceil(self.sampling_ratio * len(pending)))
                attach(pending[:k])
                deferred.extend(pending[k:])
                if st is not None:
                    s_t.insert(0, st)  # not consumed this round
            elif st is not None:
                taken.add(id(st))
                attach(st.pending_tasks())
                if su is not None:
                    s_u.insert(0, su)
            elif su is not None:
                taken.add(id(su))
                attach(su.pending_tasks())

        # line 21: whatever list still has stages + sampled remainders
        for s in s_t + s_u:
            if id(s) not in taken:
                taken.add(id(s))
                attach(s.pending_tasks())
        attach(deferred)
        return dec
