"""Application profiler: BN + discretizers + dynamic-stage statistics (§IV-B).

One :class:`AppProfile` per application template.  It is trained on a
history of job traces and provides everything the scheduler needs:

- posterior duration estimates per stage / per job (BN inference on the
  evidence of completed stages — including "revealed skipped" chain stages
  observed as bin 0);
- uncertainty-reduction scores R(X) (Eq. 6) incl. the dynamic-stage bonus;
- job-duration distribution intervals for the non-overlapping grouping
  (Algorithm 1 line 5);
- per-candidate duration means for realized dynamic stages.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

from .bayesnet import BayesNet, Discretizer, fit_discretizer
from .dag import ApplicationTemplate, Job, Stage, StageType
from .entropy import (
    dynamic_stage_entropy,
    uncertainty_reduction,
    uncertainty_reductions,
)


@dataclass
class JobTrace:
    """One historical execution of an application."""

    app_name: str
    durations: Dict[str, float]  # stage name -> duration (0.0 if skipped)
    # dyn stage -> (chosen candidates, chosen edges)
    dynamic: Dict[str, Tuple[Tuple[str, ...], Tuple[Tuple[str, str], ...]]] = field(
        default_factory=dict
    )
    # dyn stage -> {candidate: duration}
    dynamic_durations: Dict[str, Dict[str, float]] = field(default_factory=dict)


class AppProfile:
    def __init__(self, app: ApplicationTemplate) -> None:
        self.app = app
        self.bn = BayesNet()
        self.discretizers: Dict[str, Discretizer] = {}
        self.mean_duration: float = 0.0
        # dynamic-stage statistics
        self.candidate_probs: Dict[str, Dict[str, float]] = {}
        self.edge_probs: Dict[str, Dict[Tuple[str, str], float]] = {}
        self.candidate_mean_dur: Dict[str, Dict[str, float]] = {}
        self._dyn_entropy: Dict[str, float] = {}
        self._fitted = False
        # posterior caches — the paper's "lookup table" argument (§IV-D):
        # evidence sets recur across scheduling events, so memoised BN
        # queries make scheduling effectively O(1) per stage.
        self._marg_cache: Dict[Tuple, np.ndarray] = {}
        self._ur_cache: Dict[Tuple, float] = {}
        # ---- incremental (cross-round) per-job caches -------------------
        # Keyed by job_id, each slot stores (evidence_version, payload);
        # a stale version is simply overwritten, so memory stays O(active
        # jobs).  Entries are dropped via forget_job() on job completion.
        self._job_ev: Dict[int, Tuple[int, Dict[str, int]]] = {}
        # (job_id, use_bn) -> (version, done_names, base_estimates, has_running)
        self._job_base: Dict[Tuple[int, bool], Tuple] = {}
        # (job_id, use_bn) -> (version, calib_sig, mode, value)
        self._job_rd: Dict[Tuple[int, bool], Tuple] = {}
        # (job_id, use_bn) -> (version, (lo, hi))
        self._job_bounds: Dict[Tuple[int, bool], Tuple] = {}
        # job_id -> (version, {stage_name: R})
        self._job_ur: Dict[int, Tuple[int, Dict[str, float]]] = {}

    # ------------------------------------------------------------------ fit
    def fit(self, traces: Sequence[JobTrace], max_bins: int = 6,
            mi_threshold: float = 0.05, max_parents: int = 3) -> "AppProfile":
        names = self.app.topo_order()
        mat = np.zeros((len(traces), len(names)))
        for i, tr in enumerate(traces):
            for j, n in enumerate(names):
                mat[i, j] = tr.durations.get(n, 0.0)

        for j, n in enumerate(names):
            self.discretizers[n] = fit_discretizer(mat[:, j], max_bins=max_bins)

        binned = np.zeros_like(mat, dtype=np.int64)
        for j, n in enumerate(names):
            d = self.discretizers[n]
            binned[:, j] = [d.transform(x) for x in mat[:, j]]

        self.bn.fit(
            binned,
            names=names,
            cards=[self.discretizers[n].cardinality for n in names],
            template_edges=self.app.edges,
            mi_threshold=mi_threshold,
            max_parents=max_parents,
        )
        self.mean_duration = float(mat.sum(axis=1).mean())

        # dynamic-stage statistics from realized plans
        for st in self.app.stages:
            if st.stype is not StageType.DYNAMIC:
                continue
            n_tr = max(1, len(traces))
            cprob = {c: 0.0 for c in st.candidates}
            eprob = {e: 0.0 for e in st.candidate_edges}
            cdur: Dict[str, List[float]] = {c: [] for c in st.candidates}
            for tr in traces:
                chosen, edges = tr.dynamic.get(st.name, ((), ()))
                for c in chosen:
                    if c in cprob:
                        cprob[c] += 1.0
                for e in edges:
                    if tuple(e) in eprob:
                        eprob[tuple(e)] += 1.0
                for c, d in tr.dynamic_durations.get(st.name, {}).items():
                    if c in cdur:
                        cdur[c].append(d)
            self.candidate_probs[st.name] = {c: v / n_tr for c, v in cprob.items()}
            self.edge_probs[st.name] = {e: v / n_tr for e, v in eprob.items()}
            self.candidate_mean_dur[st.name] = {
                c: (float(np.mean(v)) if v else 1.0) for c, v in cdur.items()
            }
            self._dyn_entropy[st.name] = dynamic_stage_entropy(
                self.candidate_probs[st.name], self.edge_probs[st.name]
            )
        self._fitted = True
        return self

    # ------------------------------------------------------- evidence/query
    def evidence_for(self, job: Job, version: Optional[int] = None) -> Dict[str, int]:
        """BN evidence from this job's observable state.

        ``version`` (the job's ``evidence_version``) enables the
        cross-round cache: evidence is rebuilt only when the runtime has
        reported an observable-state change for this job.
        """
        if version is not None:
            hit = self._job_ev.get(job.job_id)
            if hit is not None and hit[0] == version:
                return hit[1]
        ev: Dict[str, int] = {}
        for name, dur in job.completed_durations().items():
            if name in self.discretizers:
                ev[name] = self.discretizers[name].transform(dur)
        for name in job.observed_skips():
            d = self.discretizers.get(name)
            if d is not None and d.has_zero_bin and name not in ev:
                ev[name] = 0
        if version is not None:
            self._job_ev[job.job_id] = (version, ev)
        return ev

    def forget_job(self, job_id: int) -> None:
        """Drop all per-job cache slots (call when a job leaves the system)."""
        self._job_ev.pop(job_id, None)
        self._job_ur.pop(job_id, None)
        for use_bn in (False, True):
            self._job_base.pop((job_id, use_bn), None)
            self._job_rd.pop((job_id, use_bn), None)
            self._job_bounds.pop((job_id, use_bn), None)

    @staticmethod
    def _ev_key(evidence: Mapping[str, int]) -> Tuple:
        return tuple(sorted(evidence.items()))

    def marginal(self, name: str, evidence: Mapping[str, int]) -> np.ndarray:
        key = (name, self._ev_key(evidence))
        out = self._marg_cache.get(key)
        if out is None:
            out = self.bn.marginal(name, evidence)
            self._marg_cache[key] = out
        return out

    def marginals_for(
        self, names: Sequence[str], evidence: Mapping[str, int]
    ) -> None:
        """Prefill the posterior cache for ``names`` under one evidence set,
        sharing a single evidence-reduction pass over the BN factors (one
        forward pass instead of one per stage)."""
        ev_key = self._ev_key(evidence)
        missing = [n for n in names if (n, ev_key) not in self._marg_cache]
        if not missing:
            return
        factors = None
        for n in missing:
            if evidence and n in evidence:
                self._marg_cache[(n, ev_key)] = self.bn.marginal(n, evidence)
                continue
            if factors is None:
                factors = self.bn.reduced_factors(evidence)
            self._marg_cache[(n, ev_key)] = self.bn.marginal(
                n, evidence, factors=factors
            )

    def stage_expectation(self, name: str, evidence: Mapping[str, int]) -> float:
        """E[duration of stage | evidence] via BN posterior."""
        if not self._fitted or name not in self.discretizers:
            return 1.0
        post = self.marginal(name, evidence)
        return self.discretizers[name].expectation(post)

    def stage_bounds(self, name: str, evidence: Mapping[str, int]) -> Tuple[float, float]:
        d = self.discretizers.get(name)
        if d is None:
            return (0.0, 1.0)
        post = self.marginal(name, evidence)
        idx = np.where(post > 1e-9)[0]
        if len(idx) == 0:
            return (0.0, 0.0)
        return (float(d.repr_value[idx].min()), float(d.repr_value[idx].max()))

    # ------------------------------------------------- remaining-time query
    def _base_estimates(
        self, job: Job, use_bn: bool, version: Optional[int] = None
    ) -> Tuple[set, Dict[str, float], bool]:
        """(done_names, base, has_running) for ``job``'s stages.

        ``base[name]`` is the stage's duration estimate *before* batching
        calibration and elapsed-time subtraction — a pure function of the
        job's BN evidence and observable structure, so it is cacheable per
        (job, evidence_version).  ``has_running`` records whether any
        unfinished stage is executing (making the final remaining-duration
        value time-dependent and thus uncacheable as a scalar).
        """
        key = (job.job_id, bool(use_bn))
        if version is not None:
            hit = self._job_base.get(key)
            if hit is not None and hit[0] == version:
                return hit[1], hit[2], hit[3]
        ev = self.evidence_for(job, version) if use_bn else {}
        if self._fitted:
            # one BN forward pass covers every stage expectation below
            self.marginals_for(
                [
                    n
                    for n, s in job.stages.items()
                    if n in self.discretizers and not s.obs_done()
                ],
                ev if use_bn else {},
            )
        done: set = set()
        base: Dict[str, float] = {}
        has_running = False
        for name, stage in job.stages.items():
            # NOTE: ``stage.will_execute`` is ground truth — only observable
            # once the stage is *revealed* (no oracle leak).  Unrevealed
            # stages keep their BN expectation, whose bin-0 mass already
            # prices in the probability they never run.
            if stage.obs_done():
                done.add(name)
                continue
            if name in self.discretizers and self._fitted:
                if use_bn:
                    e = self.stage_expectation(name, ev)
                else:
                    post = self.marginal(name, {}) if self.bn.nodes else None
                    e = (
                        self.discretizers[name].expectation(post)
                        if post is not None
                        else float(self.discretizers[name].repr_value.mean())
                    )
            elif "." in name:
                # runtime-expanded dynamic inner stage "<dyn>.<candidate>"
                dyn, cand = name.split(".", 1)
                e = self.candidate_mean_dur.get(dyn, {}).get(cand, 1.0)
            else:
                e = 1.0
            if stage.running():
                has_running = True
            base[name] = e
        if version is not None:
            self._job_base[key] = (version, done, base, has_running)
        return done, base, has_running

    def est_remaining(
        self,
        job: Job,
        now: float,
        calibrate: Optional[Callable[[Stage, float], float]] = None,
        mode: str = "critical_path",
        use_bn: bool = True,
        version: Optional[int] = None,
        calib_key: Optional[Tuple] = None,
    ) -> float:
        """Estimated remaining duration of ``job`` (line 1 of Algorithm 1).

        ``calibrate`` maps (stage, base_estimate) -> batching-calibrated
        estimate (Eq. 2); identity if None.  ``use_bn=False`` gives the
        "LLMSched w/o BN" ablation (historical means, no posterior).

        ``version`` is the job's ``evidence_version``; when provided, the
        per-stage BN work is cached across scheduling rounds and only the
        cheap calibrate/elapsed/critical-path pass re-runs.  ``calib_key``
        is a hashable token identifying the calibration context (e.g.
        (profile epoch, target batch)); when the job additionally has no
        running stage the final scalar is cached outright.
        """
        slot = (job.job_id, bool(use_bn))
        sig = ("ident",) if calibrate is None else calib_key
        if version is not None and sig is not None:
            hit = self._job_rd.get(slot)
            if (
                hit is not None
                and hit[0] == version
                and hit[1] == sig
                and hit[2] == mode
            ):
                return hit[3]
        done, base, has_running = self._base_estimates(job, use_bn, version)
        est: Dict[str, float] = {}
        for name, stage in job.stages.items():
            if name in done:
                est[name] = 0.0
                continue
            e = base[name]
            if calibrate is not None:
                e = calibrate(stage, e)
            if stage.running():
                started = min(
                    (t.start_time for t in stage.tasks if t.start_time >= 0),
                    default=now,
                )
                e = max(0.0, e - (now - started))
            est[name] = e

        if mode == "sum":
            out = float(sum(est.values()))
        else:
            # critical path over unfinished stages (finished contribute 0)
            order = self.app.topo_order()
            dist: Dict[str, float] = {}
            for n in order:
                if n not in job.stages:
                    continue
                pmax = max(
                    (dist.get(p, 0.0) for p in self.app.parents(n)), default=0.0
                )
                dist[n] = pmax + est.get(n, 0.0)
            # realized dynamic inner stages live outside the template order
            extra = sum(est.get(n, 0.0) for n in est if n not in dist)
            out = float(max(dist.values(), default=0.0) + extra)
        if version is not None and sig is not None and not has_running:
            self._job_rd[slot] = (version, sig, mode, out)
        return out

    def job_bounds(
        self, job: Job, use_bn: bool = True, version: Optional[int] = None
    ) -> Tuple[float, float]:
        """[lo, hi] of the job's remaining-duration distribution (line 5)."""
        slot = (job.job_id, bool(use_bn))
        if version is not None:
            hit = self._job_bounds.get(slot)
            if hit is not None and hit[0] == version:
                return hit[1]
        ev = self.evidence_for(job, version) if use_bn else {}
        if self._fitted:
            self.marginals_for(
                [
                    n
                    for n, s in job.stages.items()
                    if n in self.discretizers and not s.obs_done()
                ],
                ev,
            )
        lo = hi = 0.0
        for name, stage in job.stages.items():
            if stage.obs_done():
                continue
            l, h = self.stage_bounds(name, ev) if self._fitted else (0.0, 1.0)
            lo += l
            hi += h
        out = (lo, hi)
        if version is not None:
            self._job_bounds[slot] = (version, out)
        return out

    # ------------------------------------------------- uncertainty reduction
    def _dynamic_bonus(self, job: Job, stage_name: str, ev: Mapping[str, int]) -> float:
        """Eq. 4 bonus for dynamic stages resolved by finishing this stage."""
        bonus = 0.0
        st = job.stages.get(stage_name)
        if st is not None and st.stype is StageType.LLM:
            # dynamic stages resolved by this LLM stage (its children)
            for child in self.app.children(stage_name):
                cst = job.stages.get(child)
                if (
                    cst is not None
                    and cst.stype is StageType.DYNAMIC
                    and not cst.revealed
                ):
                    h = self._dyn_entropy.get(child, 0.0)
                    d = self.discretizers.get(child)
                    post = self.marginal(child, ev) if d else None
                    rng = d.range_span(post) if d is not None and post is not None else 1.0
                    bonus += h * max(rng, 1e-6)
        return bonus

    def stage_uncertainty_reduction(
        self, job: Job, stage_name: str, version: Optional[int] = None
    ) -> float:
        """R(stage) for Algorithm 1 line 8 (Eq. 6 + dynamic bonus)."""
        return self.stage_uncertainty_reductions(job, [stage_name], version)[0]

    def stage_uncertainty_reductions(
        self,
        job: Job,
        stage_names: Sequence[str],
        version: Optional[int] = None,
    ) -> List[float]:
        """Batched R(stage) for several ready stages of one job.

        All stages share one evidence set, one unscheduled-set scan, and
        one BN evidence-reduction pass (via
        :func:`repro.core.entropy.uncertainty_reductions`).  With
        ``version`` set, scores are additionally cached per
        (job, evidence_version) across scheduling rounds.
        """
        if not self._fitted:
            return [0.0] * len(stage_names)
        vcache: Optional[Dict[str, float]] = None
        if version is not None:
            slot = self._job_ur.get(job.job_id)
            if slot is not None and slot[0] == version:
                vcache = slot[1]
            else:
                vcache = {}
                self._job_ur[job.job_id] = (version, vcache)
            missing = [n for n in stage_names if n not in vcache]
            if not missing:
                return [vcache[n] for n in stage_names]
        else:
            missing = list(dict.fromkeys(stage_names))

        ev = self.evidence_for(job, version)
        unscheduled = [
            name
            for name, s in job.stages.items()
            if not s.obs_done()
            and not s.running()
            and s.dispatched_tasks == 0
        ]
        unsched_t = tuple(sorted(unscheduled))
        ev_key = self._ev_key(ev)
        results: Dict[str, float] = {}
        need_mi: List[Tuple[str, float]] = []
        for name in missing:
            key = (name, unsched_t, ev_key)
            hit = self._ur_cache.get(key)
            if hit is not None:
                results[name] = hit
                continue
            bonus = self._dynamic_bonus(job, name, ev)
            if name not in self.bn.nodes:
                results[name] = float(bonus)
                self._ur_cache[key] = results[name]
                continue
            need_mi.append((name, bonus))
        if need_mi:
            vals = uncertainty_reductions(
                self.bn,
                self.discretizers,
                [n for n, _ in need_mi],
                unscheduled,
                ev,
                dynamic_bonuses=[b for _, b in need_mi],
            )
            for (name, _), val in zip(need_mi, vals):
                results[name] = val
                self._ur_cache[(name, unsched_t, ev_key)] = val
        if vcache is not None:
            vcache.update(results)
            return [vcache[n] for n in stage_names]
        return [results[n] for n in stage_names]


class ProfileStore:
    """Profiles for all applications, keyed by template name."""

    def __init__(self) -> None:
        self.profiles: Dict[str, AppProfile] = {}

    def fit(self, apps: Sequence[ApplicationTemplate], traces: Sequence[JobTrace],
            **kw) -> "ProfileStore":
        by_app: Dict[str, List[JobTrace]] = {}
        for t in traces:
            by_app.setdefault(t.app_name, []).append(t)
        for app in apps:
            prof = AppProfile(app)
            if by_app.get(app.name):
                prof.fit(by_app[app.name], **kw)
            self.profiles[app.name] = prof
        return self

    def __getitem__(self, name: str) -> AppProfile:
        return self.profiles[name]

    def get(self, name: str) -> Optional[AppProfile]:
        return self.profiles.get(name)

    def forget_job(self, job_id: int) -> None:
        """Evict a finished job's slots from every profile's caches."""
        for prof in self.profiles.values():
            prof.forget_job(job_id)
