"""Baseline schedulers (paper §V "Baselines").

- FCFS     : arrival order (Spark default).
- Fair     : equal share across running jobs (round-robin interleave).
- SJF      : shortest estimated *total* duration first (historical app mean).
- SRTF     : shortest estimated *remaining* time first (static estimates).
- Argus    : stage rank by depth / #children / #tasks (Wu et al., IPDPS'21).
- Carbyne  : altruistic — SRTF order, leftover capacity redistributed fairly.
- Decima   : RL (REINFORCE) over per-stage features; schedules one stage
             per invocation (the behaviour the paper calls out for
             planning workloads).

All baselines receive the *same* prior information the paper grants them:
historical mean durations per application and the template DAG structure.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dag import Job, Stage, Task
from .profiler import ProfileStore
from .scheduler import ClusterView, Decision, Scheduler


def _attach(dec: Decision, tasks: Sequence[Task]) -> None:
    for t in tasks:
        (dec.llm if t.is_llm else dec.regular).append(t)


class FCFS(Scheduler):
    name = "fcfs"

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        dec = Decision()
        for job in sorted(jobs, key=lambda j: (j.arrival_time, j.job_id)):
            for stage in job.ready_stages():
                _attach(dec, stage.pending_tasks())
        return dec


class Fair(Scheduler):
    name = "fair"

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        dec = Decision()
        queues: List[List[Task]] = []
        for job in sorted(jobs, key=lambda j: (j.arrival_time, j.job_id)):
            q: List[Task] = []
            for stage in job.ready_stages():
                q.extend(stage.pending_tasks())
            if q:
                queues.append(q)
        # round-robin one task per job per round: equal share
        while any(queues):
            for q in queues:
                if q:
                    _attach(dec, [q.pop(0)])
        return dec


class SJF(Scheduler):
    """Shortest (total historical) Job First."""

    name = "sjf"

    def __init__(self, profiles: ProfileStore) -> None:
        self.profiles = profiles

    def _job_key(self, job: Job) -> float:
        p = self.profiles.get(job.app.name)
        return p.mean_duration if p else float("inf")

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        dec = Decision()
        for job in sorted(jobs, key=lambda j: (self._job_key(j), j.arrival_time)):
            for stage in job.ready_stages():
                _attach(dec, stage.pending_tasks())
        return dec


class SRTF(SJF):
    """Shortest Remaining Time First with *static* per-stage estimates
    (no BN posterior — that distinction belongs to LLMSched)."""

    name = "srtf"

    def _job_key(self, job: Job) -> float:
        p = self.profiles.get(job.app.name)
        if p is None or not p._fitted:
            return float("inf")
        rem = 0.0
        for s in job.stages.values():
            if s.obs_done():
                continue
            d = p.discretizers.get(s.name)
            if d is not None:
                prior = p.bn.marginal(s.name, {}) if p.bn.nodes else None
                rem += d.expectation(prior) if prior is not None else 1.0
            else:
                rem += 1.0
        return rem


class Argus(Scheduler):
    """Stage-rank scheduler: prefer stages that unlock more downstream work
    — more children, more tasks, smaller depth (root-side) first."""

    name = "argus"

    def __init__(self, profiles: Optional[ProfileStore] = None) -> None:
        self.profiles = profiles

    @staticmethod
    def _depth(job: Job, stage: Stage) -> int:
        app = job.app
        depth = 0
        frontier = [stage.name]
        seen = set()
        while frontier:
            nxt = []
            for n in frontier:
                for p in app.parents(n):
                    if p not in seen:
                        seen.add(p)
                        nxt.append(p)
            if nxt:
                depth += 1
            frontier = nxt
        return depth

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        dec = Decision()
        ranked: List[Tuple[Tuple, Stage]] = []
        for job in jobs:
            for stage in job.ready_stages():
                n_children = len(job.app.children(stage.name))
                key = (
                    self._depth(job, stage),          # shallow first
                    -n_children,                      # more children first
                    -len(stage.pending_tasks()),      # more tasks first
                    job.arrival_time,
                )
                ranked.append((key, stage))
        for _, stage in sorted(ranked, key=lambda t: t[0]):
            _attach(dec, stage.pending_tasks())
        return dec


class Carbyne(Scheduler):
    """Altruistic scheduling (simplified): jobs ordered SRTF, but each job
    initially claims only what its current critical path needs (one wave);
    leftover tasks are redistributed round-robin (the "altruism")."""

    name = "carbyne"

    def __init__(self, profiles: ProfileStore) -> None:
        self.profiles = profiles
        self._srtf = SRTF(profiles)

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        dec = Decision()
        ordered = sorted(
            jobs, key=lambda j: (self._srtf._job_key(j), j.arrival_time)
        )
        leftovers: List[List[Task]] = []
        for job in ordered:
            for stage in job.ready_stages():
                pend = stage.pending_tasks()
                # claim one wave: as many tasks as the stage strictly needs
                # to keep its critical path moving (1 task), donate the rest
                _attach(dec, pend[:1])
                if pend[1:]:
                    leftovers.append(pend[1:])
        while any(leftovers):
            for q in leftovers:
                if q:
                    _attach(dec, [q.pop(0)])
        return dec


# ---------------------------------------------------------------------------
# Decima (RL baseline)
# ---------------------------------------------------------------------------
class DecimaPolicy:
    """Tiny 2-layer MLP scoring stages from hand features (numpy)."""

    N_FEATURES = 6

    def __init__(self, hidden: int = 16, seed: int = 0) -> None:
        rng = np.random.default_rng(seed)
        self.w1 = rng.normal(0, 0.5, (self.N_FEATURES, hidden))
        self.b1 = np.zeros(hidden)
        self.w2 = rng.normal(0, 0.5, (hidden, 1))
        self.b2 = np.zeros(1)

    def params(self) -> List[np.ndarray]:
        return [self.w1, self.b1, self.w2, self.b2]

    def scores(self, feats: np.ndarray) -> np.ndarray:
        h = np.tanh(feats @ self.w1 + self.b1)
        return (h @ self.w2 + self.b2).ravel()

    def grad_log_softmax(self, feats: np.ndarray, action: int) -> List[np.ndarray]:
        """∇ log π(action | feats) for REINFORCE."""
        h_pre = feats @ self.w1 + self.b1
        h = np.tanh(h_pre)
        s = (h @ self.w2 + self.b2).ravel()
        p = np.exp(s - s.max())
        p /= p.sum()
        # d log p[a] / d s = onehot(a) - p
        ds = -p
        ds[action] += 1.0
        dw2 = h.T @ ds[:, None]
        db2 = np.array([ds.sum()])
        dh = ds[:, None] @ self.w2.T
        dpre = dh * (1 - h * h)
        dw1 = feats.T @ dpre
        db1 = dpre.sum(axis=0)
        return [dw1, db1, dw2, db2]


class Decima(Scheduler):
    """REINFORCE-trained neural scheduler; picks ONE stage per invocation."""

    name = "decima"

    def __init__(self, profiles: ProfileStore, seed: int = 0, train: bool = False):
        self.profiles = profiles
        self.policy = DecimaPolicy(seed=seed)
        self.rng = np.random.default_rng(seed + 1)
        self.train = train
        self.trajectory: List[Tuple[np.ndarray, int]] = []

    def _features(self, job: Job, stage: Stage, now: float) -> np.ndarray:
        p = self.profiles.get(job.app.name)
        est = 1.0
        if p and p._fitted and stage.name in p.discretizers:
            d = p.discretizers[stage.name]
            est = float(d.repr_value.mean())
        rem = p.mean_duration if p else 1.0
        return np.array(
            [
                math.log1p(rem),
                math.log1p(est),
                len(stage.pending_tasks()) / 8.0,
                len(job.app.children(stage.name)) / 4.0,
                math.log1p(max(0.0, now - job.arrival_time)),
                1.0 if stage.stype.value == "llm" else 0.0,
            ]
        )

    def schedule(self, jobs: Sequence[Job], view: ClusterView) -> Decision:
        dec = Decision()
        cands: List[Stage] = []
        feats: List[np.ndarray] = []
        for job in jobs:
            for stage in job.ready_stages():
                cands.append(stage)
                feats.append(self._features(job, stage, view.now))
        if not cands:
            return dec
        f = np.stack(feats)
        s = self.policy.scores(f)
        if self.train:
            p = np.exp(s - s.max())
            p /= p.sum()
            a = int(self.rng.choice(len(cands), p=p))
            self.trajectory.append((f, a))
        else:
            a = int(np.argmax(s))
        # Decima schedules the tasks of only one stage at a time.
        _attach(dec, cands[a].pending_tasks())
        return dec

    # -- REINFORCE ----------------------------------------------------------
    def finish_episode(self, neg_avg_jct: float, lr: float = 1e-3) -> None:
        """Policy-gradient update with episode return = -avg JCT."""
        if not self.trajectory:
            return
        grads = [np.zeros_like(p) for p in self.policy.params()]
        for f, a in self.trajectory:
            g = self.policy.grad_log_softmax(f, a)
            for acc, gi in zip(grads, g):
                acc += gi
        for p, g in zip(self.policy.params(), grads):
            p += lr * neg_avg_jct * g / len(self.trajectory)
        self.trajectory.clear()


def make_baselines(profiles: ProfileStore) -> Dict[str, Scheduler]:
    return {
        "fcfs": FCFS(),
        "fair": Fair(),
        "sjf": SJF(profiles),
        "argus": Argus(profiles),
        "carbyne": Carbyne(profiles),
        "decima": Decima(profiles),
    }
