"""Discrete Bayesian network for profiling compound LLM applications (§IV-B).

pyagrum (used by the paper) is unavailable offline, so this is a
from-scratch discrete BN with:

- quantile discretization of stage durations into ≤ ``max_bins`` intervals,
  with a dedicated bin 0 for "not executed" (duration == 0, paper footnote 2);
- structure = application-template edges + extra edges mined by pairwise
  mutual-information thresholding (parents capped to keep CPDs dense);
- CPDs from Laplace-smoothed counts;
- exact inference by variable elimination (factor algebra over numpy).

Networks here are small (≤ ~25 nodes, cardinality ≤ 7) so exact inference
is effectively constant-time — the paper makes the same argument (§IV-D).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

Evidence = Mapping[str, int]  # var name -> observed bin index


# ---------------------------------------------------------------------------
# Factor algebra
# ---------------------------------------------------------------------------
@dataclass
class Factor:
    """A factor over discrete variables: ``values[i_0, ..., i_{k-1}]``."""

    vars: Tuple[str, ...]
    values: np.ndarray  # shape = cards of vars, float64

    def __post_init__(self) -> None:
        assert self.values.ndim == len(self.vars)

    @property
    def cards(self) -> Tuple[int, ...]:
        return self.values.shape

    def product(self, other: "Factor") -> "Factor":
        all_vars = list(self.vars) + [v for v in other.vars if v not in self.vars]
        a = _broadcast(self, all_vars)
        b = _broadcast(other, all_vars)
        return Factor(tuple(all_vars), a * b)

    def marginalize(self, var: str) -> "Factor":
        ax = self.vars.index(var)
        new_vars = tuple(v for v in self.vars if v != var)
        return Factor(new_vars, self.values.sum(axis=ax))

    def reduce(self, var: str, value: int) -> "Factor":
        ax = self.vars.index(var)
        new_vars = tuple(v for v in self.vars if v != var)
        return Factor(new_vars, np.take(self.values, value, axis=ax))

    def normalize(self) -> "Factor":
        z = self.values.sum()
        if z <= 0:
            # Degenerate (evidence with zero probability under the model):
            # fall back to uniform so downstream entropy math stays finite.
            vals = np.full_like(self.values, 1.0 / self.values.size)
            return Factor(self.vars, vals)
        return Factor(self.vars, self.values / z)

    def reorder(self, order: Sequence[str]) -> "Factor":
        perm = [self.vars.index(v) for v in order]
        return Factor(tuple(order), np.transpose(self.values, perm))


def _broadcast(f: Factor, all_vars: List[str]) -> np.ndarray:
    shape = [1] * len(all_vars)
    src_axes = []
    for i, v in enumerate(all_vars):
        if v in f.vars:
            src_axes.append((f.vars.index(v), i))
    perm = [a for a, _ in sorted(src_axes, key=lambda t: t[1])]
    arr = np.transpose(f.values, perm) if perm else f.values
    it = iter(range(arr.ndim))
    for i, v in enumerate(all_vars):
        if v in f.vars:
            shape[i] = arr.shape[next(it)]
    return arr.reshape(shape)


def eliminate(factors: List[Factor], keep: Sequence[str]) -> Factor:
    """Variable elimination: multiply all factors, sum out vars not in keep.

    Uses a min-degree-ish heuristic (eliminate vars appearing in fewest
    factors first) — plenty for networks this small.
    """
    factors = list(factors)
    all_vars: Set[str] = set()
    for f in factors:
        all_vars.update(f.vars)
    to_eliminate = [v for v in all_vars if v not in keep]

    while to_eliminate:
        # pick var in fewest factors
        counts = {v: sum(v in f.vars for f in factors) for v in to_eliminate}
        v = min(to_eliminate, key=lambda x: counts[x])
        to_eliminate.remove(v)
        related = [f for f in factors if v in f.vars]
        rest = [f for f in factors if v not in f.vars]
        if not related:
            continue
        prod = related[0]
        for f in related[1:]:
            prod = prod.product(f)
        factors = rest + [prod.marginalize(v)]

    if not factors:
        return Factor((), np.array(1.0))
    prod = factors[0]
    for f in factors[1:]:
        prod = prod.product(f)
    # sum out any stray vars (shouldn't happen, but be safe)
    for v in list(prod.vars):
        if v not in keep:
            prod = prod.marginalize(v)
    return prod.reorder([v for v in keep if v in prod.vars])


# ---------------------------------------------------------------------------
# Discretizer
# ---------------------------------------------------------------------------
@dataclass
class Discretizer:
    """Quantile discretizer for one stage's duration distribution.

    Bin 0 is reserved for "not executed" (duration == 0) whenever any
    history sample is 0.  Real durations go into up to ``max_bins``
    quantile intervals.  ``repr_value[b]`` is the mean duration of training
    samples in bin b (used for expectations); ``lo/hi`` give interval
    bounds (used for Range()).
    """

    edges: np.ndarray          # interior bin edges for positive durations
    has_zero_bin: bool
    repr_value: np.ndarray     # mean duration per bin
    lo: np.ndarray             # lower bound per bin
    hi: np.ndarray             # upper bound per bin

    @property
    def cardinality(self) -> int:
        return len(self.repr_value)

    def transform(self, duration: float) -> int:
        if self.has_zero_bin and duration <= 0.0:
            return 0
        b = int(np.searchsorted(self.edges, duration, side="right"))
        b += 1 if self.has_zero_bin else 0
        # a duration class never seen in training (e.g. a stage that
        # only ever skipped, leaving just the zero bin) must clamp into
        # the last fitted bin instead of indexing past the CPD's
        # cardinality; a no-op for every well-fitted discretizer
        return min(b, len(self.repr_value) - 1)

    def range_span(self, probs: np.ndarray, eps: float = 1e-9) -> float:
        """Range of the (posterior) duration distribution: spread of
        representative values over bins with non-negligible mass."""
        idx = np.where(probs > eps)[0]
        if len(idx) == 0:
            return 0.0
        return float(self.repr_value[idx].max() - self.repr_value[idx].min())

    def expectation(self, probs: np.ndarray) -> float:
        return float(np.dot(probs, self.repr_value))


def fit_discretizer(samples: Sequence[float], max_bins: int = 6) -> Discretizer:
    s = np.asarray(list(samples), dtype=np.float64)
    zero = s[s <= 0.0]
    pos = s[s > 0.0]
    has_zero_bin = len(zero) > 0
    if len(pos) == 0:
        return Discretizer(
            edges=np.array([]),
            has_zero_bin=True,
            repr_value=np.array([0.0]),
            lo=np.array([0.0]),
            hi=np.array([0.0]),
        )
    uniq = np.unique(pos)
    k = int(min(max_bins, len(uniq)))
    # quantile ("frequency-based", paper §V) edges
    qs = np.quantile(pos, np.linspace(0, 1, k + 1)[1:-1]) if k > 1 else np.array([])
    edges = np.unique(qs)
    nbins = len(edges) + 1
    offset = 1 if has_zero_bin else 0
    card = nbins + offset
    repr_value = np.zeros(card)
    lo = np.zeros(card)
    hi = np.zeros(card)
    assign = np.searchsorted(edges, pos, side="right") + offset
    for b in range(offset, card):
        mask = assign == b
        if mask.any():
            repr_value[b] = pos[mask].mean()
            lo[b] = pos[mask].min()
            hi[b] = pos[mask].max()
        else:  # empty quantile bin (ties) — use edge midpoint
            lo_e = edges[b - offset - 1] if b - offset - 1 >= 0 else pos.min()
            hi_e = edges[b - offset] if b - offset < len(edges) else pos.max()
            repr_value[b] = 0.5 * (lo_e + hi_e)
            lo[b], hi[b] = lo_e, hi_e
    return Discretizer(edges=edges, has_zero_bin=has_zero_bin,
                       repr_value=repr_value, lo=lo, hi=hi)


# ---------------------------------------------------------------------------
# Bayesian network
# ---------------------------------------------------------------------------
class BayesNet:
    """Discrete BN over stage-duration variables of one application."""

    def __init__(self) -> None:
        self.nodes: List[str] = []
        self.cards: Dict[str, int] = {}
        self.parents: Dict[str, List[str]] = {}
        self.cpds: Dict[str, Factor] = {}  # factor over (node, *parents)
        self._desc_cache: Dict[str, Set[str]] = {}  # node -> descendants

    # -- structure + parameters -------------------------------------------
    def fit(
        self,
        data: np.ndarray,                 # (n_samples, n_vars) bin indices
        names: Sequence[str],
        cards: Sequence[int],
        template_edges: Iterable[Tuple[str, str]] = (),
        mi_threshold: float = 0.05,
        max_parents: int = 3,
        alpha: float = 0.5,
    ) -> "BayesNet":
        names = list(names)
        self.nodes = names
        self.cards = dict(zip(names, cards))
        idx = {n: i for i, n in enumerate(names)}
        n = len(names)

        # --- structure: template edges first, then MI-mined extras --------
        order = {name: i for i, name in enumerate(names)}  # topo order given
        parents: Dict[str, List[str]] = {name: [] for name in names}
        for u, v in template_edges:
            if u in idx and v in idx and order[u] < order[v]:
                if u not in parents[v] and len(parents[v]) < max_parents:
                    parents[v].append(u)
        # mine extra edges by empirical pairwise MI (earlier -> later only)
        mi_cache: List[Tuple[float, str, str]] = []
        for j in range(n):
            for i in range(j):
                u, v = names[i], names[j]
                if u in parents[v]:
                    continue
                m = _empirical_mi(data[:, idx[u]], data[:, idx[v]],
                                  self.cards[u], self.cards[v])
                if m > mi_threshold:
                    mi_cache.append((m, u, v))
        for m, u, v in sorted(mi_cache, reverse=True):
            if len(parents[v]) < max_parents:
                parents[v].append(u)
        self.parents = parents

        # --- CPDs: Laplace-smoothed counts ---------------------------------
        for v in names:
            ps = parents[v]
            shape = tuple([self.cards[v]] + [self.cards[p] for p in ps])
            counts = np.full(shape, alpha, dtype=np.float64)
            cols = [idx[v]] + [idx[p] for p in ps]
            for row in data:
                counts[tuple(int(row[c]) for c in cols)] += 1.0
            counts /= counts.sum(axis=0, keepdims=True)
            self.cpds[v] = Factor(tuple([v] + ps), counts)
        self._desc_cache = {}
        return self

    # -- correlation (Eq. 1): directed path u ->* v in the BN ---------------
    def _descendants(self, u: str) -> Set[str]:
        hit = self._desc_cache.get(u)
        if hit is not None:
            return hit
        children: Dict[str, List[str]] = {x: [] for x in self.nodes}
        for c, ps in self.parents.items():
            for p in ps:
                children[p].append(c)
        seen: Set[str] = set()
        frontier = [u]
        while frontier:
            x = frontier.pop()
            for c in children.get(x, ()):
                if c not in seen:
                    seen.add(c)
                    frontier.append(c)
        self._desc_cache[u] = seen
        return seen

    def correlated(self, u: str, v: str) -> bool:
        if u == v:
            return False
        return v in self._descendants(u)

    def correlated_set(self, u: str) -> List[str]:
        return [v for v in self.nodes if self.correlated(u, v)]

    def uncertainty_reducing(self) -> List[str]:
        """Stages correlated with ≥1 other stage (paper: uncertainty-reducing)."""
        return [u for u in self.nodes if len(self.correlated_set(u)) > 0]

    # -- inference ----------------------------------------------------------
    def _reduced_factors(self, evidence: Evidence) -> List[Factor]:
        out = []
        for v in self.nodes:
            f = self.cpds[v]
            for e, val in evidence.items():
                if e in f.vars:
                    f = f.reduce(e, int(val))
            out.append(f)
        return out

    def reduced_factors(self, evidence: Optional[Evidence] = None) -> List[Factor]:
        """Evidence-reduced CPD factors — the shared prefix of every query
        against the same evidence set.  Compute once, then pass to
        :meth:`joint`/:meth:`marginal`/:meth:`marginals` via ``factors=``
        to amortize one BN "forward pass" over many queries."""
        return self._reduced_factors(dict(evidence or {}))

    def joint(
        self,
        query: Sequence[str],
        evidence: Optional[Evidence] = None,
        factors: Optional[List[Factor]] = None,
    ) -> Factor:
        """P(query | evidence), normalized, vars ordered as ``query``."""
        evidence = dict(evidence or {})
        query = [q for q in query if q not in evidence]
        if factors is None:
            factors = self._reduced_factors(evidence)
        f = eliminate(factors, keep=query)
        return f.normalize().reorder(query)

    def marginal(
        self,
        var: str,
        evidence: Optional[Evidence] = None,
        factors: Optional[List[Factor]] = None,
    ) -> np.ndarray:
        if evidence and var in evidence:
            p = np.zeros(self.cards[var])
            p[int(evidence[var])] = 1.0
            return p
        return self.joint([var], evidence, factors=factors).values

    def marginals(
        self, names: Sequence[str], evidence: Optional[Evidence] = None
    ) -> Dict[str, np.ndarray]:
        """Posterior marginals of ``names`` sharing one evidence-reduction
        pass (the dominant per-query cost for these small networks)."""
        evidence = dict(evidence or {})
        factors = self._reduced_factors(evidence)
        return {
            n: self.marginal(n, evidence, factors=factors) for n in names
        }


def _empirical_mi(x: np.ndarray, y: np.ndarray, cx: int, cy: int) -> float:
    joint = np.zeros((cx, cy))
    for a, b in zip(x, y):
        joint[int(a), int(b)] += 1.0
    joint /= max(joint.sum(), 1.0)
    px = joint.sum(axis=1, keepdims=True)
    py = joint.sum(axis=0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = joint * (np.log2(joint) - np.log2(px) - np.log2(py))
    return float(np.nansum(t))
