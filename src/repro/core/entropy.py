"""Entropy-based uncertainty quantification (paper §IV-C, Eqs. 3–6)."""

from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

from .bayesnet import BayesNet, Discretizer, Evidence, Factor


def entropy(probs: np.ndarray) -> float:
    """Shannon entropy (Eq. 3), base 2; 0·log0 := 0."""
    p = np.asarray(probs, dtype=np.float64).ravel()
    p = p[p > 0]
    return float(-(p * np.log2(p)).sum())


def binary_entropy(p: float) -> float:
    p = float(np.clip(p, 0.0, 1.0))
    return entropy(np.array([p, 1.0 - p]))


def dynamic_stage_entropy(
    candidate_probs: Mapping[str, float],
    edge_probs: Mapping[Tuple[str, str], float],
) -> float:
    """Eq. (4): node entropy + edge entropy of the candidate graph.

    ``candidate_probs[c]``  = P(stage c is selected by the planner LLM)
    ``edge_probs[(u, v)]``  = P(edge u→v exists in the generated plan)
    Both are learned from the history of realized plans.
    """
    h = 0.0
    for p in candidate_probs.values():
        h += binary_entropy(p)
    for p in edge_probs.values():
        h += binary_entropy(p)
    return h


def conditional_mutual_information(
    bn: BayesNet,
    targets: Sequence[str],
    x: str,
    evidence: Optional[Evidence] = None,
    max_joint: int = 4,
    factors: Optional[Sequence[Factor]] = None,
    marginal_cache: Optional[Dict[str, np.ndarray]] = None,
) -> float:
    """I(Y_1..Y_M ; X | E)  (Eq. 5 with conditioning set E).

    Exact when M ≤ ``max_joint`` (joint table ≤ 7^(max_joint+1) entries);
    for larger M we keep the ``max_joint`` targets whose marginal posterior
    entropy is largest and compute the exact joint MI over those — a lower
    bound that preserves the ranking the scheduler needs.

    ``factors`` optionally carries precomputed evidence-reduced CPD factors
    (:meth:`BayesNet.reduced_factors`), letting callers amortize the
    evidence-reduction pass over many queries against the same evidence.
    ``marginal_cache`` (same contract: one evidence set) shares target
    posteriors across repeated calls.
    """
    evidence = dict(evidence or {})
    targets = [t for t in targets if t != x and t not in evidence]
    if not targets:
        return 0.0
    factors = list(factors) if factors is not None else None
    if len(targets) > max_joint:

        def marg(t: str) -> np.ndarray:
            if marginal_cache is not None and t in marginal_cache:
                return marginal_cache[t]
            m = bn.marginal(t, evidence, factors=factors)
            if marginal_cache is not None:
                marginal_cache[t] = m
            return m

        ents = {t: entropy(marg(t)) for t in targets}
        targets = sorted(targets, key=lambda t: -ents[t])[:max_joint]

    # joint over (targets, x) given evidence
    jf = bn.joint(list(targets) + [x], evidence, factors=factors)
    if x not in jf.vars:  # x fixed by evidence — no information to gain
        return 0.0
    p_joint = jf.reorder(list(targets) + [x]).values
    p_y = p_joint.sum(axis=-1)             # P(Y|E)
    p_x = p_joint.reshape(-1, p_joint.shape[-1]).sum(axis=0)  # P(X|E)

    h_y = entropy(p_y)
    # H(Y | X, E) = sum_x P(x|E) H(Y | X=x, E)
    h_y_given_x = 0.0
    flat = p_joint.reshape(-1, p_joint.shape[-1])
    for xi in range(flat.shape[1]):
        px = p_x[xi]
        if px <= 0:
            continue
        h_y_given_x += px * entropy(flat[:, xi] / px)
    return max(0.0, h_y - h_y_given_x)


def uncertainty_reduction(
    bn: BayesNet,
    discretizers: Mapping[str, Discretizer],
    x: str,
    unscheduled: Iterable[str],
    evidence: Optional[Evidence] = None,
    dynamic_bonus: float = 0.0,
) -> float:
    """R(X)  (Eq. 6): I(Y_1..Y_M; X | E) × Σ_m Range(Y_m)  [+ dynamic bonus].

    ``dynamic_bonus`` carries the Eq. (4) entropy of a dynamic stage whose
    structure is resolved by finishing X (its preceding LLM stage), already
    multiplied by that stage's duration range (paper §IV-C last ¶).
    """
    evidence = dict(evidence or {})
    unsched = [u for u in unscheduled if u != x and u not in evidence]
    correlated = [y for y in unsched if bn.correlated(x, y)]
    if not correlated:
        return float(dynamic_bonus)
    mi = conditional_mutual_information(bn, correlated, x, evidence)
    range_sum = 0.0
    for y in correlated:
        post = bn.marginal(y, evidence)
        range_sum += discretizers[y].range_span(post)
    return float(mi * range_sum + dynamic_bonus)


def uncertainty_reductions(
    bn: BayesNet,
    discretizers: Mapping[str, Discretizer],
    xs: Sequence[str],
    unscheduled: Iterable[str],
    evidence: Optional[Evidence] = None,
    dynamic_bonuses: Optional[Sequence[float]] = None,
) -> list:
    """Batched Eq. 6: R(X) for every X in ``xs`` against one evidence set.

    Produces exactly the same numbers as calling
    :func:`uncertainty_reduction` per stage, but performs the BN
    evidence-reduction pass and the target posteriors once for the whole
    batch — one "forward pass" scores all ready stages of a job.
    """
    evidence = dict(evidence or {})
    unscheduled = list(unscheduled)
    bonuses = (
        list(dynamic_bonuses) if dynamic_bonuses is not None else [0.0] * len(xs)
    )
    factors: Optional[list] = None          # built lazily on first MI query
    post_cache: Dict[str, np.ndarray] = {}  # shared target posteriors
    out = []
    for x, bonus in zip(xs, bonuses):
        unsched = [u for u in unscheduled if u != x and u not in evidence]
        correlated = [y for y in unsched if bn.correlated(x, y)]
        if not correlated:
            out.append(float(bonus))
            continue
        if factors is None:
            factors = bn.reduced_factors(evidence)
        mi = conditional_mutual_information(
            bn, correlated, x, evidence, factors=factors,
            marginal_cache=post_cache,
        )
        range_sum = 0.0
        for y in correlated:
            post = post_cache.get(y)
            if post is None:
                post = bn.marginal(y, evidence, factors=factors)
                post_cache[y] = post
            range_sum += discretizers[y].range_span(post)
        out.append(float(mi * range_sum + bonus))
    return out
