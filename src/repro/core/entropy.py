"""Entropy-based uncertainty quantification (paper §IV-C, Eqs. 3–6)."""

from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional, Sequence, Tuple

import numpy as np

from .bayesnet import BayesNet, Discretizer, Evidence, Factor


def entropy(probs: np.ndarray) -> float:
    """Shannon entropy (Eq. 3), base 2; 0·log0 := 0."""
    p = np.asarray(probs, dtype=np.float64).ravel()
    p = p[p > 0]
    return float(-(p * np.log2(p)).sum())


def binary_entropy(p: float) -> float:
    p = float(np.clip(p, 0.0, 1.0))
    return entropy(np.array([p, 1.0 - p]))


def dynamic_stage_entropy(
    candidate_probs: Mapping[str, float],
    edge_probs: Mapping[Tuple[str, str], float],
) -> float:
    """Eq. (4): node entropy + edge entropy of the candidate graph.

    ``candidate_probs[c]``  = P(stage c is selected by the planner LLM)
    ``edge_probs[(u, v)]``  = P(edge u→v exists in the generated plan)
    Both are learned from the history of realized plans.
    """
    h = 0.0
    for p in candidate_probs.values():
        h += binary_entropy(p)
    for p in edge_probs.values():
        h += binary_entropy(p)
    return h


def conditional_mutual_information(
    bn: BayesNet,
    targets: Sequence[str],
    x: str,
    evidence: Optional[Evidence] = None,
    max_joint: int = 4,
) -> float:
    """I(Y_1..Y_M ; X | E)  (Eq. 5 with conditioning set E).

    Exact when M ≤ ``max_joint`` (joint table ≤ 7^(max_joint+1) entries);
    for larger M we keep the ``max_joint`` targets whose marginal posterior
    entropy is largest and compute the exact joint MI over those — a lower
    bound that preserves the ranking the scheduler needs.
    """
    evidence = dict(evidence or {})
    targets = [t for t in targets if t != x and t not in evidence]
    if not targets:
        return 0.0
    if len(targets) > max_joint:
        ents = {t: entropy(bn.marginal(t, evidence)) for t in targets}
        targets = sorted(targets, key=lambda t: -ents[t])[:max_joint]

    # joint over (targets, x) given evidence
    jf = bn.joint(list(targets) + [x], evidence)
    if x not in jf.vars:  # x fixed by evidence — no information to gain
        return 0.0
    p_joint = jf.reorder(list(targets) + [x]).values
    p_y = p_joint.sum(axis=-1)             # P(Y|E)
    p_x = p_joint.reshape(-1, p_joint.shape[-1]).sum(axis=0)  # P(X|E)

    h_y = entropy(p_y)
    # H(Y | X, E) = sum_x P(x|E) H(Y | X=x, E)
    h_y_given_x = 0.0
    flat = p_joint.reshape(-1, p_joint.shape[-1])
    for xi in range(flat.shape[1]):
        px = p_x[xi]
        if px <= 0:
            continue
        h_y_given_x += px * entropy(flat[:, xi] / px)
    return max(0.0, h_y - h_y_given_x)


def uncertainty_reduction(
    bn: BayesNet,
    discretizers: Mapping[str, Discretizer],
    x: str,
    unscheduled: Iterable[str],
    evidence: Optional[Evidence] = None,
    dynamic_bonus: float = 0.0,
) -> float:
    """R(X)  (Eq. 6): I(Y_1..Y_M; X | E) × Σ_m Range(Y_m)  [+ dynamic bonus].

    ``dynamic_bonus`` carries the Eq. (4) entropy of a dynamic stage whose
    structure is resolved by finishing X (its preceding LLM stage), already
    multiplied by that stage's duration range (paper §IV-C last ¶).
    """
    evidence = dict(evidence or {})
    unsched = [u for u in unscheduled if u != x and u not in evidence]
    correlated = [y for y in unsched if bn.correlated(x, y)]
    if not correlated:
        return float(dynamic_bonus)
    mi = conditional_mutual_information(bn, correlated, x, evidence)
    range_sum = 0.0
    for y in correlated:
        post = bn.marginal(y, evidence)
        range_sum += discretizers[y].range_span(post)
    return float(mi * range_sum + dynamic_bonus)
