"""LLMSched core: the paper's primary contribution.

- :mod:`repro.core.dag`         -- DAG model (regular/LLM/dynamic stages, SIV-A)
- :mod:`repro.core.bayesnet`    -- discrete Bayesian network profiler (SIV-B)
- :mod:`repro.core.calibration` -- batching-aware duration calibration (Eq. 2)
- :mod:`repro.core.entropy`     -- entropy/MI uncertainty quantification (SIV-C)
- :mod:`repro.core.profiler`    -- per-application profiles (BN + discretizers)
- :mod:`repro.core.scheduler`   -- Algorithm 1 (uncertainty-aware eps-greedy)
- :mod:`repro.core.cascade`     -- quality gates + cascade escalation model
- :mod:`repro.core.baselines`   -- FCFS / Fair / SJF / SRTF / Argus / Carbyne / Decima
"""

from .dag import (
    SLO,
    SLO_TIERS,
    ApplicationTemplate,
    Job,
    Stage,
    StageTemplate,
    StageType,
    Task,
    TaskState,
    make_job,
)
from .bayesnet import BayesNet, Discretizer, Factor, fit_discretizer
from .calibration import LatencyProfile, measured_profile, roofline_profile
from .entropy import (
    binary_entropy,
    conditional_mutual_information,
    dynamic_stage_entropy,
    entropy,
    uncertainty_reduction,
)
from .cascade import (
    DeterministicGate,
    QualityGate,
    cascade_cost,
    fleet_ranks,
    stage_difficulty,
)
from .metrics import RunMetrics
from .profiler import AppProfile, JobTrace, ProfileStore
from .scheduler import (
    ClusterView,
    Decision,
    LLMSched,
    Scheduler,
    TaskKey,
    task_key,
)
from .baselines import FCFS, SJF, SRTF, Argus, Carbyne, Decima, Fair, make_baselines

__all__ = [
    "SLO", "SLO_TIERS",
    "ApplicationTemplate", "Job", "Stage", "StageTemplate", "StageType",
    "Task", "TaskState", "make_job",
    "BayesNet", "Discretizer", "Factor", "fit_discretizer",
    "LatencyProfile", "measured_profile", "roofline_profile",
    "binary_entropy", "conditional_mutual_information",
    "dynamic_stage_entropy", "entropy", "uncertainty_reduction",
    "AppProfile", "JobTrace", "ProfileStore", "RunMetrics",
    "DeterministicGate", "QualityGate", "cascade_cost", "fleet_ranks",
    "stage_difficulty",
    "ClusterView", "Decision", "LLMSched", "Scheduler",
    "TaskKey", "task_key",
    "FCFS", "SJF", "SRTF", "Argus", "Carbyne", "Decima", "Fair",
    "make_baselines",
]
