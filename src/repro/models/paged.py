"""Paged KV-cache model path (vLLM-style) for the serving engine.

Instead of one dense ``(B, S_max, K, hd)`` slot cache per attention layer,
K/V live in a shared pool of fixed-size pages, ``(P, page_size, K, hd)``,
and each request owns a *block table* mapping logical token positions to
physical pages.  The same block table is shared by every layer (each
layer has its own physical pool, like vLLM), so allocation is a single
host-side decision per page.

Three entry points, mirroring ``transformer.py``'s cache contract:

- :func:`init_paged_pools` — allocate the per-layer page pools;
- :func:`paged_prefill_chunk` — run one prompt chunk (attending to the
  pages written by earlier chunks) and scatter its K/V into the pools;
  chunked prefill is what lets long prompts interleave with decode steps;
  attention goes through the fused paged-prefill kernel (block tables
  via scalar prefetch) — the chunk never materializes a dense context;
- :func:`paged_decode_step` — one decode token for a batch of requests,
  writing through block tables and attending via the paged kernel.

Pools come in two flavours selected by ``kv_dtype``: ``"fp32"`` stores
pages in the model's compute dtype (the historical layout, bit-for-bit
identical to the slot path), and ``"int8"`` stores int8 pages plus
per-page scale pools (``k_s``/``v_s``, one float32 scale per token slot
per kv head) that both kernels dequantize on the fly.  Quantization
happens exactly once per token, at scatter time, from the exact value —
page bits are therefore a pure function of the tokens they hold, which
keeps prefix-cache adoption, copy-on-write, and migration
token-deterministic under int8.

Supported architectures are the pure-attention decoder families (every
layer ``attn+{mlp,dense_mlp,moe}``, no prefix/cross/MLA/recurrent
layers and no int8 cache) — checked by :func:`supports_paged`.  The
numerics intentionally match the slot path bit-for-bit under greedy
decoding: positions past a request's length are masked to an exact
softmax weight of 0 in both paths, so recycled page garbage can never
reach the output (tested token-for-token in ``tests/test_paged_engine``).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import layers as L
from .config import ModelConfig
from .transformer import _apply_ffn, _scan_layout, layer_kind

Params = Dict[str, Any]
Pools = Dict[str, Any]


def supports_paged(cfg: ModelConfig) -> bool:
    """True when every layer's mixer is plain GQA attention."""
    if cfg.family not in ("dense", "moe") or cfg.kv_cache_dtype == "int8":
        return False
    if cfg.mla is not None or cfg.mamba is not None or cfg.encoder is not None:
        return False
    n_prefix, pat, n_sb = _scan_layout(cfg)
    if n_prefix or n_sb == 0:
        return False
    kinds = [layer_kind(cfg, j).split("+")[0] for j in range(pat)]
    return all(k == "attn" for k in kinds)


KV_DTYPES = ("fp32", "int8")


def init_paged_pools(
    cfg: ModelConfig, num_pages: int, page_size: int, kv_dtype: str = "fp32"
) -> Pools:
    """Per-pattern-position page pools, stacked over superblocks.

    Shape mirrors ``init_cache``'s ``blocks`` tree: pools["blocks"][j] is
    ``{"k","v": (n_sb, P, page_size, K, hd)}``.  With ``kv_dtype="int8"``
    the K/V leaves are int8 and per-page scale pools ride alongside:
    ``{"k_s","v_s": (n_sb, P, page_size, K) float32}``, initialised to a
    neutral scale of 1 (never-written slots dequantize to finite values
    the kernels' masking then discards).
    """
    if not supports_paged(cfg):
        raise ValueError(
            f"config {cfg.name!r} is not paged-KV compatible "
            "(requires a pure-attention decoder, fp/bf16 cache)"
        )
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
    _, pat, n_sb = _scan_layout(cfg)
    K, hd = cfg.n_kv_heads, cfg.hd
    kv_shape = (n_sb, num_pages, page_size, K, hd)

    def one_pool():
        if kv_dtype == "int8":
            return {
                "k": jnp.zeros(kv_shape, jnp.int8),
                "v": jnp.zeros(kv_shape, jnp.int8),
                "k_s": jnp.ones(kv_shape[:-1], jnp.float32),
                "v_s": jnp.ones(kv_shape[:-1], jnp.float32),
            }
        return {"k": jnp.zeros(kv_shape, cfg.jdtype),
                "v": jnp.zeros(kv_shape, cfg.jdtype)}

    return {"blocks": {str(j): one_pool() for j in range(pat)}}


def _scatter_tokens(
    pool: jax.Array,       # (P, ps, K, hd)
    flat_idx: jax.Array,   # (T,) int32 — page*ps + offset per token
    values: jax.Array,     # (T, K, hd)
) -> jax.Array:
    P, ps, K, hd = pool.shape
    flat = pool.reshape(P * ps, K, hd)
    flat = flat.at[flat_idx].set(values.astype(flat.dtype))
    return flat.reshape(P, ps, K, hd)


def _scatter_scales(
    pool: jax.Array,       # (P, ps, K) f32 scale pool
    flat_idx: jax.Array,   # (T,) int32
    scales: jax.Array,     # (T, K)
) -> jax.Array:
    P, ps, K = pool.shape
    flat = pool.reshape(P * ps, K)
    flat = flat.at[flat_idx].set(scales.astype(flat.dtype))
    return flat.reshape(P, ps, K)


def _write_kv(pool: Dict[str, jax.Array], flat_idx, k, v):
    """Scatter one batch of K/V tokens, quantizing when the pool is int8.

    ``k``/``v`` are ``(T, K, hd)``; returns the updated pool dict.
    """
    if "k_s" in pool:
        kq, ks = ops.quantize_kv(k)
        vq, vs = ops.quantize_kv(v)
        return {
            "k": _scatter_tokens(pool["k"], flat_idx, kq),
            "v": _scatter_tokens(pool["v"], flat_idx, vq),
            "k_s": _scatter_scales(pool["k_s"], flat_idx, ks),
            "v_s": _scatter_scales(pool["v_s"], flat_idx, vs),
        }
    return {
        "k": _scatter_tokens(pool["k"], flat_idx, k),
        "v": _scatter_tokens(pool["v"], flat_idx, v),
    }


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def paged_decode_step(
    params: Params,
    cfg: ModelConfig,
    pools: Pools,
    tokens: jax.Array,        # (B,) int32 — one new token per request
    block_tables: jax.Array,  # (B, pages_per_seq) int32
    lengths: jax.Array,       # (B,) int32 — tokens already in cache
) -> Tuple[jax.Array, Pools]:
    """One decode step over paged KV; returns (logits (B, V), pools)."""
    B = tokens.shape[0]
    _, pat, n_sb = _scan_layout(cfg)
    ps = pools["blocks"]["0"]["k"].shape[2]
    x = L.embed(params, tokens[:, None]).astype(cfg.jdtype)

    rows = jnp.arange(B)
    write_page = block_tables[rows, lengths // ps]          # (B,)
    write_flat = write_page * ps + lengths % ps             # (B,)
    kinds = [layer_kind(cfg, j) for j in range(pat)]

    def layer(p: Params, pool: Dict[str, jax.Array], j: int, x: jax.Array):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = L._proj_qkv(p["attn"], cfg, h, h)         # (B,1,·,hd)
        pos = lengths[:, None]
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
        new_pool = _write_kv(pool, write_flat, k[:, 0], v[:, 0])
        out = ops.paged_decode_attention(
            q[:, 0], new_pool["k"], new_pool["v"], block_tables, lengths + 1,
            k_scales=new_pool.get("k_s"), v_scales=new_pool.get("v_s"),
        )
        x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
        x = _apply_ffn(p, cfg, kinds[j], x, decoding=True)
        return x, new_pool

    def body(x, xs):
        new_blk = {}
        for j in range(pat):
            p, pool = xs[str(j)]
            x, new_blk[str(j)] = layer(p, pool, j, x)
        return x, new_blk

    xs = {
        str(j): (params["blocks"][str(j)], pools["blocks"][str(j)])
        for j in range(pat)
    }
    x, new_blocks = jax.lax.scan(body, x, xs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    return logits[:, 0], {"blocks": new_blocks}


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------
def paged_prefill_chunk(
    params: Params,
    cfg: ModelConfig,
    pools: Pools,
    tokens: jax.Array,        # (1, C) int32 — this chunk of the prompt
    block_table: jax.Array,   # (pages_per_seq,) int32
    past: int,                # tokens of this prompt already prefilled
) -> Tuple[jax.Array, Pools]:
    """Run one prompt chunk for a single request; returns (logits, pools).

    The chunk's queries attend causally to (already-paged history + the
    chunk itself); its K/V are scattered into the pools at positions
    ``past .. past+C`` and attention runs through the fused paged-prefill
    kernel over the block table — no dense context view is gathered.
    ``past`` is static per jit specialization — chunk boundaries are
    multiples of the chunk size, so the number of distinct compilations
    is tiny.  Returned logits cover the whole chunk, ``(1, C, V)``.
    """
    _, pat, n_sb = _scan_layout(cfg)
    ps = pools["blocks"]["0"]["k"].shape[2]
    C = tokens.shape[1]
    x = L.embed(params, tokens).astype(cfg.jdtype)
    positions = (past + jnp.arange(C))[None, :]             # (1, C)
    write_flat = block_table[(past + jnp.arange(C)) // ps] * ps + (
        past + jnp.arange(C)
    ) % ps
    kinds = [layer_kind(cfg, j) for j in range(pat)]

    def layer(p: Params, pool: Dict[str, jax.Array], j: int, x: jax.Array):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = L._proj_qkv(p["attn"], cfg, h, h)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        new_pool = _write_kv(pool, write_flat, k[0], v[0])
        out = ops.paged_prefill_attention(
            q[0], new_pool["k"], new_pool["v"], block_table, past,
            k_scales=new_pool.get("k_s"), v_scales=new_pool.get("v_s"),
        )[None]
        x = x + out.reshape(1, C, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
        x = _apply_ffn(p, cfg, kinds[j], x)
        return x, new_pool

    def body(x, xs):
        new_blk = {}
        for j in range(pat):
            p, pool = xs[str(j)]
            x, new_blk[str(j)] = layer(p, pool, j, x)
        return x, new_blk

    xs = {
        str(j): (params["blocks"][str(j)], pools["blocks"][str(j)])
        for j in range(pat)
    }
    x, new_blocks = jax.lax.scan(body, x, xs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    return logits, {"blocks": new_blocks}
