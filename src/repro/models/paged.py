"""Paged KV-cache model path (vLLM-style) for the serving engine.

Instead of one dense ``(B, S_max, K, hd)`` slot cache per attention layer,
K/V live in a shared pool of fixed-size pages, ``(P, page_size, K, hd)``,
and each request owns a *block table* mapping logical token positions to
physical pages.  The same block table is shared by every layer (each
layer has its own physical pool, like vLLM), so allocation is a single
host-side decision per page.

Three entry points, mirroring ``transformer.py``'s cache contract:

- :func:`init_paged_pools` — allocate the per-layer page pools;
- :func:`paged_prefill_chunk` — run one prompt chunk (attending to the
  pages written by earlier chunks) and scatter its K/V into the pools;
  chunked prefill is what lets long prompts interleave with decode steps;
- :func:`paged_decode_step` — one decode token for a batch of requests,
  writing through block tables and attending via the paged kernel.

Supported architectures are the pure-attention decoder families (every
layer ``attn+{mlp,dense_mlp,moe}``, no prefix/cross/MLA/recurrent
layers and no int8 cache) — checked by :func:`supports_paged`.  The
numerics intentionally match the slot path bit-for-bit under greedy
decoding: positions past a request's length are masked to an exact
softmax weight of 0 in both paths, so recycled page garbage can never
reach the output (tested token-for-token in ``tests/test_paged_engine``).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import layers as L
from .config import ModelConfig
from .transformer import _apply_ffn, _scan_layout, layer_kind

Params = Dict[str, Any]
Pools = Dict[str, Any]


def supports_paged(cfg: ModelConfig) -> bool:
    """True when every layer's mixer is plain GQA attention."""
    if cfg.family not in ("dense", "moe") or cfg.kv_cache_dtype == "int8":
        return False
    if cfg.mla is not None or cfg.mamba is not None or cfg.encoder is not None:
        return False
    n_prefix, pat, n_sb = _scan_layout(cfg)
    if n_prefix or n_sb == 0:
        return False
    kinds = [layer_kind(cfg, j).split("+")[0] for j in range(pat)]
    return all(k == "attn" for k in kinds)


def init_paged_pools(
    cfg: ModelConfig, num_pages: int, page_size: int
) -> Pools:
    """Per-pattern-position page pools, stacked over superblocks.

    Shape mirrors ``init_cache``'s ``blocks`` tree: pools["blocks"][j] is
    ``{"k","v": (n_sb, P, page_size, K, hd)}``.
    """
    if not supports_paged(cfg):
        raise ValueError(
            f"config {cfg.name!r} is not paged-KV compatible "
            "(requires a pure-attention decoder, fp/bf16 cache)"
        )
    _, pat, n_sb = _scan_layout(cfg)
    K, hd = cfg.n_kv_heads, cfg.hd
    dt = cfg.jdtype
    blocks = {
        str(j): {
            "k": jnp.zeros((n_sb, num_pages, page_size, K, hd), dt),
            "v": jnp.zeros((n_sb, num_pages, page_size, K, hd), dt),
        }
        for j in range(pat)
    }
    return {"blocks": blocks}


def _scatter_tokens(
    pool: jax.Array,       # (P, ps, K, hd)
    flat_idx: jax.Array,   # (T,) int32 — page*ps + offset per token
    values: jax.Array,     # (T, K, hd)
) -> jax.Array:
    P, ps, K, hd = pool.shape
    flat = pool.reshape(P * ps, K, hd)
    flat = flat.at[flat_idx].set(values.astype(flat.dtype))
    return flat.reshape(P, ps, K, hd)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def paged_decode_step(
    params: Params,
    cfg: ModelConfig,
    pools: Pools,
    tokens: jax.Array,        # (B,) int32 — one new token per request
    block_tables: jax.Array,  # (B, pages_per_seq) int32
    lengths: jax.Array,       # (B,) int32 — tokens already in cache
) -> Tuple[jax.Array, Pools]:
    """One decode step over paged KV; returns (logits (B, V), pools)."""
    B = tokens.shape[0]
    _, pat, n_sb = _scan_layout(cfg)
    ps = pools["blocks"]["0"]["k"].shape[2]
    x = L.embed(params, tokens[:, None]).astype(cfg.jdtype)

    rows = jnp.arange(B)
    write_page = block_tables[rows, lengths // ps]          # (B,)
    write_flat = write_page * ps + lengths % ps             # (B,)
    kinds = [layer_kind(cfg, j) for j in range(pat)]

    def layer(p: Params, pool: Dict[str, jax.Array], j: int, x: jax.Array):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = L._proj_qkv(p["attn"], cfg, h, h)         # (B,1,·,hd)
        pos = lengths[:, None]
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
        pool_k = _scatter_tokens(pool["k"], write_flat, k[:, 0])
        pool_v = _scatter_tokens(pool["v"], write_flat, v[:, 0])
        out = ops.paged_decode_attention(
            q[:, 0], pool_k, pool_v, block_tables, lengths + 1
        )
        x = x + out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
        x = _apply_ffn(p, cfg, kinds[j], x, decoding=True)
        return x, {"k": pool_k, "v": pool_v}

    def body(x, xs):
        new_blk = {}
        for j in range(pat):
            p, pool = xs[str(j)]
            x, new_blk[str(j)] = layer(p, pool, j, x)
        return x, new_blk

    xs = {
        str(j): (params["blocks"][str(j)], pools["blocks"][str(j)])
        for j in range(pat)
    }
    x, new_blocks = jax.lax.scan(body, x, xs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    return logits[:, 0], {"blocks": new_blocks}


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------
def paged_prefill_chunk(
    params: Params,
    cfg: ModelConfig,
    pools: Pools,
    tokens: jax.Array,        # (1, C) int32 — this chunk of the prompt
    block_table: jax.Array,   # (pages_per_seq,) int32
    past: int,                # tokens of this prompt already prefilled
) -> Tuple[jax.Array, Pools]:
    """Run one prompt chunk for a single request; returns (logits, pools).

    The chunk's queries attend causally to (already-paged history + the
    chunk itself); its K/V are scattered into the pools at positions
    ``past .. past+C``.  ``past`` is static per jit specialization —
    chunk boundaries are multiples of the chunk size, so the number of
    distinct compilations is tiny.  Returned logits cover the whole
    chunk, ``(1, C, V)``.
    """
    _, pat, n_sb = _scan_layout(cfg)
    ps = pools["blocks"]["0"]["k"].shape[2]
    C = tokens.shape[1]
    ctx = past + C
    n_ctx_pages = -(-ctx // ps)          # static: pages holding the context
    x = L.embed(params, tokens).astype(cfg.jdtype)
    positions = (past + jnp.arange(C))[None, :]             # (1, C)
    write_flat = block_table[(past + jnp.arange(C)) // ps] * ps + (
        past + jnp.arange(C)
    ) % ps
    ctx_flat = (
        block_table[:n_ctx_pages, None] * ps + jnp.arange(ps)[None, :]
    ).reshape(-1)                                           # (n_ctx_pages*ps,)
    kv_len = jnp.array([ctx], jnp.int32)
    kinds = [layer_kind(cfg, j) for j in range(pat)]

    def layer(p: Params, pool: Dict[str, jax.Array], j: int, x: jax.Array):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        q, k, v = L._proj_qkv(p["attn"], cfg, h, h)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        pool_k = _scatter_tokens(pool["k"], write_flat, k[0])
        pool_v = _scatter_tokens(pool["v"], write_flat, v[0])
        K, hd = cfg.n_kv_heads, cfg.hd
        k_ctx = pool_k.reshape(-1, K, hd)[ctx_flat][None]   # (1, n_ctx, K, hd)
        v_ctx = pool_v.reshape(-1, K, hd)[ctx_flat][None]
        out = ops.attention(
            q, k_ctx, v_ctx, causal=True, q_offset=past, kv_len=kv_len
        )
        x = x + out.reshape(1, C, cfg.n_heads * cfg.hd) @ p["attn"]["wo"]
        x = _apply_ffn(p, cfg, kinds[j], x)
        return x, {"k": pool_k, "v": pool_v}

    def body(x, xs):
        new_blk = {}
        for j in range(pat):
            p, pool = xs[str(j)]
            x, new_blk[str(j)] = layer(p, pool, j, x)
        return x, new_blk

    xs = {
        str(j): (params["blocks"][str(j)], pools["blocks"][str(j)])
        for j in range(pat)
    }
    x, new_blocks = jax.lax.scan(body, x, xs)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    return logits, {"blocks": new_blocks}
