"""State-space / recurrent blocks: Mamba (Jamba's SSM layers) and xLSTM.

Mamba-1 selective scan, faithful to Gu & Dao: in-proj → causal depthwise
conv → data-dependent (Δ, B, C) → selective state-space scan → gate →
out-proj.  The scan itself runs through :func:`repro.kernels.ops.ssm_scan`
(Pallas kernel on TPU, jnp oracle elsewhere).

xLSTM (Beck et al. 2024): mLSTM blocks (matrix memory, exponential gating)
with an sLSTM block every ``slstm_every`` layers.  We implement the
recurrent cells with ``lax.scan``; the sLSTM uses per-head elementwise
recurrence (block-diagonal simplification — noted in DESIGN.md).

Both expose full-sequence (train/prefill) and single-step (decode) forms;
decode state is O(1) in sequence length, which is why these archs run the
``long_500k`` shape.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig
from .layers import Maker, Params


# ---------------------------------------------------------------------------
# Mamba
# ---------------------------------------------------------------------------
def _dt_rank(cfg: ModelConfig) -> int:
    m = cfg.mamba
    return m.dt_rank or max(1, math.ceil(cfg.d_model / 16))


def init_mamba(mk: Maker, cfg: ModelConfig) -> None:
    m = cfg.mamba
    d = cfg.d_model
    di = d * m.expand
    r = _dt_rank(cfg)
    mk.dense("in_proj", (d, 2 * di), ("embed", "ff"))
    mk.dense("conv_w", (m.d_conv, di), ("conv", "ff"))
    mk.dense("conv_b", (di,), ("ff",), zeros=True)
    mk.dense("x_proj", (di, r + 2 * m.d_state), ("ff", None))
    mk.dense("dt_proj", (r, di), (None, "ff"))
    mk.dense("dt_bias", (di,), ("ff",), zeros=True)
    # A_log init: log(1..N) rows (S4D-real)
    a = jnp.broadcast_to(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (di, m.d_state))
    mk.f32("A_log", jnp.log(a), ("ff", "state"))
    mk.dense("D", (di,), ("ff",), ones=True)
    mk.dense("out_proj", (di, d), ("ff", "embed"))


def _mamba_ssm_inputs(p: Params, cfg: ModelConfig, xz: jax.Array):
    m = cfg.mamba
    r = _dt_rank(cfg)
    di = cfg.d_model * m.expand
    x, z = xz[..., :di], xz[..., di:]
    return x, z, r, di


def mamba_full(
    p: Params, cfg: ModelConfig, x_in: jax.Array,
    state: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence Mamba block.  Returns (out, final_state)."""
    m = cfg.mamba
    B, S, d = x_in.shape
    xz = x_in @ p["in_proj"]
    x, z, r, di = _mamba_ssm_inputs(p, cfg, xz)

    # causal depthwise conv over time (kernel d_conv)
    pad = jnp.zeros((B, m.d_conv - 1, di), x.dtype) if state is None else state["conv"]
    xp = jnp.concatenate([pad, x], axis=1)
    conv_state = xp[:, -(m.d_conv - 1):, :] if m.d_conv > 1 else xp[:, :0]
    x = sum(
        xp[:, i : i + S, :] * p["conv_w"][i][None, None, :]
        for i in range(m.d_conv)
    ) + p["conv_b"]
    x = jax.nn.silu(x)

    proj = x @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :r] @ p["dt_proj"] + p["dt_bias"])
    Bm = proj[..., r : r + m.d_state]
    Cm = proj[..., r + m.d_state :]
    A = -jnp.exp(p["A_log"])
    h0 = state["ssm"] if state is not None else None
    y, h = ops.ssm_scan(x, dt, A, Bm, Cm, p["D"], h0=h0)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": conv_state, "ssm": h}


def mamba_decode(
    p: Params, cfg: ModelConfig, x_in: jax.Array, state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token step; state = {conv (B, d_conv-1, di), ssm (B, di, N)}."""
    return mamba_full(p, cfg, x_in, state=state)


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    m = cfg.mamba
    di = cfg.d_model * m.expand
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------
def init_mlstm(mk: Maker, cfg: ModelConfig) -> None:
    x = cfg.xlstm
    d = cfg.d_model
    di = int(d * x.proj_factor)
    mk.dense("up_proj", (d, 2 * di), ("embed", "ff"))
    mk.dense("wq", (di, di), ("ff", None))
    mk.dense("wk", (di, di), ("ff", None))
    mk.dense("wv", (di, di), ("ff", None))
    mk.dense("w_i", (di, x.n_heads), ("ff", None))
    mk.dense("w_f", (di, x.n_heads), ("ff", None))
    mk.dense("w_o", (di, di), ("ff", None))
    mk.dense("down_proj", (di, d), ("ff", "embed"))


def _mlstm_cell(q, k, v, i_gate, f_gate, C, n):
    """One mLSTM step.  C: (B,H,hd,hd) matrix memory, n: (B,H,hd)."""
    C = f_gate[..., None, None] * C + i_gate[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )
    n = f_gate[..., None] * n + i_gate[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q)), 1.0)
    y = jnp.einsum("bhde,bhe->bhd", C, q) / denom[..., None]
    return y, C, n


def mlstm_full(p: Params, cfg: ModelConfig, x_in: jax.Array,
               state=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    xc = cfg.xlstm
    B, S, d = x_in.shape
    di = int(d * xc.proj_factor)
    H = xc.n_heads
    hd = di // H
    up = x_in @ p["up_proj"]
    u, z = up[..., :di], up[..., di:]
    q = (u @ p["wq"]).reshape(B, S, H, hd)
    k = (u @ p["wk"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = (u @ p["wv"]).reshape(B, S, H, hd)
    # stabilized exponential gating (log-space accumulation)
    i_pre = (u @ p["w_i"]).astype(jnp.float32)          # (B,S,H)
    f_pre = (u @ p["w_f"]).astype(jnp.float32)
    log_f = -jax.nn.softplus(-f_pre)                     # log sigmoid(f)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, inp):
        C, n, mst = carry
        qt, kt, vt, it, lft = inp
        m_new = jnp.maximum(lft + mst, it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(lft + mst - m_new)
        y, C, n = _mlstm_cell(
            qt.astype(jnp.float32), kt.astype(jnp.float32),
            vt.astype(jnp.float32), i_g, f_g, C, n,
        )
        return (C, n, m_new), y

    xs = tuple(
        jnp.moveaxis(a, 1, 0)
        for a in (q, k, v, i_pre, log_f)
    )
    (C, n, mst), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(x_in.dtype)
    y = y * jax.nn.silu(z)
    out = (y * jax.nn.sigmoid(u @ p["w_o"])) @ p["down_proj"]
    return out, {"C": C, "n": n, "m": mst}


def init_slstm(mk: Maker, cfg: ModelConfig) -> None:
    d = cfg.d_model
    H = cfg.xlstm.n_heads
    mk.dense("w_izfo", (d, 4 * d), ("embed", "ff"))
    mk.dense("r_izfo", (4 * d,), ("ff",), zeros=True)  # diagonal recurrence
    mk.dense("out_proj", (d, d), (None, "embed"))


def slstm_full(p: Params, cfg: ModelConfig, x_in: jax.Array,
               state=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, d = x_in.shape
    pre = (x_in @ p["w_izfo"]).astype(jnp.float32)       # (B,S,4d)
    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
        m0 = jnp.full((B, d), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]
    r = p["r_izfo"].astype(jnp.float32)

    def step(carry, zt):
        c, n, h, mst = carry
        rec = jnp.concatenate([h, h, h, h], axis=-1) * r[None]
        zi, zz, zf, zo = jnp.split(zt + rec, 4, axis=-1)
        log_f = -jax.nn.softplus(-zf)
        m_new = jnp.maximum(log_f + mst, zi)
        i_g = jnp.exp(zi - m_new)
        f_g = jnp.exp(log_f + mst - m_new)
        c = f_g * c + i_g * jnp.tanh(zz)
        n = f_g * n + i_g
        h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    (c, n, h, mst), ys = jax.lax.scan(step, (c0, n0, h0, m0), jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).astype(x_in.dtype)
    out = y @ p["out_proj"]
    return out, {"c": c, "n": n, "h": h, "m": mst}


def xlstm_init_state(cfg: ModelConfig, batch: int, is_slstm: bool) -> Dict[str, Any]:
    d = cfg.d_model
    x = cfg.xlstm
    if is_slstm:
        return {
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32),
        }
    di = int(d * x.proj_factor)
    hd = di // x.n_heads
    return {
        "C": jnp.zeros((batch, x.n_heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, x.n_heads, hd), jnp.float32),
        "m": jnp.full((batch, x.n_heads), -1e30, jnp.float32),
    }
