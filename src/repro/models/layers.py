"""Core transformer layers: functional JAX (no flax), params as pytrees.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
param tree with tuples of *logical* axis names (resolved to PartitionSpecs
by :mod:`repro.distributed.sharding`).  Weights follow the 2D production
sharding: tensor dims on ``model``, fsdp dim (``embed``) on ``data``.

Attention / norm / scan hot-spots call :mod:`repro.kernels.ops`, which
dispatches to Pallas kernels on TPU and their jnp oracles elsewhere.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ModelConfig

Params = Dict[str, Any]
Specs = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


class Maker:
    """Param factory: builds matching (params, specs) trees."""

    def __init__(self, key: jax.Array, dtype) -> None:
        self.key = key
        self.dtype = dtype
        self.params: Params = {}
        self.specs: Specs = {}
        self._n = 0

    def sub(self, name: str) -> "Maker":
        m = Maker(jax.random.fold_in(self.key, hash(name) % (2 ** 31)), self.dtype)
        self.params[name] = m.params
        self.specs[name] = m.specs
        return m

    def dense(self, name: str, shape, spec, fan_in=None, zeros=False, ones=False):
        self._n += 1
        k = jax.random.fold_in(self.key, self._n)
        if ones:
            arr = jnp.ones(shape, self.dtype)
        elif zeros:
            arr = jnp.zeros(shape, self.dtype)
        else:
            arr = _dense_init(k, shape, self.dtype, fan_in)
        self.params[name] = arr
        self.specs[name] = tuple(spec)
        return arr

    def f32(self, name: str, value: jax.Array, spec):
        self.params[name] = value.astype(jnp.float32)
        self.specs[name] = tuple(spec)
        return value


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(mk: Maker, name: str, d: int) -> None:
    mk.dense(name, (d,), (None,), ones=True)


def rmsnorm(gamma: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    return ops.rmsnorm(x, gamma, eps=eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def init_attention(mk: Maker, cfg: ModelConfig, cross: bool = False) -> None:
    d, hd = cfg.d_model, cfg.hd
    h, k = cfg.n_heads, cfg.n_kv_heads
    mk.dense("wq", (d, h * hd), ("embed", "heads"))
    mk.dense("wk", (d, k * hd), ("embed", "kv_heads"))
    mk.dense("wv", (d, k * hd), ("embed", "kv_heads"))
    mk.dense("wo", (h * hd, d), ("heads", "embed"))
    if cfg.qkv_bias and not cross:
        mk.dense("bq", (h * hd,), ("heads",), zeros=True)
        mk.dense("bk", (k * hd,), ("kv_heads",), zeros=True)
        mk.dense("bv", (k * hd,), ("kv_heads",), zeros=True)


def _proj_qkv(p: Params, cfg: ModelConfig, x: jax.Array, kv_src: jax.Array):
    B, S, _ = x.shape
    Skv = kv_src.shape[1]
    h, k, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    kk = (kv_src @ p["wk"]).reshape(B, Skv, k, hd)
    v = (kv_src @ p["wv"]).reshape(B, Skv, k, hd)
    if "bq" in p:
        q = q + p["bq"].reshape(1, 1, h, hd)
        kk = kk + p["bk"].reshape(1, 1, k, hd)
        v = v + p["bv"].reshape(1, 1, k, hd)
    return q, kk, v


def attention_full(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, S, d)
    positions: jax.Array,          # (B, S)
    causal: bool = True,
    use_rope: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence attention (train / prefill).  Returns (out, kv)."""
    q, k, v = _proj_qkv(p, cfg, x, x)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    out = ops.attention(q, k, v, causal=causal)
    B, S, _ = x.shape
    out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, {"k": k, "v": v}


def attention_decode(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, 1, d)
    cache_k: jax.Array,            # (B, S_max, K, hd)
    cache_v: jax.Array,
    lengths: jax.Array,            # (B,) tokens already in cache
    use_rope: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode; returns (out, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    q, k, v = _proj_qkv(p, cfg, x, x)
    if use_rope:
        pos = lengths[:, None]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    # write new kv at position `lengths`
    onehot = jax.nn.one_hot(lengths, cache_k.shape[1], dtype=cache_k.dtype)
    cache_k = cache_k + onehot[:, :, None, None] * k.astype(cache_k.dtype)
    cache_v = cache_v + onehot[:, :, None, None] * v.astype(cache_v.dtype)
    out = ops.decode_attention(
        q[:, 0], cache_k, cache_v, lengths + 1
    )
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, cache_k, cache_v


def _q8_kv(x: jax.Array):
    """Symmetric int8 per (batch, pos, head): scale over head_dim."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dq8_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode_q8(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, 1, d)
    cache: Dict[str, jax.Array],   # {k,v: int8 (B,S,K,hd); k_s,v_s: (B,S,K)}
    lengths: jax.Array,
    use_rope: bool = True,
):
    """Decode over an int8-quantized KV cache (serving memory optimization).

    New K/V are quantized at write; the cached payload is dequantized on
    the fly inside the attention contraction (XLA fuses convert×dot, so no
    bf16 copy of the cache materializes on TPU).
    """
    B = x.shape[0]
    q, k, v = _proj_qkv(p, cfg, x, x)
    if use_rope:
        pos = lengths[:, None]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    kq, ks = _q8_kv(k)     # (B,1,K,hd), (B,1,K)
    vq, vs = _q8_kv(v)
    onehot = jax.nn.one_hot(lengths, cache["k"].shape[1], dtype=jnp.int8)
    sel = onehot[:, :, None, None]
    new = dict(cache)
    new["k"] = cache["k"] * (1 - sel) + sel * kq
    new["v"] = cache["v"] * (1 - sel) + sel * vq
    oh_f = onehot.astype(jnp.float32)[:, :, None]
    new["k_s"] = cache["k_s"] * (1 - oh_f) + oh_f * ks
    new["v_s"] = cache["v_s"] * (1 - oh_f) + oh_f * vs
    k_deq = _dq8_kv(new["k"], new["k_s"], cfg.jdtype)
    v_deq = _dq8_kv(new["v"], new["v_s"], cfg.jdtype)
    out = ops.decode_attention(q[:, 0], k_deq, v_deq, lengths + 1)
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, new


def cross_attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                   # (B, S, d)
    enc_kv: Tuple[jax.Array, jax.Array],  # precomputed (k, v): (B, S_enc, K, hd)
) -> jax.Array:
    B, S, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k, v = enc_kv
    out = ops.attention(q, k, v, causal=False)
    return out.reshape(B, S, h * hd) @ p["wo"]


def cross_kv(p: Params, cfg: ModelConfig, enc_out: jax.Array):
    B, Se, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------
def init_mla(mk: Maker, cfg: ModelConfig) -> None:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    mk.dense("wq", (d, h * qd), ("embed", "heads"))
    mk.dense("w_dkv", (d, m.kv_lora_rank + m.rope_head_dim), ("embed", "lora"))
    mk.dense("w_uk", (m.kv_lora_rank, h * m.nope_head_dim), ("lora", "heads"))
    mk.dense("w_uv", (m.kv_lora_rank, h * m.v_head_dim), ("lora", "heads"))
    mk.dense("wo", (h * m.v_head_dim, d), ("heads", "embed"))


def _mla_qkv(p, cfg, x, positions):
    """Project to MLA q / compressed kv; returns q, (c_kv, k_rope)."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    q = (x @ p["wq"]).reshape(B, S, h, qd)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    dkv = x @ p["w_dkv"]
    c_kv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand(p, cfg, c_kv, k_rope):
    """Expand compressed cache into per-head K/V (B, S, H, ·)."""
    m = cfg.mla
    B, S, _ = c_kv.shape
    h = cfg.n_heads
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, h, m.nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, S, h, m.v_head_dim)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, m.rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_full(p, cfg, x, positions) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    k, v = _mla_expand(p, cfg, c_kv, k_rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    out = ops.attention(q, k, v, causal=True, scale=scale)
    out = out.reshape(B, S, cfg.n_heads * m.v_head_dim) @ p["wo"]
    # cache is the COMPRESSED latent (the paper's 10×+ KV saving)
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p, cfg, x, cache_c, cache_r, lengths):
    m = cfg.mla
    B = x.shape[0]
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, lengths[:, None])
    onehot = jax.nn.one_hot(lengths, cache_c.shape[1], dtype=cache_c.dtype)
    cache_c = cache_c + onehot[:, :, None] * c_kv.astype(cache_c.dtype)
    cache_r = cache_r + onehot[:, :, None] * k_rope.astype(cache_r.dtype)
    k, v = _mla_expand(p, cfg, cache_c, cache_r)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)[:, 0]
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    out = ops.decode_attention(q, k, v, lengths + 1, scale=scale)
    out = out.reshape(B, 1, cfg.n_heads * m.v_head_dim) @ p["wo"]
    return out, cache_c, cache_r


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------
def init_mlp(mk: Maker, d: int, ff: int) -> None:
    mk.dense("w_gate", (d, ff), ("embed", "ff"))
    mk.dense("w_up", (d, ff), ("embed", "ff"))
    mk.dense("w_down", (ff, d), ("ff", "embed"))


def mlp(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def mlp_ws_decode(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Weight-stationary decode MLP (serve_opt2 variant).

    At decode the activations are tiny (B tokens) while the fsdp-sharded
    weights are huge; XLA's SPMD partitioner still all-gathers the weight
    shards every step.  This shard_map keeps every weight shard where it
    lives and moves only activation partials:

        x (replicated) --slice d over data--> partial h  --psum(data)-->
        silu·u --local (ff/model)--> partial y --psum(model)--> y(d/data)

    Collective bytes per layer drop from O(|W|/model) to O(B·d) —
    ~40x for llama3-405b decode_32k.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import active_mesh

    mesh = active_mesh()
    d = cfg.d_model
    ff = p["w_gate"].shape[-1]
    if (
        mesh is None
        or "data" not in mesh.shape
        or "model" not in mesh.shape
        or d % (mesh.shape["data"]) or ff % mesh.shape["model"]
        or d % mesh.shape["data"]
    ):
        return mlp(p, x)
    dsz = mesh.shape["data"]
    d_l = d // dsz

    def body(xl, wg, wu, wd):
        i = jax.lax.axis_index("data")
        xs = jax.lax.dynamic_slice_in_dim(xl, i * d_l, d_l, axis=-1)
        h = jax.lax.psum(xs @ wg, "data")          # (B,1,ff_m) bf16
        u = jax.lax.psum(xs @ wu, "data")
        a = (jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
        y = jax.lax.psum(a @ wd, "model")          # (B,1,d_l)
        return y

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P("data", "model"), P("data", "model"), P("model", "data")),
        out_specs=P(None, None, "data"),
        check_rep=False,
    )(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embed(mk: Maker, cfg: ModelConfig) -> None:
    mk.dense("embedding", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
             fan_in=cfg.d_model)
    if not cfg.tie_embeddings:
        mk.dense("lm_head", (cfg.d_model, cfg.vocab), ("embed", "vocab"))


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0)


def unembed(p: Params, x: jax.Array, tie: bool) -> jax.Array:
    w = p["embedding"].T if tie else p["lm_head"]
    return x @ w
