"""Mixture-of-Experts: dense one-hot oracle + expert-parallel production path.

Two interchangeable implementations (config ``moe.impl``):

- ``dense``: every expert runs on every token, combined with top-k gate
  weights.  O(E) compute — smoke tests / correctness oracle only.

- ``ep``: production path under shard_map.
    * tokens  : sharded over (pod, data), replicated over ``model``;
    * experts : expert dim sharded over ``data``  (expert parallelism),
                expert-FFN dim sharded over ``model`` (tensor parallelism);
    * dataflow: route top-k locally → sort assignments by destination data
      shard → fixed-capacity all_to_all over ``data`` → local grouped GEMM
      (``jax.lax.ragged_dot``) on each shard's experts → all_to_all back →
      gate-weighted segment_sum → psum over ``model`` (FFN partials).
  Assignments beyond per-destination capacity (capacity_factor) are
  dropped — standard capacity semantics; gate weights renormalize.

Shared experts (DeepSeek/Kimi) always run densely (they see every token).
This layout fits 1T-param MoEs at 256 chips: kimi-k2 expert weights =
2.06 TB bf16 / (16 data × 16 model) ≈ 8 GB/chip.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import Maker, Params, mlp


def init_moe(mk: Maker, cfg: ModelConfig) -> None:
    m = cfg.moe
    d = cfg.d_model
    mk.dense("router", (d, m.n_experts), ("embed", "experts"))
    # expert dim -> data (EP), ffn dim -> model (TP)
    mk.dense("w_gate", (m.n_experts, d, m.d_ff_expert), ("experts_ep", None, "ff"))
    mk.dense("w_up", (m.n_experts, d, m.d_ff_expert), ("experts_ep", None, "ff"))
    mk.dense("w_down", (m.n_experts, m.d_ff_expert, d), ("experts_ep", "ff", None))
    if m.n_shared > 0:
        sh = mk.sub("shared")
        sh.dense("w_gate", (d, m.n_shared * m.d_ff_expert), ("embed", "ff"))
        sh.dense("w_up", (d, m.n_shared * m.d_ff_expert), ("embed", "ff"))
        sh.dense("w_down", (m.n_shared * m.d_ff_expert, d), ("ff", "embed"))


def _routing(p: Params, cfg: ModelConfig, x2d: jax.Array):
    m = cfg.moe
    logits = (x2d @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    return w, ids


def moe_dense(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Oracle: run all experts on all tokens (tiny configs only)."""
    m = cfg.moe
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    w, ids = _routing(p, cfg, x2d)
    comb = jnp.zeros((x2d.shape[0], m.n_experts), jnp.float32)
    comb = comb.at[jnp.arange(x2d.shape[0])[:, None], ids].add(w)
    h = jnp.einsum("td,edf->tef", x2d, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x2d, p["w_up"])
    y_e = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["w_down"])
    y = jnp.einsum("ted,te->td", y_e.astype(jnp.float32), comb).astype(x.dtype)
    if m.n_shared > 0:
        y = y + mlp(p["shared"], x2d)
    return y.reshape(B, S, d)


# ---------------------------------------------------------------------------
# Expert-parallel path
# ---------------------------------------------------------------------------
def _ep_body_dedup(
    x_local: jax.Array,            # (T_l, d)
    router: jax.Array,             # (d, E)
    w_gate: jax.Array,             # (E_l, d, f_l)
    w_up: jax.Array,
    w_down: jax.Array,
    cfg: ModelConfig,
    ep_axis: str,
    ep_size: int,
    tp_axis: Optional[str],
) -> jax.Array:
    """Deduplicated dispatch: one row per (token, destination shard).

    Top-k routing sends each token row up to k times; here a token's row
    crosses the wire once per *shard* owning ≥1 of its experts, with the
    (local expert id, gate weight) list piggybacked (tens of bytes vs a
    14 KB row).  With ``shard_groups`` (DeepSeek node-limited routing
    analogue) the destination count is capped, bounding a2a volume at
    L/k of the naive dispatch.  Receivers expand pairs back to
    assignments locally (HBM, not wire) for the grouped GEMM.
    """
    m = cfg.moe
    T_l, d = x_local.shape
    E = m.n_experts
    # static axis size (capacity math needs a Python int; jax.lax has no
    # axis_size and psum(1, axis) traces under shard_map)
    dsize = ep_size
    E_l = w_gate.shape[0]
    k = m.top_k

    logits = (x_local @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    if m.shard_groups and m.shard_groups < dsize:
        # group-limited routing: keep only the top-L shards by mass
        shard_mass = probs.reshape(T_l, dsize, E_l).sum(-1)      # (T_l, ds)
        _, top_shards = jax.lax.top_k(shard_mass, m.shard_groups)
        allowed = jnp.zeros((T_l, dsize), bool).at[
            jnp.arange(T_l)[:, None], top_shards
        ].set(True)
        probs = jnp.where(
            jnp.repeat(allowed, E_l, axis=1), probs, 0.0
        )
        max_dest = m.shard_groups
    else:
        max_dest = min(k, dsize)
    w, ids = jax.lax.top_k(probs, k)
    w = (w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)).astype(jnp.float32)
    dest = ids // E_l                                            # (T_l, k)
    leid = ids - dest * E_l

    # dense (token, shard) pair table — vectorized, no scatter
    shard_iota = jnp.arange(dsize)[None, :, None]                # (1, ds, 1)
    hit = dest[:, None, :] == shard_iota                         # (T_l, ds, k)
    pair_eid = jnp.where(hit, leid[:, None, :], E_l).astype(jnp.int32)
    pair_w = jnp.where(hit, w[:, None, :], 0.0).astype(jnp.float32)
    pair_exists = hit.any(-1)                                    # (T_l, ds)

    # fixed-capacity packing of pairs per destination
    A = T_l * dsize
    flat_dest = jnp.tile(jnp.arange(dsize)[None], (T_l, 1)).reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T_l), dsize)
    sort_key = jnp.where(pair_exists.reshape(-1), flat_dest, dsize)
    cap = max(8, int(T_l * max_dest * m.capacity_factor) // max(1, dsize))
    order = jnp.argsort(sort_key, stable=True)
    s_dest = sort_key[order]
    starts = jnp.searchsorted(s_dest, jnp.arange(dsize))
    rank = jnp.arange(A) - starts[s_dest]
    keep = (rank < cap) & (s_dest < dsize)
    slot = jnp.where(keep, s_dest * cap + rank, dsize * cap)

    R = dsize * cap
    send_rows = jnp.zeros((R + 1, d), x_local.dtype).at[slot].set(
        x_local[flat_tok[order]]
    )
    send_eid = jnp.full((R + 1, k), E_l, jnp.int32).at[slot].set(
        pair_eid.reshape(A, k)[order]
    )
    send_w = jnp.zeros((R + 1, k), jnp.float32).at[slot].set(
        pair_w.reshape(A, k)[order]
    )

    a2a = functools.partial(
        jax.lax.all_to_all, axis_name=ep_axis, split_axis=0, concat_axis=0,
        tiled=True,
    )
    if m.dispatch_dtype == "int8":
        amax = jnp.max(jnp.abs(send_rows[:-1].astype(jnp.float32)), axis=-1)
        scl = jnp.maximum(amax, 1e-8) / 127.0
        q8 = jnp.clip(
            jnp.round(send_rows[:-1].astype(jnp.float32) / scl[:, None]),
            -127, 127,
        ).astype(jnp.int8)
        recv_rows = (
            a2a(q8).astype(jnp.float32) * a2a(scl[:, None])
        ).astype(x_local.dtype)
    else:
        recv_rows = a2a(send_rows[:-1])
    recv_eid = a2a(send_eid[:-1])
    recv_w = a2a(send_w[:-1])

    # --- receiver: expand pairs -> assignments (local HBM, not wire) ------
    C2 = max(8, int(T_l * k * m.capacity_factor) // max(1, dsize) * dsize)
    C2 = min(C2, R * k)
    a_eid = recv_eid.reshape(-1)                                  # (R*k,)
    a_pair = jnp.repeat(jnp.arange(R), k)
    a_w = recv_w.reshape(-1)
    g_order = jnp.argsort(jnp.where(a_eid < E_l, a_eid, E_l), stable=True)
    g_order = g_order[:C2]
    rows = recv_rows[a_pair[g_order]]                             # (C2, d)
    sel_eid = a_eid[g_order]
    counts = jnp.bincount(jnp.clip(sel_eid, 0, E_l), length=E_l + 1)[:E_l]
    h = jax.lax.ragged_dot(rows, w_gate, group_sizes=counts)
    u = jax.lax.ragged_dot(rows, w_up, group_sizes=counts)
    act = (jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)).astype(rows.dtype)
    yr = jax.lax.ragged_dot(act, w_down, group_sizes=counts)      # (C2, d)
    valid = sel_eid < E_l
    contrib = yr.astype(jnp.float32) * (a_w[g_order] * valid)[:, None]
    y_pairs = jax.ops.segment_sum(contrib, a_pair[g_order], num_segments=R)

    # --- return + combine ---------------------------------------------------
    comb_dt = jnp.float32 if m.combine_dtype == "float32" else jnp.bfloat16
    back = a2a(y_pairs.astype(comb_dt))                           # (R, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)
    y = jax.ops.segment_sum(
        back[slot].astype(jnp.float32), flat_tok[order], num_segments=T_l
    )
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)
    return y.astype(x_local.dtype)


def _ep_body(
    x_local: jax.Array,            # (T_l, d) tokens of this (pod, data) shard
    router: jax.Array,             # (d, E) replicated
    w_gate: jax.Array,             # (E_l, d, f_l)
    w_up: jax.Array,               # (E_l, d, f_l)
    w_down: jax.Array,             # (E_l, f_l, d)
    cfg: ModelConfig,
    ep_axis: str,
    ep_size: int,
    tp_axis: Optional[str],
) -> jax.Array:
    m = cfg.moe
    T_l, d = x_local.shape
    E = m.n_experts
    didx = jax.lax.axis_index(ep_axis)
    dsize = ep_size  # static: capacity/slot shapes below must be Python ints
    E_l = w_gate.shape[0]
    A = T_l * m.top_k                                   # assignments

    w, ids = _routing({"router": router}, cfg, x_local)  # (T_l, k)
    flat_eid = ids.reshape(-1)
    flat_w = w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T_l), m.top_k)
    dest = flat_eid // E_l                               # owning data shard

    # --- pack into fixed-capacity per-destination slots -------------------
    cap = max(8, int(A * m.capacity_factor) // max(1, dsize))
    order = jnp.argsort(dest, stable=True)               # group by dest
    s_dest = dest[order]
    # rank within destination group
    starts = jnp.searchsorted(s_dest, jnp.arange(dsize))
    rank = jnp.arange(A) - starts[s_dest]
    keep = rank < cap
    slot = jnp.where(keep, s_dest * cap + rank, dsize * cap)  # overflow slot

    send_rows = jnp.zeros((dsize * cap + 1, d), x_local.dtype)
    send_rows = send_rows.at[slot].set(x_local[flat_tok[order]])
    send_eid = jnp.full((dsize * cap + 1,), E * dsize, jnp.int32)
    send_eid = send_eid.at[slot].set(flat_eid[order].astype(jnp.int32))

    a2a = functools.partial(
        jax.lax.all_to_all, axis_name=ep_axis, split_axis=0, concat_axis=0,
        tiled=True,
    )
    payload = send_rows[:-1]
    if m.dispatch_dtype == "int8":
        # quantized dispatch (DeepSeek-V3 fp8-dispatch analogue): rowwise
        # int8 payload + f32 scales — 2x fewer a2a wire bytes than bf16
        amax = jnp.max(jnp.abs(payload.astype(jnp.float32)), axis=-1)
        scl = jnp.maximum(amax, 1e-8) / 127.0
        q8 = jnp.clip(
            jnp.round(payload.astype(jnp.float32) / scl[:, None]), -127, 127
        ).astype(jnp.int8)
        recv_q = a2a(q8)
        recv_s = a2a(scl[:, None])[:, 0]
        recv_rows = (recv_q.astype(jnp.float32) * recv_s[:, None]).astype(
            x_local.dtype
        )
    else:
        recv_rows = a2a(payload)
    recv_eid = a2a(send_eid[:-1].reshape(dsize * cap, 1))[:, 0]

    # --- local grouped GEMM ------------------------------------------------
    leid = recv_eid - didx * E_l                          # local expert id
    valid = (leid >= 0) & (leid < E_l)
    leid = jnp.where(valid, leid, E_l)
    g_order = jnp.argsort(leid, stable=True)
    rows = recv_rows[g_order]
    counts = jnp.bincount(jnp.clip(leid, 0, E_l), length=E_l + 1)[:E_l]
    h = jax.lax.ragged_dot(rows, w_gate, group_sizes=counts)
    u = jax.lax.ragged_dot(rows, w_up, group_sizes=counts)
    act = (jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)).astype(rows.dtype)
    yr = jax.lax.ragged_dot(act, w_down, group_sizes=counts)  # (R, d) partial/f
    # unsort back to slot order; zero the invalid rows
    inv = jnp.zeros_like(g_order).at[g_order].set(jnp.arange(g_order.shape[0]))
    yr = yr[inv] * valid[:, None]

    # --- return to source shards + combine ---------------------------------
    back = a2a(yr)                                        # (dsize*cap, d)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)
    contrib = back[slot] * (flat_w[order] * keep)[:, None].astype(back.dtype)
    comb_dt = jnp.float32 if m.combine_dtype == "float32" else jnp.bfloat16
    y = jax.ops.segment_sum(
        contrib.astype(comb_dt), flat_tok[order], num_segments=T_l
    )
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)                      # sum FFN partials
    return y.astype(x_local.dtype)


def moe_ep(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    mesh=None,
) -> jax.Array:
    """Expert-parallel MoE under shard_map; falls back to dense w/o mesh."""
    from jax.experimental.shard_map import shard_map

    from ..distributed.sharding import active_mesh

    mesh = mesh or active_mesh()
    m = cfg.moe
    if mesh is None or "data" not in mesh.shape or m.n_experts % mesh.shape["data"]:
        return moe_dense(p, cfg, x)
    n_tok = x.shape[0] * x.shape[1]
    tok_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
    if n_tok % tok_shards or (n_tok // tok_shards) < m.top_k:
        # decode-style tiny token counts: dense dispatch is cheaper than
        # a degenerate all_to_all (and shard_map needs divisibility)
        return moe_dense(p, cfg, x)
    tp_axis = "model" if "model" in mesh.shape else None
    if tp_axis and m.d_ff_expert % mesh.shape[tp_axis]:
        tp_axis = None

    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    batch_axes: Tuple[str, ...] = tuple(
        a for a in ("pod", "data") if a in mesh.shape
    )

    body_fn = _ep_body_dedup if m.dedup_dispatch else _ep_body
    body = functools.partial(
        body_fn, cfg=cfg, ep_axis="data", ep_size=mesh.shape["data"],
        tp_axis=tp_axis,
    )
    y2d = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None),
            P(None, None),
            P("data", None, tp_axis),
            P("data", None, tp_axis),
            P("data", tp_axis, None),
        ),
        out_specs=P(batch_axes, None),
        check_rep=False,
    )(x2d, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if m.n_shared > 0:
        y2d = y2d + mlp(p["shared"], x2d)
    return y2d.reshape(B, S, d)


def moe_block(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.moe.impl == "ep":
        return moe_ep(p, cfg, x)
    return moe_dense(p, cfg, x)
