"""Step builders + input specs for every (architecture × input shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — used by
the multi-pod dry-run and the roofline analysis.

``build_train_step`` / ``build_decode_step`` / ``build_prefill_step``
return pure functions suitable for ``jax.jit(..., in_shardings=...)``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.optimizer import OptConfig, adamw_update, init_opt_state
from ..distributed.sharding import resolve_spec
from .config import ModelConfig, ShapeConfig
from . import transformer as T


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — dry-run currency)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = sds((B, S), jnp.int32)
        out["targets"] = sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, S), jnp.int32)
    else:  # decode: one new token against a cache of length S
        out["tokens"] = sds((B,), jnp.int32)
        max_len = ((S + 8 + 255) // 256) * 256   # shardable cache length
        out["cache"] = jax.eval_shape(
            lambda: T.init_cache(cfg, B, max_len)
        )
    if cfg.family == "vlm" and shape.kind != "decode":
        out["enc_input"] = sds((B, cfg.encoder.n_ctx, cfg.d_model), cfg.jdtype)
    if cfg.family == "audio" and shape.kind != "decode":
        out["enc_input"] = sds((B, cfg.encoder.n_ctx, cfg.d_model), cfg.jdtype)
    return out


# logical sharding for inputs
def input_logical(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = ("batch", None)
        out["targets"] = ("batch", None)
    elif shape.kind == "prefill":
        out["tokens"] = ("batch", None)
    else:
        out["tokens"] = ("batch",)
        out["cache"] = "__cache__"   # resolved by cache_logical()
    if cfg.family in ("vlm", "audio") and shape.kind != "decode":
        out["enc_input"] = ("batch", None, None)
    return out


def cache_logical(cfg: ModelConfig, cache_shapes, model_axis_size: int):
    """Logical names for every cache leaf, chosen per-arch: KV heads shard
    over ``model`` when divisible, otherwise the cache sequence dim does
    (flash-decode style; XLA inserts the partial-softmax reductions)."""
    heads_divisible = model_axis_size > 0 and cfg.n_kv_heads % model_axis_size == 0
    kv_heads = "kv_heads" if heads_divisible else None
    kv_seq = None if heads_divisible else "kv_seq"

    def map_leaf(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(x.shape)
        if any(getattr(p_, "key", None) == "__cross" for p_ in path):
            base = (None, "batch", None, kv_heads, None)
            return (None,) * max(0, nd - 5) + tuple(base)[-nd:]
        if name in ("k", "v"):
            base = ("batch", kv_seq, kv_heads, None)
        elif name in ("k_s", "v_s"):
            base = ("batch", kv_seq, kv_heads)
        elif name in ("c_kv", "k_rope"):
            base = ("batch", kv_seq, None)
        elif name == "lengths":
            base = ("batch",)
        elif name in ("conv", "ssm", "C", "n", "m", "c", "h"):
            base = ("batch",) + (None,) * (nd - 1)
            base = base[:nd]
        elif name in ("__cross_k", "__cross_v"):
            base = ("batch", None, kv_heads, None)
        else:
            base = (None,) * nd
        pad = nd - len(base)
        return (None,) * pad + tuple(base) if pad >= 0 else tuple(base)[-nd:]

    return jax.tree_util.tree_map_with_path(map_leaf, cache_shapes)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, opt_cfg: Optional[OptConfig] = None):
    opt_cfg = opt_cfg or OptConfig()

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return T.lm_loss(
                p, cfg, batch["tokens"], batch["targets"],
                enc_input=batch.get("enc_input"),
            )

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_state, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        ml = max_len or (tokens.shape[1] + 8)
        return T.prefill(params, cfg, tokens, max_len=ml,
                         enc_input=batch.get("enc_input"))

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, batch):
        return T.decode_step(params, cfg, batch["cache"], batch["tokens"])

    return serve_step


def build_forward(cfg: ModelConfig):
    def fwd(params, batch):
        logits, _ = T.forward(params, cfg, batch["tokens"],
                              enc_input=batch.get("enc_input"))
        return logits

    return fwd


# ---------------------------------------------------------------------------
# Param/opt-state shapes + shardings (dry-run helpers)
# ---------------------------------------------------------------------------
_SPEC_CACHE: Dict[str, Any] = {}


def param_shapes(cfg: ModelConfig) -> Tuple[Any, Any]:
    """(shape_tree, spec_tree) via eval_shape — no allocation.  The spec
    tree is built as a (static) side effect of tracing init_params."""
    key = (cfg.name, cfg.n_layers)
    if key not in _SPEC_CACHE:
        box: Dict[str, Any] = {}

        def init():
            p, s = T.init_params(cfg, jax.random.key(0))
            box["specs"] = s
            return p

        shapes = jax.eval_shape(init)
        _SPEC_CACHE[key] = (shapes, box["specs"])
    return _SPEC_CACHE[key]


def opt_state_shapes(cfg: ModelConfig, opt_cfg: OptConfig, params_shapes):
    return jax.eval_shape(
        lambda: init_opt_state(
            jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shapes),
            opt_cfg,
        )
    )


# ---------------------------------------------------------------------------
# Model-tier cost table (cascade routing currency)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TierSpec:
    """Serving economics of one zoo architecture.

    Attributes
    ----------
    usd_per_mtok : float
        Serving price in $ per million *generated* tokens.  Hand-set to
        API-price-like values, monotone in active parameter count
        within a family (MoE models price by *active* params — Kimi-K2
        at 32B active undercuts dense Llama-405B despite more total
        weight).
    quality : float
        Task-success proxy in [0, 1]: the probability mass of stage
        difficulties this tier clears a quality gate on (see
        :mod:`repro.core.cascade`).  Monotone in price.
    latency_scale : float
        Per-token decode latency multiplier relative to the simulator's
        baseline ``l(b)`` model (1.0 = baseline; cheap tiers decode
        faster, giant tiers slower).
    """

    usd_per_mtok: float
    quality: float
    latency_scale: float


#: Per-architecture tier economics, keyed by the registry arch id
#: (``repro.configs.ARCH_IDS``).
MODEL_TIERS: Dict[str, TierSpec] = {
    "whisper_tiny":         TierSpec(0.05, 0.30, 0.45),
    "xlstm_350m":           TierSpec(0.06, 0.35, 0.50),
    "stablelm_1_6b":        TierSpec(0.10, 0.45, 0.60),
    "deepseek_v2_lite_16b": TierSpec(0.28, 0.60, 0.75),
    "internlm2_20b":        TierSpec(0.35, 0.62, 0.80),
    "llama3_2_vision_90b":  TierSpec(1.20, 0.78, 1.15),
    "qwen1_5_110b":         TierSpec(1.40, 0.80, 1.20),
    "jamba_1_5_large_398b": TierSpec(2.20, 0.86, 1.25),
    "kimi_k2_1t_a32b":      TierSpec(2.40, 0.96, 1.30),
    "llama3_405b":          TierSpec(3.50, 0.90, 1.60),
}

#: Every non-arch-id spelling a ``ModelConfig.name`` or CLI alias can
#: carry, mapped to its arch id — explicit, so resolution never guesses.
_TIER_ALIASES: Dict[str, str] = {
    # published config names
    "stablelm-1.6b": "stablelm_1_6b",
    "internlm2-20b": "internlm2_20b",
    "qwen1.5-110b": "qwen1_5_110b",
    "llama3-405b": "llama3_405b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-tiny": "whisper_tiny",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "xlstm-350m": "xlstm_350m",
    # smoke-config names (same family → same tier economics, so CPU
    # testbeds exercise real heterogeneous routing)
    "stablelm-smoke": "stablelm_1_6b",
    "internlm2-smoke": "internlm2_20b",
    "qwen-smoke": "qwen1_5_110b",
    "llama3-smoke": "llama3_405b",
    "vision-smoke": "llama3_2_vision_90b",
    "jamba-smoke": "jamba_1_5_large_398b",
    "whisper-smoke": "whisper_tiny",
    "kimi-smoke": "kimi_k2_1t_a32b",
    "deepseek-smoke": "deepseek_v2_lite_16b",
    "xlstm-smoke": "xlstm_350m",
}


def resolve_tier(name: str) -> Optional[str]:
    """Map any known model spelling to its tier-table arch id.

    Parameters
    ----------
    name : str
        A registry arch id, a published ``ModelConfig.name``, a smoke-
        config name, or a CLI alias.

    Returns
    -------
    str or None
        The ``MODEL_TIERS`` key, or ``None`` for unknown models (e.g.
        ad-hoc test configs) — callers must gate the cost signal off
        rather than invent a price.
    """
    key = name.strip().lower()
    if key in MODEL_TIERS:
        return key
    return _TIER_ALIASES.get(key)


def tier_spec(name: str) -> Optional[TierSpec]:
    """Return the :class:`TierSpec` for any known model spelling.

    Parameters
    ----------
    name : str
        Any spelling :func:`resolve_tier` accepts.

    Returns
    -------
    TierSpec or None
        The tier economics, or ``None`` for unknown models.
    """
    arch = resolve_tier(name)
    return MODEL_TIERS[arch] if arch is not None else None


def cost_per_token(name: str) -> Optional[float]:
    """Return the serving cost of one generated token, in $.

    Parameters
    ----------
    name : str
        Any spelling :func:`resolve_tier` accepts.

    Returns
    -------
    float or None
        ``usd_per_mtok / 1e6``, or ``None`` for unknown models.
    """
    spec = tier_spec(name)
    return spec.usd_per_mtok / 1e6 if spec is not None else None
