"""Architecture zoo: composable JAX models for all assigned architectures."""

from .config import (
    SHAPES,
    EncoderConfig,
    MLAConfig,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    ShapeConfig,
    XLSTMConfig,
    shape_applicable,
)
from .transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    layer_kind,
    lm_loss,
    prefill,
    superblock_len,
)

__all__ = [
    "SHAPES", "EncoderConfig", "MLAConfig", "MambaConfig", "MoEConfig",
    "ModelConfig", "ShapeConfig", "XLSTMConfig", "shape_applicable",
    "decode_step", "forward", "init_cache", "init_params", "layer_kind",
    "lm_loss", "prefill", "superblock_len",
]
