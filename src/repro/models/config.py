"""Model configuration schema for the architecture zoo.

One :class:`ModelConfig` describes any of the 10 assigned architectures
(dense GQA, VLM, hybrid Mamba/attention, enc-dec audio, MoE, MLA, xLSTM).
`repro.configs.<arch>` files instantiate these with the exact published
numbers plus a reduced smoke variant.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # always-on shared experts (DeepSeek/Kimi)
    layer_period: int = 1       # MoE every k-th layer (Jamba: 2)
    first_dense: int = 0        # leading dense layers (DeepSeek: 1)
    d_ff_dense: int = 0         # ff width of the dense layers
    capacity_factor: float = 1.25
    impl: str = "dense"         # "dense" (one-hot oracle) | "ep" (shard_map)
    combine_dtype: str = "float32"   # psum dtype for expert combine
    dispatch_dtype: str = "bfloat16" # a2a payload ("int8" = quantized
                                     # dispatch, DeepSeek-V3 style)
    dedup_dispatch: bool = False     # send each token row once per dest
                                     # shard (not once per expert)
    shard_groups: int = 0            # >0: token may route to experts on at
                                     # most this many shards (DeepSeek
                                     # node-limited routing analogue)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0        # 0 = no q compression (V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8        # sLSTM block every k-th layer (others mLSTM)
    proj_factor: float = 2.0    # mLSTM up-projection
    n_heads: int = 4
    chunk: int = 64             # chunkwise-parallel mLSTM chunk length


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder of enc-dec (whisper) / vision tower stub of VLMs."""

    n_layers: int = 4
    n_ctx: int = 1500           # precomputed frames / patches (stub input)
    d_model: int = 0            # 0 -> same as decoder


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | vlm | hybrid | audio | moe | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False      # Qwen
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None

    # layer pattern --------------------------------------------------------
    attn_period: int = 1        # hybrid: attention every k-th layer (Jamba 8)
    cross_attn_period: int = 0  # vlm: cross-attn layer every k-th (0 = none)

    # runtime knobs ---------------------------------------------------------
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # "int8" enables quantized KV
    decode_mlp: str = "auto"           # "ws" = weight-stationary shard_map
                                       # MLP for decode (activation psums
                                       # instead of per-step weight gathers)
    scan_layers: bool = True
    remat: str = "full"         # "none" | "full" — activation checkpointing
    max_seq: int = 8192
    sub_quadratic: bool = False # can run long_500k

    # ------------------------------------------------------------------ api
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid pattern: Jamba places attention once per `attn_period`."""
        if self.attn_period <= 1:
            return True
        return (i % self.attn_period) == (self.attn_period // 2)

    def is_cross_layer(self, i: int) -> bool:
        return self.cross_attn_period > 0 and (i % self.cross_attn_period) == (
            self.cross_attn_period - 1
        )

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_dense:
            return False
        return ((i - self.moe.first_dense) % self.moe.layer_period) == 0

    # -- parameter counting (roofline MODEL_FLOPS) ---------------------------
    def param_count(self) -> Tuple[int, int]:
        """(total_params, active_params) — active differs for MoE."""
        d, hd = self.d_model, self.hd
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        total = active = 0
        # embeddings (+ untied head)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        enc_params = 0
        if self.encoder is not None:
            ed = self.encoder.d_model or d
            per = 4 * ed * ed + 3 * ed * self.d_ff if self.d_ff else 4 * ed * ed
            enc_params = self.encoder.n_layers * per
            total += enc_params
            active += enc_params
        for i in range(self.n_layers):
            layer_t = layer_a = 0
            if self.family == "ssm" and self.xlstm is not None:
                f = self.xlstm.proj_factor
                di = int(d * f)
                layer_t = 2 * d * di + di * d + 3 * di * self.xlstm.n_heads * 4
                layer_t += 4 * di * (di // max(1, self.xlstm.n_heads))
                layer_a = layer_t
            else:
                if self.is_attn_layer(i):
                    if self.mla is not None:
                        m = self.mla
                        qdim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                        layer_t += d * qdim                       # q proj
                        layer_t += d * (m.kv_lora_rank + m.rope_head_dim)
                        layer_t += m.kv_lora_rank * self.n_heads * (
                            m.nope_head_dim + m.v_head_dim
                        )
                        layer_t += self.n_heads * m.v_head_dim * d
                    else:
                        layer_t += d * q + 2 * d * kv + q * d
                elif self.mamba is not None:
                    di = d * self.mamba.expand
                    layer_t += 2 * d * di + di * d
                    layer_t += di * (2 * self.mamba.d_state + self.mamba.d_conv + 2)
                layer_a += layer_t
                if self.is_moe_layer(i):
                    m = self.moe
                    e = 3 * d * m.d_ff_expert
                    layer_t += (m.n_experts + m.n_shared) * e + d * m.n_experts
                    layer_a += (m.top_k + m.n_shared) * e + d * m.n_experts
                elif self.moe is not None and i < self.moe.first_dense:
                    ffd = 3 * d * (self.moe.d_ff_dense or self.d_ff)
                    layer_t += ffd
                    layer_a += ffd
                elif self.d_ff > 0:
                    ff = 3 * d * self.d_ff
                    layer_t += ff
                    layer_a += ff
                if self.is_cross_layer(i):
                    layer_t += 2 * d * kv + d * q + q * d
                    layer_a += 2 * d * kv + d * q + q * d
            total += layer_t
            active += layer_a
        return total, active

    def kv_bytes_per_token(self) -> int:
        """Decode-cache bytes per token (per request) — drives Eq. 2 l(b)."""
        b = {"bfloat16": 2, "int8": 1, "float32": 4}[self.kv_cache_dtype]
        if self.family == "ssm":
            return 0  # constant-size recurrent state
        if self.mla is not None:
            per_layer = self.mla.kv_lora_rank + self.mla.rope_head_dim
        else:
            per_layer = 2 * self.n_kv_heads * self.hd
        n_attn = sum(1 for i in range(self.n_layers) if self.is_attn_layer(i))
        if self.family == "hybrid":
            n_attn = sum(
                1 for i in range(self.n_layers) if self.is_attn_layer(i)
            )
        return n_attn * per_layer * b

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assignment: 4 shapes, shared across all 10 archs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "quadratic attention at 524k ctx (skip per assignment)"
    return True, ""
