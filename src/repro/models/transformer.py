"""Composable decoder / enc-dec transformer covering all 10 architectures.

Layer heterogeneity (Jamba's 1:7 attn:mamba, vision cross-attn every 5th,
MoE every k-th, xLSTM's sLSTM every 8th) is handled by *superblocks*: the
repeating pattern unit.  Parameters are stacked over superblocks and the
stack is traversed with ``jax.lax.scan`` — HLO size is O(pattern), not
O(depth), which keeps 512-device SPMD compiles of 126-layer models cheap
and matches production practice (MaxText).

Caches:
- attention layers: slot-based KV cache (B, S_max, K, hd) + lengths (B,)
  — TPU-idiomatic static shapes instead of paged indirection;
- MLA layers: *compressed* latent cache (B, S_max, lora+rope) with the
  weight-absorption decode path (cache never expands to per-head K/V);
- Mamba/xLSTM layers: O(1) recurrent state.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels import ops
from .config import ModelConfig
from . import layers as L
from . import moe as M
from . import ssm as S

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Layer kinds
# ---------------------------------------------------------------------------
def layer_kind(cfg: ModelConfig, i: int) -> str:
    """What lives at absolute layer index i."""
    if cfg.family == "ssm":
        x = cfg.xlstm
        cell = "slstm" if (i % x.slstm_every) == (x.slstm_every - 1) else "mlstm"
        return f"{cell}+none"
    mixer = "attn"
    if cfg.mla is not None:
        mixer = "mla"
    if cfg.mamba is not None and not cfg.is_attn_layer(i):
        mixer = "mamba"
    if cfg.is_cross_layer(i):
        mixer = "cross"
    ffn = "mlp"
    if cfg.is_moe_layer(i):
        ffn = "moe"
    elif cfg.moe is not None and i < cfg.moe.first_dense:
        ffn = "dense_mlp"
    elif cfg.d_ff == 0:
        ffn = "none"
    return f"{mixer}+{ffn}"


def superblock_len(cfg: ModelConfig) -> int:
    periods = [1]
    if cfg.family == "ssm":
        periods.append(cfg.xlstm.slstm_every)
    if cfg.attn_period > 1:
        periods.append(cfg.attn_period)
    if cfg.cross_attn_period > 0:
        periods.append(cfg.cross_attn_period)
    if cfg.moe is not None and cfg.moe.layer_period > 1:
        periods.append(cfg.moe.layer_period)
    return int(math.lcm(*periods))


def _scan_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_prefix, pattern_len, n_superblocks): prefix layers are unscanned
    (e.g. DeepSeek's leading dense layer)."""
    n_prefix = cfg.moe.first_dense if cfg.moe is not None else 0
    pat = superblock_len(cfg)
    rest = cfg.n_layers - n_prefix
    if rest % pat:
        # pattern does not tile the remaining depth: unscanned prefix only
        return cfg.n_layers, 1, 0
    return n_prefix, pat, rest // pat


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(mk: L.Maker, cfg: ModelConfig, kind: str) -> None:
    mixer, ffn = kind.split("+")
    L.init_rmsnorm(mk, "norm1", cfg.d_model)
    if mixer == "attn":
        L.init_attention(mk.sub("attn"), cfg)
    elif mixer == "mla":
        L.init_mla(mk.sub("attn"), cfg)
    elif mixer == "cross":
        L.init_attention(mk.sub("attn"), cfg, cross=True)
    elif mixer == "mamba":
        S.init_mamba(mk.sub("mamba"), cfg)
    elif mixer == "mlstm":
        S.init_mlstm(mk.sub("cell"), cfg)
    elif mixer == "slstm":
        S.init_slstm(mk.sub("cell"), cfg)
    if ffn != "none":
        L.init_rmsnorm(mk, "norm2", cfg.d_model)
    if ffn == "mlp":
        L.init_mlp(mk.sub("mlp"), cfg.d_model, cfg.d_ff)
    elif ffn == "dense_mlp":
        L.init_mlp(mk.sub("mlp"), cfg.d_model, cfg.moe.d_ff_dense or cfg.d_ff)
    elif ffn == "moe":
        M.init_moe(mk.sub("moe"), cfg)


def _init_decoder_layer_for_audio(mk: L.Maker, cfg: ModelConfig) -> None:
    """Whisper decoder layer: self-attn + cross-attn + mlp."""
    L.init_rmsnorm(mk, "norm1", cfg.d_model)
    L.init_attention(mk.sub("attn"), cfg)
    L.init_rmsnorm(mk, "norm_x", cfg.d_model)
    L.init_attention(mk.sub("xattn"), cfg, cross=True)
    L.init_rmsnorm(mk, "norm2", cfg.d_model)
    L.init_mlp(mk.sub("mlp"), cfg.d_model, cfg.d_ff)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Params, Params]:
    """Returns (params, specs) with per-superblock stacked layer weights."""
    mk = L.Maker(key, cfg.jdtype)
    L.init_embed(mk, cfg)
    L.init_rmsnorm(mk, "final_norm", cfg.d_model)

    n_prefix, pat, n_sb = _scan_layout(cfg)

    # prefix (unscanned) layers
    for i in range(n_prefix):
        sub = mk.sub(f"prefix_{i}")
        _init_layer(sub, cfg, layer_kind(cfg, i))

    # scanned superblocks: one stacked tree per pattern position
    if n_sb > 0:
        def make_pos(j: int):
            kind = layer_kind(cfg, n_prefix + j)
            sub_mks = []
            for s in range(n_sb):
                smk = L.Maker(
                    jax.random.fold_in(key, 10_000 + j * 1000 + s), cfg.jdtype
                )
                if cfg.family == "audio":
                    _init_decoder_layer_for_audio(smk, cfg)
                else:
                    _init_layer(smk, cfg, kind)
                sub_mks.append(smk)
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[m.params for m in sub_mks])
            specs = jax.tree.map(
                lambda sp: (None,) + tuple(sp),
                sub_mks[0].specs,
                is_leaf=lambda x: isinstance(x, tuple),
            )
            return stacked, specs

        blocks, bspecs = {}, {}
        for j in range(pat):
            blocks[str(j)], bspecs[str(j)] = make_pos(j)
        mk.params["blocks"] = blocks
        mk.specs["blocks"] = bspecs

    # encoder (whisper) — the conv frontend is stubbed: inputs are frames
    if cfg.encoder is not None and cfg.family == "audio":
        enc_mks = []
        for s in range(cfg.encoder.n_layers):
            emk = L.Maker(jax.random.fold_in(key, 77_000 + s), cfg.jdtype)
            _init_layer(emk, cfg, "attn+mlp")
            enc_mks.append(emk)
        mk.params["encoder"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[m.params for m in enc_mks]
        )
        mk.specs["encoder"] = jax.tree.map(
            lambda sp: (None,) + tuple(sp),
            enc_mks[0].specs,
            is_leaf=lambda x: isinstance(x, tuple),
        )
        fmk = mk.sub("enc_norm")
        L.init_rmsnorm(fmk, "g", cfg.d_model)
    return mk.params, mk.specs


# ---------------------------------------------------------------------------
# forward building blocks
# ---------------------------------------------------------------------------
def _apply_ffn(p: Params, cfg: ModelConfig, kind: str, x: jax.Array,
               decoding: bool = False) -> jax.Array:
    mixer, ffn = kind.split("+")
    if ffn == "none":
        return x
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if ffn in ("mlp", "dense_mlp"):
        if decoding and cfg.decode_mlp == "ws":
            return x + L.mlp_ws_decode(p["mlp"], cfg, h)
        return x + L.mlp(p["mlp"], h)
    return x + M.moe_block(p["moe"], cfg, h)


def _apply_layer_full(
    p: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    enc_kv=None,
    causal: bool = True,
):
    """Full-sequence layer; returns (x, cache_contrib)."""
    mixer, _ = kind.split("+")
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    cache = None
    if mixer == "attn":
        out, kv = L.attention_full(p["attn"], cfg, h, positions, causal=causal)
        cache = kv
        x = x + out
    elif mixer == "mla":
        out, ckv = L.mla_full(p["attn"], cfg, h, positions)
        cache = ckv
        x = x + out
    elif mixer == "cross":
        x = x + L.cross_attention(p["attn"], cfg, h, enc_kv)
    elif mixer == "mamba":
        out, st = S.mamba_full(p["mamba"], cfg, h)
        cache = st
        x = x + out
    elif mixer == "mlstm":
        out, st = S.mlstm_full(p["cell"], cfg, h)
        cache = st
        x = x + out
    elif mixer == "slstm":
        out, st = S.slstm_full(p["cell"], cfg, h)
        cache = st
        x = x + out
    x = _apply_ffn(p, cfg, kind, x)
    x = constrain(x, ("batch", "seq_act", None))
    return x, cache


def _apply_layer_decode(
    p: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    cache: Any,
    lengths: jax.Array,
    enc_kv=None,
):
    mixer, _ = kind.split("+")
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    new_cache = cache
    if mixer == "attn":
        if cfg.kv_cache_dtype == "int8":
            out, new_cache = L.attention_decode_q8(p["attn"], cfg, h, cache, lengths)
        else:
            out, ck, cv = L.attention_decode(
                p["attn"], cfg, h, cache["k"], cache["v"], lengths
            )
            new_cache = {"k": ck, "v": cv}
        x = x + out
    elif mixer == "mla":
        out, cc, cr = _mla_decode_absorbed(p["attn"], cfg, h, cache, lengths)
        new_cache = {"c_kv": cc, "k_rope": cr}
        x = x + out
    elif mixer == "cross":
        x = x + L.cross_attention(p["attn"], cfg, h, enc_kv)
    elif mixer == "mamba":
        out, st = S.mamba_decode(p["mamba"], cfg, h, cache)
        new_cache = st
        x = x + out
    elif mixer == "mlstm":
        out, st = S.mlstm_full(p["cell"], cfg, h, state=cache)
        new_cache = st
        x = x + out
    elif mixer == "slstm":
        out, st = S.slstm_full(p["cell"], cfg, h, state=cache)
        new_cache = st
        x = x + out
    x = _apply_ffn(p, cfg, kind, x, decoding=True)
    x = constrain(x, ("dec_batch", None, None))
    return x, new_cache


def _mla_decode_absorbed(p, cfg, x, cache, lengths):
    """MLA decode with weight absorption: attention runs in the compressed
    latent space; the per-head K/V are never materialized (the key MLA
    serving optimization — cache stays (S, lora+rope))."""
    m = cfg.mla
    B = x.shape[0]
    h = cfg.n_heads
    q_nope, q_rope, c_kv_new, k_rope_new = L._mla_qkv(p, cfg, x, lengths[:, None])
    cache_c, cache_r = cache["c_kv"], cache["k_rope"]
    onehot = jax.nn.one_hot(lengths, cache_c.shape[1], dtype=cache_c.dtype)
    cache_c = cache_c + onehot[:, :, None] * c_kv_new.astype(cache_c.dtype)
    cache_r = cache_r + onehot[:, :, None] * k_rope_new.astype(cache_r.dtype)
    # absorb W_uk into q:  q' = q_nope @ W_uk^T  -> latent space
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk)        # (B,H,lora)
    S_max = cache_c.shape[1]
    logits = jnp.einsum("bhl,bsl->bhs", q_lat.astype(jnp.float32),
                        cache_c.astype(jnp.float32))
    logits += jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                         cache_r.astype(jnp.float32))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    mask = jnp.arange(S_max)[None, :] < (lengths + 1)[:, None]
    logits = logits * scale + jnp.where(mask, 0.0, -1e30)[:, None, :]
    w = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhs,bsl->bhl", w, cache_c.astype(jnp.float32))  # latent ctx
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhl,lhv->bhv", ctx, w_uv)                  # (B,H,v)
    out = out.reshape(B, 1, h * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return out, cache_c, cache_r


# ---------------------------------------------------------------------------
# encoder (whisper) / cross-kv precompute (vlm + audio)
# ---------------------------------------------------------------------------
def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed (stub) frame embeddings."""
    B, Se, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    x = frames

    def body(x, p):
        x, _ = _apply_layer_full(p, cfg, "attn+mlp", x, pos, causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_norm"]["g"], x, cfg.norm_eps)


def _cross_kvs(params: Params, cfg: ModelConfig, enc_out: jax.Array):
    """Precompute cross-attn K/V for every cross layer (stacked)."""
    n_prefix, pat, n_sb = _scan_layout(cfg)
    out = {}
    if cfg.family == "audio":
        for j in range(pat):
            p = params["blocks"][str(j)]
            k, v = jax.vmap(
                lambda pj: L.cross_kv(pj["xattn"], cfg, enc_out)
            )(p)
            out[str(j)] = (k, v)
        return out
    for j in range(pat):
        if layer_kind(cfg, n_prefix + j).startswith("cross"):
            p = params["blocks"][str(j)]
            k, v = jax.vmap(lambda pj: L.cross_kv(pj["attn"], cfg, enc_out))(p)
            out[str(j)] = (k, v)
    return out


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,                       # (B, S)
    enc_input: Optional[jax.Array] = None,   # vlm patches / audio frames
    collect_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """Returns (logits (B,S,V), cache or None)."""
    B, Sq = tokens.shape
    x = L.embed(params, tokens).astype(cfg.jdtype)
    x = constrain(x, ("batch", None, None))
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    enc_out = None
    cross = {}
    if cfg.family == "audio":
        enc_out = encode(params, cfg, enc_input)
        cross = _cross_kvs(params, cfg, enc_out)
    elif cfg.family == "vlm" and enc_input is not None:
        cross = _cross_kvs(params, cfg, enc_input.astype(cfg.jdtype))

    n_prefix, pat, n_sb = _scan_layout(cfg)
    caches: Dict[str, Any] = {}

    for i in range(n_prefix):
        kind = layer_kind(cfg, i)
        x, c = _apply_layer_full(params[f"prefix_{i}"], cfg, kind, x, positions)
        if collect_cache and c is not None:
            caches[f"prefix_{i}"] = c

    if n_sb > 0:
        kinds = [layer_kind(cfg, n_prefix + j) for j in range(pat)]
        if cfg.family == "audio":
            kinds = ["audio_dec"] * pat

        xs = {}
        for j in range(pat):
            blk = dict(params["blocks"][str(j)])
            if cfg.family == "audio" or str(j) in cross:
                blk["__cross_k"], blk["__cross_v"] = cross[str(j)]
            xs[str(j)] = blk

        def body(x, xs):
            new_caches = {}
            for j in range(pat):
                p = xs[str(j)]
                kv = None
                if "__cross_k" in p:
                    kv = (p["__cross_k"], p["__cross_v"])
                if cfg.family == "audio":
                    x2, c = _audio_dec_layer_full(p, cfg, x, positions, kv)
                    new_caches[str(j)] = c
                else:
                    x2, c = _apply_layer_full(
                        p, cfg, kinds[j], x, positions, enc_kv=kv
                    )
                    if c is not None:
                        new_caches[str(j)] = c
                x = x2
            return x, (new_caches if collect_cache else None)

        if cfg.remat == "full":
            body = jax.checkpoint(body)
        x, scan_caches = jax.lax.scan(body, x, xs)
        if collect_cache and scan_caches is not None:
            caches["blocks"] = scan_caches

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    logits = constrain(logits, ("batch", None, "vocab_act"))
    out_cache = caches if collect_cache else None
    if collect_cache and enc_out is not None:
        out_cache["__enc_out"] = enc_out
    return logits, out_cache


def _audio_dec_layer_full(p, cfg, x, positions, enc_kv):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    out, kv = L.attention_full(p["attn"], cfg, h, positions, causal=True)
    x = x + out
    hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
    x = x + L.cross_attention(p["xattn"], cfg, hx, enc_kv)
    h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h2)
    return x, kv


def _audio_dec_layer_decode(p, cfg, x, cache, lengths, enc_kv):
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    out, ck, cv = L.attention_decode(p["attn"], cfg, h, cache["k"], cache["v"], lengths)
    x = x + out
    hx = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
    x = x + L.cross_attention(p["xattn"], cfg, hx, enc_kv)
    h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    x = x + L.mlp(p["mlp"], h2)
    return x, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# KV cache allocation + decode
# ---------------------------------------------------------------------------
def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    enc_input: Optional[jax.Array] = None,
    params: Optional[Params] = None,
) -> Dict[str, Any]:
    """Allocate empty caches (+ precomputed cross K/V when params given)."""
    dt = cfg.jdtype
    K, hd = cfg.n_kv_heads, cfg.hd
    n_prefix, pat, n_sb = _scan_layout(cfg)

    def attn_cache(lead=()):
        if cfg.kv_cache_dtype == "int8":
            return {
                "k": jnp.zeros(lead + (batch, max_len, K, hd), jnp.int8),
                "v": jnp.zeros(lead + (batch, max_len, K, hd), jnp.int8),
                "k_s": jnp.zeros(lead + (batch, max_len, K), jnp.float32),
                "v_s": jnp.zeros(lead + (batch, max_len, K), jnp.float32),
            }
        return {
            "k": jnp.zeros(lead + (batch, max_len, K, hd), dt),
            "v": jnp.zeros(lead + (batch, max_len, K, hd), dt),
        }

    def mla_cache(lead=()):
        m = cfg.mla
        return {
            "c_kv": jnp.zeros(lead + (batch, max_len, m.kv_lora_rank), dt),
            "k_rope": jnp.zeros(lead + (batch, max_len, m.rope_head_dim), dt),
        }

    caches: Dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
    for i in range(n_prefix):
        mixer = layer_kind(cfg, i).split("+")[0]
        if mixer == "attn":
            caches[f"prefix_{i}"] = attn_cache()
        elif mixer == "mla":
            caches[f"prefix_{i}"] = mla_cache()
        elif mixer == "mamba":
            caches[f"prefix_{i}"] = S.mamba_init_state(cfg, batch, dt)
    if n_sb > 0:
        blocks = {}
        for j in range(pat):
            if cfg.family == "audio":
                blocks[str(j)] = attn_cache((n_sb,))
                continue
            mixer = layer_kind(cfg, n_prefix + j).split("+")[0]
            if mixer == "attn":
                blocks[str(j)] = attn_cache((n_sb,))
            elif mixer == "cross":
                blocks[str(j)] = {}  # cross K/V live in __cross (static)
            elif mixer == "mla":
                blocks[str(j)] = mla_cache((n_sb,))
            elif mixer == "mamba":
                st = S.mamba_init_state(cfg, batch, dt)
                blocks[str(j)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n_sb,) + a.shape), st
                )
            elif mixer in ("mlstm", "slstm"):
                st = S.xlstm_init_state(cfg, batch, mixer == "slstm")
                blocks[str(j)] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n_sb,) + a.shape), st
                )
        caches["blocks"] = blocks
    if cfg.family in ("audio", "vlm"):
        if params is not None and enc_input is not None:
            enc_out = (
                encode(params, cfg, enc_input)
                if cfg.family == "audio"
                else enc_input.astype(cfg.jdtype)
            )
            caches["__cross"] = _cross_kvs(params, cfg, enc_out)
        else:
            # stub cross K/V (dry-run decode: filled by prefill in real runs)
            Se = cfg.encoder.n_ctx if cfg.encoder is not None else 0
            cross: Dict[str, Any] = {}
            for j in range(pat):
                is_cross = cfg.family == "audio" or layer_kind(
                    cfg, n_prefix + j
                ).startswith("cross")
                if is_cross and Se:
                    kv_shape = (n_sb, batch, Se, K, hd)
                    cross[str(j)] = (
                        jnp.zeros(kv_shape, dt),
                        jnp.zeros(kv_shape, dt),
                    )
            if cross:
                caches["__cross"] = cross
    return caches


def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    max_len: int,
    enc_input: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Run the prompt, build the decode cache.  Returns (last_logits, cache)."""
    B, Sq = tokens.shape
    logits, run_cache = forward(
        params, cfg, tokens, enc_input=enc_input, collect_cache=True
    )
    cache = init_cache(cfg, B, max_len, enc_input=enc_input, params=params)
    cache["lengths"] = jnp.full((B,), Sq, jnp.int32)

    # place prefill K/V into the slot caches
    def place_attn(dst, src):  # src (…, B, Sq, K, hd) -> dst (…, B, max, K, hd)
        return jax.lax.dynamic_update_slice_in_dim(dst, src.astype(dst.dtype), 0, axis=-3)

    def place_attn_q8(dst_blk, src_k, src_v):
        kq, ks = L._q8_kv(src_k)
        vq, vs = L._q8_kv(src_v)
        out = dict(dst_blk)
        out["k"] = jax.lax.dynamic_update_slice_in_dim(dst_blk["k"], kq, 0, axis=-3)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(dst_blk["v"], vq, 0, axis=-3)
        out["k_s"] = jax.lax.dynamic_update_slice_in_dim(dst_blk["k_s"], ks, 0, axis=-2)
        out["v_s"] = jax.lax.dynamic_update_slice_in_dim(dst_blk["v_s"], vs, 0, axis=-2)
        return out

    for key_, c in (run_cache or {}).items():
        if key_ == "__enc_out":
            continue
        if key_ == "blocks":
            for j, blk in c.items():
                dst = cache["blocks"][j]
                if "k" in blk and cfg.kv_cache_dtype == "int8":
                    cache["blocks"][j] = place_attn_q8(dst, blk["k"], blk["v"])
                elif "k" in blk:
                    dst["k"] = place_attn(dst["k"], blk["k"])
                    dst["v"] = place_attn(dst["v"], blk["v"])
                elif "c_kv" in blk:
                    dst["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                        dst["c_kv"], blk["c_kv"].astype(dst["c_kv"].dtype), 0, axis=-2
                    )
                    dst["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                        dst["k_rope"], blk["k_rope"].astype(dst["k_rope"].dtype), 0, axis=-2
                    )
                else:  # recurrent states: final state replaces init
                    cache["blocks"][j] = blk
        else:
            dst = cache[key_]
            if "k" in c and cfg.kv_cache_dtype == "int8":
                cache[key_] = place_attn_q8(dst, c["k"], c["v"])
            elif "k" in c:
                dst["k"] = place_attn(dst["k"], c["k"])
                dst["v"] = place_attn(dst["v"], c["v"])
            elif "c_kv" in c:
                dst["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                    dst["c_kv"], c["c_kv"].astype(dst["c_kv"].dtype), 0, axis=-2
                )
                dst["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                    dst["k_rope"], c["k_rope"].astype(dst["k_rope"].dtype), 0, axis=-2
                )
            else:
                cache[key_] = c
    return logits[:, -1], cache


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: Dict[str, Any],
    tokens: jax.Array,                 # (B,) or (B,1)
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step for the whole batch; returns (logits (B,V), cache)."""
    if tokens.ndim == 1:
        tokens = tokens[:, None]
    B = tokens.shape[0]
    lengths = cache["lengths"]
    x = L.embed(params, tokens).astype(cfg.jdtype)
    x = constrain(x, ("dec_batch", None, None))
    n_prefix, pat, n_sb = _scan_layout(cfg)
    cross = cache.get("__cross", {})
    new_cache: Dict[str, Any] = dict(cache)

    for i in range(n_prefix):
        kind = layer_kind(cfg, i)
        x, c = _apply_layer_decode(
            params[f"prefix_{i}"], cfg, kind, x, cache.get(f"prefix_{i}"), lengths
        )
        new_cache[f"prefix_{i}"] = c

    if n_sb > 0:
        kinds = [layer_kind(cfg, n_prefix + j) for j in range(pat)]

        def body(x, xs):
            p_and_c = xs
            new_blk = {}
            for j in range(pat):
                p, c = p_and_c[str(j)]
                kv = None
                if "__cross_k" in p:
                    kv = (p["__cross_k"], p["__cross_v"])
                if cfg.family == "audio":
                    x2, nc = _audio_dec_layer_decode(p, cfg, x, c, lengths, kv)
                else:
                    x2, nc = _apply_layer_decode(
                        p, cfg, kinds[j], x, c, lengths, enc_kv=kv
                    )
                new_blk[str(j)] = nc
                x = x2
            return x, new_blk

        xs = {}
        for j in range(pat):
            blk = dict(params["blocks"][str(j)])
            if str(j) in cross:
                blk["__cross_k"], blk["__cross_v"] = cross[str(j)]
            xs[str(j)] = (blk, cache["blocks"][str(j)])
        x, nb = jax.lax.scan(body, x, xs)
        new_cache["blocks"] = nb

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params, x, cfg.tie_embeddings)
    new_cache["lengths"] = lengths + 1
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def lm_loss(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    targets: jax.Array,
    enc_input: Optional[jax.Array] = None,
) -> jax.Array:
    logits, _ = forward(params, cfg, tokens, enc_input=enc_input)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
