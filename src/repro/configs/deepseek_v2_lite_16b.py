"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE: 2 shared + 64 routed
top-6; first layer dense.  [arXiv:2405.04434]"""
from ..models.config import MLAConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        head_dim=128,
        d_ff=1408, vocab=102400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                      rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                      n_shared=2, first_dense=1, d_ff_dense=10944, impl="ep"),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16,
        d_ff=96, vocab=256, max_seq=128,
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                      n_shared=1, first_dense=1, d_ff_dense=192, impl="dense"),
    )
