"""internlm2-20b — dense GQA (kv=8).  [arXiv:2403.17297]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92544, rope_theta=1_000_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=192, vocab=256, max_seq=128,
    )
