"""kimi-k2-1t-a32b — trillion-param MoE: 384 experts top-8 (+1 shared),
GQA kv=8 per the assignment table.  [arXiv:2501.kimi2 paper-table]"""
from ..models.config import MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        head_dim=128,
        d_ff=2048, vocab=163840,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                      n_shared=1, impl="ep"),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, max_seq=128,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                      n_shared=1, impl="dense"),
    )
