"""xlstm-350m — sLSTM + mLSTM blocks (7:1), no separate FFN (d_ff=0).
Recurrent state (no KV growth) -> runs long_500k.  [arXiv:2405.04517]"""
from ..models.config import ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        xlstm=XLSTMConfig(slstm_every=8, proj_factor=2.0, n_heads=4),
        sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=0, vocab=256, max_seq=128,
        xlstm=XLSTMConfig(slstm_every=4, proj_factor=2.0, n_heads=2),
        sub_quadratic=True,
    )
