"""llama3-405b — dense GQA (kv=8), 128k vocab.  [arXiv:2407.21783]"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab=128256, rope_theta=500_000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-smoke", family="dense",
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2,
        d_ff=192, vocab=256, max_seq=128,
    )
