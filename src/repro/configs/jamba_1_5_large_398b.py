"""jamba-1.5-large-398b — hybrid: Mamba+attention 1:7, MoE 16e top-2 every
other layer.  Sub-quadratic -> runs long_500k.  [arXiv:2403.19887]"""
from ..models.config import MambaConfig, MoEConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=24576, vocab=65536,
        attn_period=8,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                      layer_period=2, impl="ep"),
        sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, attn_period=8, max_seq=128,
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                      layer_period=2, impl="dense"),
        sub_quadratic=True,
    )
