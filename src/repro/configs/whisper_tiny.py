"""whisper-tiny — enc-dec; conv frontend STUB (precomputed frame
embeddings (B, 1500, 384) via input_specs()).  [arXiv:2212.04356]"""
from ..models.config import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab=51865,
        encoder=EncoderConfig(n_layers=4, n_ctx=1500),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, max_seq=128,
        encoder=EncoderConfig(n_layers=2, n_ctx=32),
    )
