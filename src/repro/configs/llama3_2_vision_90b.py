"""llama-3.2-vision-90b — VLM: cross-attn image layers every 5th layer.
Vision frontend is a STUB: input_specs() supplies precomputed patch
embeddings (B, 1601, d_model).  [hf:meta-llama/Llama-3.2-90B-Vision]"""
from ..models.config import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128256, rope_theta=500_000.0,
        cross_attn_period=5,
        encoder=EncoderConfig(n_layers=0, n_ctx=1601),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="vision-smoke", family="vlm",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, cross_attn_period=5, max_seq=128,
        encoder=EncoderConfig(n_layers=0, n_ctx=17),
    )
