"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config(name)`` returns the exact published config;
``get_smoke_config(name)`` returns a same-family reduced config that runs
one forward/train step on CPU in seconds.
"""

from __future__ import annotations

from importlib import import_module
from typing import Dict, List

from ..models.config import ModelConfig, SHAPES, ShapeConfig, shape_applicable

ARCH_IDS: List[str] = [
    "stablelm_1_6b",
    "internlm2_20b",
    "qwen1_5_110b",
    "llama3_405b",
    "llama3_2_vision_90b",
    "jamba_1_5_large_398b",
    "whisper_tiny",
    "kimi_k2_1t_a32b",
    "deepseek_v2_lite_16b",
    "xlstm_350m",
]

# CLI aliases (assignment spelling -> module name)
ALIASES: Dict[str, str] = {
    "stablelm-1.6b": "stablelm_1_6b",
    "internlm2-20b": "internlm2_20b",
    "qwen1.5-110b": "qwen1_5_110b",
    "llama3-405b": "llama3_405b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-tiny": "whisper_tiny",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "xlstm-350m": "xlstm_350m",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    return import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "ALIASES", "SHAPES", "ShapeConfig", "ModelConfig",
    "get_config", "get_smoke_config", "all_configs", "shape_applicable",
]
