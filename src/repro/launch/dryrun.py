import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we:
  1. build the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. resolve in/out shardings from the logical rules,
  3. ``jax.jit(step).lower(**input_specs).compile()``  (no allocation),
  4. record memory_analysis / cost_analysis / per-collective bytes parsed
     from the compiled HLO into benchmarks/artifacts/dryrun_<...>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
      --shape train_4k [--multi-pod] [--all] [--list]
"""

import argparse
import json
import pathlib
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, ALIASES, SHAPES, get_config, shape_applicable
from repro.distributed import sharding as shd
from repro.distributed.optimizer import OptConfig
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.models.config import ModelConfig, ShapeConfig

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"

# TPU v5e constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link


# ---------------------------------------------------------------------------
# Collective-bytes extraction from compiled HLO
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|s64|f64)\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f32": 4, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


# HLO line: `%name = <shape|(tuple)> <opcode>(...)`
_OP_RE = re.compile(r"=\s*(\([^)]*\)|\S+)\s+([a-z][a-z0-9\-]*)\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"(?:to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_TRIP_RE = re.compile(r"known_trip_count[^}]*\"n\"\s*:\s*\"(\d+)\"")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _parse_computations(hlo_text: str):
    """Split HLO text into named computation blocks."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective bytes with while-loop trip counts applied.

    XLA keeps scan loops rolled, so a naive text scan counts each in-loop
    collective once.  This parser walks the computation graph from ENTRY:
    a ``while`` contributes trip_count × (its body closure's bytes), where
    the trip count is the largest integer constant in the loop condition
    (scan conditions compare the induction variable against the length).
    `-done` ops are skipped (counted at `-start`).
    """
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        comps = {"__all__": hlo_text.splitlines()}
        entry = "__all__"

    def line_colls(line):
        m = _OP_RE.search(line)
        if not m:
            return None
        shape_txt, opcode = m.group(1), m.group(2)
        if opcode.endswith("-done"):
            return None
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLL_KINDS:
            return base, _shape_bytes(shape_txt)
        return None

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, ()):
            consts += [int(x) for x in _CONST_INT.findall(line)]
        return max(consts) if consts else 1

    def walk(name: str, seen) -> Dict[str, int]:
        if name in seen:
            return {k: 0 for k in _COLL_KINDS} | {"count": 0}
        seen = seen | {name}
        acc = {k: 0 for k in _COLL_KINDS}
        acc["count"] = 0
        for line in comps.get(name, ()):
            lc = line_colls(line)
            if lc:
                acc[lc[0]] += lc[1]
                acc["count"] += 1
            if " while(" in line:
                mb = _BODY_RE.search(line)
                mc = _COND_RE.search(line)
                if mb:
                    sub = walk(mb.group(1), seen)
                    mt = _TRIP_RE.search(line)  # XLA's known_trip_count
                    if mt:
                        t = int(mt.group(1))
                    else:
                        t = trip_count(mc.group(1)) if mc else 1
                    for k in acc:
                        acc[k] += t * sub[k]
            elif "to_apply=" in line or "branch_computations=" in line:
                for ref in _APPLY_RE.finditer(line):
                    for nm in ref.group(1).split(","):
                        sub = walk(nm.strip().lstrip("%"), seen)
                        for k in acc:
                            acc[k] += sub[k]
        return acc

    return walk(entry, frozenset())


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _tree_bytes_per_dev(mesh, tree_shapes, tree_shardings) -> int:
    """Analytic per-device bytes of a sharded tree (weights/opt/cache)."""
    total = 0
    mesh_shape = dict(mesh.shape)
    for s, sh in zip(jax.tree.leaves(tree_shapes), jax.tree.leaves(tree_shardings)):
        n = 1
        for d in s.shape:
            n *= d
        nbytes = n * jnp.dtype(s.dtype).itemsize
        frac = 1
        for axis_assignment in sh.spec:
            if axis_assignment is None:
                continue
            axes = (
                axis_assignment
                if isinstance(axis_assignment, tuple)
                else (axis_assignment,)
            )
            for a in axes:
                frac *= mesh_shape[a]
        total += nbytes // max(1, frac)
    return total


def _shardings_for(tree_shapes, logical_tree, mesh):
    def one(logical, shaped):
        if logical is None:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, shd.resolve_spec(logical, shaped.shape, mesh)
        )

    return jax.tree.map(
        one, logical_tree, tree_shapes,
        is_leaf=lambda x: x is None or (isinstance(x, tuple) and
                                        all(isinstance(e, (str, type(None))) for e in x)),
    )


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    opt_state_dtype: str = "int8",
    donate: bool = True,
    rules=None,
):
    """Returns (lowered, aux) for one cell."""
    model_axis = mesh.shape.get("model", 1)
    specs_in = zoo.input_specs(cfg, shape)
    logical_in = zoo.input_logical(cfg, shape)
    if "cache" in specs_in:
        logical_in["cache"] = zoo.cache_logical(cfg, specs_in["cache"], model_axis)

    p_shapes, p_specs = zoo.param_shapes(cfg)

    with shd.use_mesh(mesh, rules=rules):
        param_sh = shd.tree_shardings(p_specs, p_shapes, mesh)
        batch_sh = _shardings_for(specs_in, logical_in, mesh)

        if shape.kind == "train":
            opt_cfg = OptConfig(state_dtype=opt_state_dtype)
            step = zoo.build_train_step(cfg, opt_cfg)
            o_shapes = zoo.opt_state_shapes(cfg, opt_cfg, p_shapes)

            def opt_leaf_sharding(path, leaf):
                # m/v inherit the param's sharding pattern when shapes match
                return NamedSharding(mesh, P())

            # m/v share the param spec; scales/step replicated
            def mv_shardings(p_spec_tree):
                def one(spec, shaped):
                    if hasattr(shaped, "shape") and len(getattr(shaped, "shape", ())) > 0:
                        return NamedSharding(
                            mesh, shd.resolve_spec(spec, shaped.shape, mesh)
                        )
                    return NamedSharding(mesh, P())
                return one

            mk = mv_shardings(p_specs)

            def build_mv(spec, mv_leaf_shapes):
                out = {}
                for key in ("m", "v"):
                    leafs = mv_leaf_shapes[key]
                    if isinstance(leafs, tuple) and hasattr(leafs, "_fields"):
                        # QTensor(q, s): q has param shape, s has row shape
                        out[key] = type(leafs)(
                            mk(spec, leafs.q), mk(spec[:-1] + (None,), leafs.s)
                            if len(spec) == len(leafs.s.shape)
                            else NamedSharding(mesh, P()),
                        )
                    else:
                        out[key] = mk(spec, leafs)
                return out

            opt_sh = {
                "step": NamedSharding(mesh, P()),
                "mv": jax.tree.map(
                    build_mv, p_specs, o_shapes["mv"],
                    is_leaf=lambda x: isinstance(x, tuple)
                    and all(isinstance(e, (str, type(None))) for e in x),
                ),
            }
            jit = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jit.lower(p_shapes, o_shapes, specs_in)
            arg_bytes = (
                _tree_bytes_per_dev(mesh, p_shapes, param_sh)
                + _tree_bytes_per_dev(mesh, o_shapes, opt_sh)
                + _tree_bytes_per_dev(mesh, specs_in, batch_sh)
            )
        elif shape.kind == "prefill":
            step = zoo.build_prefill_step(cfg, max_len=shape.seq_len + 8)
            jit = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jit.lower(p_shapes, specs_in)
            arg_bytes = _tree_bytes_per_dev(mesh, p_shapes, param_sh) + \
                _tree_bytes_per_dev(mesh, specs_in, batch_sh)
        else:  # decode
            step = zoo.build_decode_step(cfg)
            jit = jax.jit(
                step,
                in_shardings=(param_sh, batch_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jit.lower(p_shapes, specs_in)
            arg_bytes = _tree_bytes_per_dev(mesh, p_shapes, param_sh) + \
                _tree_bytes_per_dev(mesh, specs_in, batch_sh)
    return lowered, {"arg_bytes_per_dev": arg_bytes}


def _cell_costs(cfg, shape, mesh, opt_state_dtype) -> Dict[str, float]:
    """(flops, bytes, collective bytes) of one compiled cell."""
    lowered, _aux = lower_cell(cfg, shape, mesh, opt_state_dtype=opt_state_dtype)
    compiled = lowered.compile()
    try:
        cost = compiled.cost_analysis() or {}
    except Exception:
        cost = {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(v for k, v in coll.items() if k != "count")),
        "coll_by_kind": coll,
    }


def extrapolated_costs(cfg, shape, mesh, opt_state_dtype) -> Dict[str, Any]:
    """XLA cost analysis counts a scan body ONCE, not ×trip-count (verified
    empirically).  Since flops/bytes/collective-bytes are affine in the
    number of scanned superblocks, compile depth-1 and depth-2 probes and
    extrapolate exactly:  X(n) = X(1) + (n-1)·(X(2) - X(1)).

    The inner *time* scans of Mamba/xLSTM recurrences stay undercounted,
    but their in-loop work is elementwise (<1% of the layer's matmul
    flops) — noted in EXPERIMENTS.md.
    """
    from repro.models import transformer as TT

    n_prefix, pat, n_sb = TT._scan_layout(cfg)
    if n_sb <= 2:
        # trip-count ≤ 2 loops may be unrolled (and then counted exactly)
        return _cell_costs(cfg, shape, mesh, opt_state_dtype)
    # probe at 2 and 3 superblocks: both are genuine while-loops, so the
    # per-superblock delta is clean (a 1-superblock scan gets unrolled and
    # would break affinity)
    cfg2 = cfg.with_(n_layers=n_prefix + 2 * pat)
    cfg3 = cfg.with_(n_layers=n_prefix + 3 * pat)
    x2 = _cell_costs(cfg2, shape, mesh, opt_state_dtype)
    x3 = _cell_costs(cfg3, shape, mesh, opt_state_dtype)
    out = {}
    for k in ("flops", "bytes", "coll"):
        delta = max(0.0, x3[k] - x2[k])
        out[k] = x2[k] + (n_sb - 2) * delta
    out["coll_by_kind"] = {
        k: int(x2["coll_by_kind"][k]
               + (n_sb - 2) * max(0, x3["coll_by_kind"][k] - x2["coll_by_kind"][k]))
        for k in x2["coll_by_kind"]
    }
    out["extrapolated"] = True
    return out


# Perf-iteration variants (EXPERIMENTS.md §Perf): config + sharding-rule
# overrides applied on top of the baseline.
VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    # pure data parallelism: replicate weights, batch over (data × model) —
    # the right layout for sub-4B models on a 256-chip mesh
    "dp": {
        "rules": {
            "heads": [None], "kv_heads": [None], "ff": [None],
            "vocab": [None], "embed": [None], "experts": [None],
            "heads_act": [None], "ff_act": [None], "vocab_act": [None],
            "batch": [("pod", "data", "model"), ("data", "model"), "data"],
        }
    },
    # sequence parallelism: residual stream sharded over model between
    # blocks (all-reduce -> reduce-scatter + all-gather)
    "sp": {"rules": {"seq_act": ["model", None]}},
    # int8 KV cache for decode (halves cache HBM traffic + residency)
    "int8kv": {"cfg": {"kv_cache_dtype": "int8"}},
    # MoE: bf16 expert-combine psum + capacity factor 1.0
    "moe_opt": {"moe": {"capacity_factor": 1.0, "combine_dtype": "bfloat16"}},
    # + int8 dispatch payload on top of moe_opt
    "moe_opt2": {"moe": {"capacity_factor": 1.0, "combine_dtype": "bfloat16",
                         "dispatch_dtype": "int8"}},
    # + deduplicated, group-limited (L=4) dispatch
    "moe_opt3": {"moe": {"capacity_factor": 1.0, "combine_dtype": "bfloat16",
                         "dispatch_dtype": "int8", "dedup_dispatch": True,
                         "shard_groups": 4}},
    # weight-stationary decode: replicate the (tiny) decode activations
    # over data so XLA psums activation partials instead of all-gathering
    # fsdp-sharded weights every step; + int8 KV
    "serve_opt": {
        "cfg": {"kv_cache_dtype": "int8"},
        "rules": {"dec_batch": [None]},
    },
    # + weight-stationary shard_map decode MLP
    "serve_opt2": {
        "cfg": {"kv_cache_dtype": "int8", "decode_mlp": "ws"},
        "rules": {"dec_batch": [None]},
    },
    "moe_opt_sp": {
        "moe": {"capacity_factor": 1.0, "combine_dtype": "bfloat16"},
        "rules": {"seq_act": ["model", None]},
    },
}


def apply_variant(cfg: ModelConfig, variant: str):
    v = VARIANTS[variant]
    if "cfg" in v:
        cfg = cfg.with_(**v["cfg"])
    if "moe" in v and cfg.moe is not None:
        import dataclasses

        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, **v["moe"]))
    return cfg, v.get("rules")


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    save: bool = True,
    opt_state_dtype: str = "int8",
    variant: str = "baseline",
    cfg_override: Optional[ModelConfig] = None,
) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    cfg, rule_override = apply_variant(cfg, variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    lowered, aux = lower_cell(cfg, shape, mesh, opt_state_dtype=opt_state_dtype, rules=rule_override)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    try:
        mem = compiled.memory_analysis()
    except Exception:  # CPU backend may not implement memory analysis
        mem = None
    try:
        xla_cost = compiled.cost_analysis() or {}
    except Exception:
        xla_cost = {}

    # collective bytes: structured HLO parse with loop trip counts applied.
    # Wire bytes: all-reduce moves ~2x its output (reduce-scatter +
    # all-gather phases); the others move ~1x output.
    coll = collective_bytes(compiled.as_text())
    coll_total = sum(
        (2 * v if k == "all-reduce" else v)
        for k, v in coll.items()
        if k != "count"
    )

    # compute/memory: exact analytic accounting (XLA-CPU cost_analysis
    # counts loop bodies once and mixes per-device/global scopes — its raw
    # numbers are recorded below under xla_cost for reference)
    from repro.launch import roofline_model as RM

    flops = RM.analytic_flops(cfg, shape)
    bytes_hbm = RM.analytic_bytes(cfg, shape)

    total_p, active_p = cfg.param_count()
    if shape.kind == "train":
        tok = shape.global_batch * shape.seq_len
        model_flops = 6 * active_p * tok
    elif shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len
        model_flops = 2 * active_p * tok
    else:
        tok = shape.global_batch
        model_flops = 2 * active_p * tok

    result = {
        "arch": arch,
        "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "analytic_arg_bytes_per_dev": int(aux["arg_bytes_per_dev"]),
            "xla_argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "xla_output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "xla_peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "xla_temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        },
        "analytic_flops": flops,
        "analytic_bytes": bytes_hbm,
        "xla_cost": {k: float(v) for k, v in xla_cost.items()
                     if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "collective_bytes": coll_total,
        "model_flops": model_flops,
        "roofline": roofline_terms(flops, bytes_hbm, coll_total, n_chips),
        "useful_flops_ratio": (model_flops / flops) if flops else None,
    }
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        tag = f"{arch.replace('.', '_').replace('-', '_')}__{shape_name}__{result['mesh']}"
        if variant != "baseline":
            tag += f"__{variant}"
        with open(ART_DIR / f"dryrun_{tag}.json", "w") as f:
            json.dump(result, f, indent=2)
    return result


def roofline_terms(flops, bytes_hbm, coll_bytes, n_chips) -> Dict[str, float]:
    """The three roofline terms in seconds.

    cost_analysis() reports GLOBAL (logical-computation) FLOPs/bytes —
    verified against 6·N·D on stablelm train (within 4%) — so compute and
    memory terms divide by chips.  collective_bytes is parsed from the
    per-device SPMD module, so it is already per-chip and divides only by
    the per-chip link bandwidth.
    """
    return {
        "compute_s": flops / (n_chips * PEAK_FLOPS),
        "memory_s": bytes_hbm / (n_chips * HBM_BW),
        "collective_s": coll_bytes / ICI_BW,
    }


# ---------------------------------------------------------------------------
def iter_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            yield arch, sname, ok, why


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--opt-state", default="int8")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args(argv)

    if args.list:
        for arch, sname, ok, why in iter_cells():
            print(f"{arch:24s} {sname:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return 0

    cells = []
    if args.all:
        for arch, sname, ok, why in iter_cells():
            if ok:
                cells.append((arch, sname))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, sname in cells:
        for mp in meshes:
            tag = f"{arch} × {sname} × {'2x16x16' if mp else '16x16'}"
            try:
                r = run_cell(arch, sname, multi_pod=mp,
                             opt_state_dtype=args.opt_state,
                             variant=args.variant)
                if "skipped" in r:
                    print(f"[SKIP] {tag}: {r['skipped']}", flush=True)
                    continue
                rt = r["roofline"]
                print(
                    f"[OK]   {tag}: compile={r['compile_s']}s "
                    f"args/dev={r['memory']['analytic_arg_bytes_per_dev']/2**30:.2f}GiB "
                    f"compute={rt['compute_s']*1e3:.2f}ms "
                    f"hbm={rt['memory_s']*1e3:.2f}ms "
                    f"coll={rt['collective_s']*1e3:.2f}ms",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
