"""Serving launcher: LLMSched-scheduled compound jobs on real engines.

The paper's end-to-end driver: spin up N continuous-batching engine
replicas with a (smoke) model, train the Bayesian-network profiles from
history, then run a compound-LLM workload through the uncertainty-aware
scheduler and report average JCT against a chosen baseline.

Replicas share one set of weights (as same-model replicas do in
production), which is what makes ``--migrate`` lossless: a decoding
request's KV pages can be handed to any peer and continue
token-for-token.  ``--kv-pages`` makes the fleet heterogeneous — e.g.
``--kv-pages 13,49`` gives replica 0 a small page pool and replica 1 a
large one, the regime where uncertainty-aware placement and live
migration earn their keep.

``--prefix-cache`` (paged engines only) enables shared-prefix KV reuse:
each replica keeps a radix index over full prompt pages, admission
adopts cached prefixes copy-free, and the scheduler's placement score
gains the cache-affinity term.  Pair it with ``--shared-prompt N`` so
each application's tasks actually share an N-token system prompt —
the workload shape where the cache pays.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --mix planning --jobs 12 --scheduler llmsched
  PYTHONPATH=src python -m repro.launch.serve --engine paged \
      --replicas 2 --kv-pages 13,49 --migrate
  PYTHONPATH=src python -m repro.launch.serve --engine paged \
      --replicas 2 --prefix-cache --shared-prompt 32
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_smoke_config
from repro.core import LLMSched, ProfileStore, make_baselines
from repro.models import init_params
from repro.serving import LLMEngine, PagedLLMEngine, ServingCluster

from repro.sim import generate_traces, generate_workload, get_generators


def build_scheduler(name: str, store: ProfileStore, epsilon: float, seed: int):
    """Instantiate LLMSched or a named baseline scheduler."""
    if name == "llmsched":
        return LLMSched(store, epsilon=epsilon, seed=seed)
    return make_baselines(store)[name]


def build_engines(args, cfg):
    """Build the replica fleet: shared weights, optional heterogeneous KV."""
    n = args.replicas if args.replicas is not None else args.engines
    if args.engine == "paged":
        params = init_params(cfg, jax.random.key(args.seed))[0]
        kv_pages = None
        if args.kv_pages:
            kv_pages = [int(x) for x in args.kv_pages.split(",")]
            if len(kv_pages) != n:
                raise SystemExit(
                    f"--kv-pages needs {n} comma-separated values, "
                    f"got {len(kv_pages)}"
                )
        return [
            PagedLLMEngine(
                cfg, max_seqs=args.max_batch, max_len=96,
                page_size=args.page_size,
                num_pages=kv_pages[i] if kv_pages else None,
                params=params,
                prefix_cache=args.prefix_cache,
            )
            for i in range(n)
        ]
    if args.migrate:
        raise SystemExit("--migrate requires --engine paged")
    if args.prefix_cache:
        raise SystemExit("--prefix-cache requires --engine paged")
    return [
        LLMEngine(cfg, max_batch=args.max_batch, max_len=96,
                  seed=args.seed + i)
        for i in range(n)
    ]


def main(argv=None) -> int:
    """Entry point for ``python -m repro.launch.serve``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--mix", default="planning",
                    choices=["mixed", "predefined", "chain", "planning"])
    ap.add_argument("--jobs", type=int, default=12)
    ap.add_argument("--scheduler", default="llmsched",
                    choices=["llmsched", "fcfs", "fair", "sjf", "argus",
                             "carbyne", "decima"])
    ap.add_argument("--engines", type=int, default=1,
                    help="deprecated alias of --replicas")
    ap.add_argument("--replicas", type=int, default=None,
                    help="number of LLM engine replicas")
    ap.add_argument("--engine", default="slot", choices=["slot", "paged"],
                    help="slot: dense per-slot KV; paged: block-table pool")
    ap.add_argument("--migrate", action="store_true",
                    help="live-migrate KV off starved replicas (paged only)")
    ap.add_argument("--kv-pages", default=None,
                    help="comma list of per-replica page-pool sizes "
                         "(heterogeneous KV budgets), e.g. 13,49")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix KV reuse via a radix index "
                         "(paged only)")
    ap.add_argument("--shared-prompt", type=int, default=0,
                    help="tokens of per-application shared system prompt "
                         "prepended to every LLM task's request")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--regular", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=0.2)
    ap.add_argument("--token-scale", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # engines are built with max_len=96; the synthesized prompt is
    # shared + 2 suffix tokens and needs one decode slot on top
    if args.shared_prompt > 93:
        raise SystemExit(
            f"--shared-prompt {args.shared_prompt} too large: the "
            "synthesized prompt (+2 suffix tokens) must fit the "
            "engines' max_len of 96"
        )

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces(args.mix, 300, seed=7))

    cfg = get_smoke_config(args.arch)
    engines = build_engines(args, cfg)
    sched = build_scheduler(args.scheduler, store, args.epsilon, args.seed)
    cluster = ServingCluster(
        sched, engines, n_regular=args.regular,
        token_scale=args.token_scale, time_scale=args.token_scale,
        migrate=args.migrate,
        shared_prompt_tokens=args.shared_prompt,
    )
    wl = generate_workload(args.mix, args.jobs, arrival_rate=0.9, seed=args.seed)
    res = cluster.run(wl)
    print(
        f"[serve] scheduler={args.scheduler} mix={args.mix} "
        f"replicas={len(engines)} jobs={len(res.jcts)} "
        f"avg_jct={res.avg_jct:.2f}s makespan={res.makespan:.1f}s "
        f"tokens={res.tokens_generated} overhead={res.avg_overhead_ms:.2f}ms "
        f"preemptions={res.preemptions} migrations={res.migrations} "
        f"prefill={res.prefill_tokens} prefill_saved={res.prefill_saved_tokens}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
