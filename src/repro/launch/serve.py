"""Serving launcher: LLMSched-scheduled compound jobs on real engines.

The paper's end-to-end driver: spin up N continuous-batching engines with
a (smoke) model, train the Bayesian-network profiles from history, then
run a compound-LLM workload through the uncertainty-aware scheduler and
report average JCT against a chosen baseline.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --mix planning --jobs 12 --scheduler llmsched
"""

from __future__ import annotations

import argparse

from repro.configs import get_smoke_config
from repro.core import LLMSched, ProfileStore, make_baselines
from repro.serving import LLMEngine, PagedLLMEngine, ServingCluster
from repro.sim import generate_traces, generate_workload, get_generators


def build_scheduler(name: str, store: ProfileStore, epsilon: float, seed: int):
    if name == "llmsched":
        return LLMSched(store, epsilon=epsilon, seed=seed)
    return make_baselines(store)[name]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--mix", default="planning",
                    choices=["mixed", "predefined", "chain", "planning"])
    ap.add_argument("--jobs", type=int, default=12)
    ap.add_argument("--scheduler", default="llmsched",
                    choices=["llmsched", "fcfs", "fair", "sjf", "argus",
                             "carbyne", "decima"])
    ap.add_argument("--engines", type=int, default=1)
    ap.add_argument("--engine", default="slot", choices=["slot", "paged"],
                    help="slot: dense per-slot KV; paged: block-table pool")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--regular", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=0.2)
    ap.add_argument("--token-scale", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces(args.mix, 300, seed=7))

    cfg = get_smoke_config(args.arch)
    if args.engine == "paged":
        engines = [
            PagedLLMEngine(cfg, max_seqs=args.max_batch, max_len=96,
                           page_size=args.page_size, seed=args.seed + i)
            for i in range(args.engines)
        ]
    else:
        engines = [
            LLMEngine(cfg, max_batch=args.max_batch, max_len=96,
                      seed=args.seed + i)
            for i in range(args.engines)
        ]
    sched = build_scheduler(args.scheduler, store, args.epsilon, args.seed)
    cluster = ServingCluster(
        sched, engines, n_regular=args.regular,
        token_scale=args.token_scale, time_scale=args.token_scale,
    )
    wl = generate_workload(args.mix, args.jobs, arrival_rate=0.9, seed=args.seed)
    res = cluster.run(wl)
    print(
        f"[serve] scheduler={args.scheduler} mix={args.mix} jobs={len(res.jcts)} "
        f"avg_jct={res.avg_jct:.2f}s makespan={res.makespan:.1f}s "
        f"tokens={res.tokens_generated} overhead={res.avg_overhead_ms:.2f}ms "
        f"preemptions={res.preemptions}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
