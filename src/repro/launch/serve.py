"""Serving launcher: LLMSched-scheduled compound jobs on real engines.

The paper's end-to-end driver: spin up N continuous-batching engine
replicas with a (smoke) model, train the Bayesian-network profiles from
history, then run a compound-LLM workload through the uncertainty-aware
scheduler and report average JCT against a chosen baseline.

Replicas share one set of weights (as same-model replicas do in
production), which is what makes ``--migrate`` lossless: a decoding
request's KV pages can be handed to any peer and continue
token-for-token.  ``--kv-pages`` makes the fleet heterogeneous — e.g.
``--kv-pages 13,49`` gives replica 0 a small page pool and replica 1 a
large one, the regime where uncertainty-aware placement and live
migration earn their keep.

``--prefix-cache`` (paged engines only) enables shared-prefix KV reuse:
each replica keeps a radix index over full prompt pages, admission
adopts cached prefixes copy-free, and the scheduler's placement score
gains the cache-affinity term.  Pair it with ``--shared-prompt N`` so
each application's tasks actually share an N-token system prompt —
the workload shape where the cache pays.

``--models a,b`` declares a **heterogeneous pool** (one model name per
replica, priced through the model-zoo tier table) — the scheduler then
routes stages by uncertainty-reduction-per-cost.  Add
``--gate-strictness s`` to score stage outputs with a deterministic
quality gate, and ``--cascade`` to escalate rejections one cost tier
up; the run then reports serving cost, escalations, and
cost-efficiency.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --mix planning --jobs 12 --scheduler llmsched
  PYTHONPATH=src python -m repro.launch.serve --engine paged \
      --replicas 2 --kv-pages 13,49 --migrate
  PYTHONPATH=src python -m repro.launch.serve --engine paged \
      --replicas 2 --prefix-cache --shared-prompt 32
"""

from __future__ import annotations

import argparse

from repro.configs import get_smoke_config
from repro.core import DeterministicGate, LLMSched, ProfileStore, make_baselines
from repro.serving import ServeConfig, ServingCluster, build_engines

from repro.sim import generate_traces, generate_workload, get_generators
from repro.sim.workloads import generate_tiered_workload


def build_scheduler(name: str, store: ProfileStore, epsilon: float, seed: int,
                    plan_ahead_s: float = 30.0):
    """Instantiate LLMSched or a named baseline scheduler."""
    if name == "llmsched":
        return LLMSched(store, epsilon=epsilon, seed=seed,
                        plan_ahead_s=plan_ahead_s)
    return make_baselines(store)[name]


def config_from_args(args) -> ServeConfig:
    """Map the CLI namespace onto a validated :class:`ServeConfig`."""
    kv_pages = None
    n = args.replicas if args.replicas is not None else args.engines
    if args.kv_pages:
        kv_pages = tuple(int(x) for x in args.kv_pages.split(","))
    models = tuple(args.models.split(",")) if args.models else None
    try:
        return ServeConfig(
            engine=args.engine,
            replicas=n,
            models=models,
            cascade=args.cascade,
            max_batch=args.max_batch,
            max_len=96,
            page_size=args.page_size,
            kv_pages=kv_pages,
            kv_dtype=args.kv_dtype,
            migrate=args.migrate,
            prefix_cache=args.prefix_cache,
            shared_prompt_tokens=args.shared_prompt,
            n_regular=args.regular,
            token_scale=args.token_scale,
            time_scale=args.token_scale,
            seed=args.seed,
            plan_ahead_s=args.plan_ahead,
            slo_tightness=args.slo_tightness,
        )
    except ValueError as e:
        raise SystemExit(str(e))


def main(argv=None) -> int:
    """Entry point for ``python -m repro.launch.serve``."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--mix", default="planning",
                    choices=["mixed", "predefined", "chain", "planning"])
    ap.add_argument("--jobs", type=int, default=12)
    ap.add_argument("--scheduler", default="llmsched",
                    choices=["llmsched", "fcfs", "fair", "sjf", "argus",
                             "carbyne", "decima"])
    ap.add_argument("--engines", type=int, default=1,
                    help="deprecated alias of --replicas")
    ap.add_argument("--replicas", type=int, default=None,
                    help="number of LLM engine replicas")
    ap.add_argument("--engine", default="slot", choices=["slot", "paged"],
                    help="slot: dense per-slot KV; paged: block-table pool")
    ap.add_argument("--migrate", action="store_true",
                    help="live-migrate KV off starved replicas (paged only)")
    ap.add_argument("--kv-pages", default=None,
                    help="comma list of per-replica page-pool sizes "
                         "(heterogeneous KV budgets), e.g. 13,49")
    ap.add_argument("--kv-dtype", default="fp32", choices=["fp32", "int8"],
                    help="paged KV page storage: fp32 keeps compute-dtype "
                         "pages, int8 quantizes with per-page scales "
                         "(~1.6x tokens per byte; paged only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="shared-prefix KV reuse via a radix index "
                         "(paged only)")
    ap.add_argument("--shared-prompt", type=int, default=0,
                    help="tokens of per-application shared system prompt "
                         "prepended to every LLM task's request")
    ap.add_argument("--models", default=None,
                    help="comma list of per-replica model names "
                         "(heterogeneous pool), e.g. "
                         "stablelm_1_6b,internlm2_20b; overrides --arch")
    ap.add_argument("--cascade", action="store_true",
                    help="escalate quality-gate rejections one cost tier "
                         "up (needs --models naming >1 tier and a "
                         "--gate-strictness gate)")
    ap.add_argument("--gate-strictness", type=float, default=None,
                    help="attach a DeterministicGate with this strictness "
                         "in [0,1] to score stage outputs")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--regular", type=int, default=4)
    ap.add_argument("--epsilon", type=float, default=0.2)
    ap.add_argument("--token-scale", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo", action="store_true",
                    help="attach tiered SLOs (interactive/batch/best-effort "
                         "deadlines) to every job and report goodput")
    ap.add_argument("--slo-tightness", type=float, default=1.0,
                    help="deadline-tightening factor for --slo workloads")
    ap.add_argument("--plan-ahead", type=float, default=30.0,
                    help="LLMSched SLO plan-ahead window W in workload "
                         "seconds")
    args = ap.parse_args(argv)

    serve_cfg = config_from_args(args)

    gens = get_generators()
    apps = [g.template for g in gens.values()]
    store = ProfileStore().fit(apps, generate_traces(args.mix, 300, seed=7))

    cfg = None if serve_cfg.models else get_smoke_config(args.arch)
    try:
        engines = build_engines(cfg, serve_cfg)
    except ValueError as e:
        raise SystemExit(str(e))
    sched = build_scheduler(args.scheduler, store, args.epsilon, args.seed,
                            plan_ahead_s=serve_cfg.plan_ahead_s)
    gate = None
    if args.gate_strictness is not None:
        try:
            gate = DeterministicGate(
                strictness=args.gate_strictness, seed=args.seed
            )
        except ValueError as e:
            raise SystemExit(str(e))
    elif serve_cfg.cascade:
        raise SystemExit("--cascade requires --gate-strictness")
    cluster = ServingCluster(sched, engines, serve_cfg, gate=gate)
    if args.slo:
        wl = generate_tiered_workload(
            args.mix, args.jobs, arrival_rate=0.9, seed=args.seed,
            tightness=serve_cfg.slo_tightness,
        )
    else:
        wl = generate_workload(args.mix, args.jobs, arrival_rate=0.9,
                               seed=args.seed)
    res = cluster.run(wl)
    goodput = res.goodput()
    slo_part = (
        "" if goodput is None
        else f" goodput={goodput:.2f}"
        + "".join(
            f" goodput[{t}]={g:.2f}"
            for t, g in sorted(res.goodput_by_tier().items())
        )
    )
    cost_part = ""
    if res.cost_by_job:
        eff = res.cost_efficiency()
        cost_part = (
            f" cost={res.total_cost:.3e}"
            f" escalations={res.escalations}"
            + (f" cost_eff={eff:.1f}" if eff is not None else "")
        )
    print(
        f"[serve] scheduler={args.scheduler} mix={args.mix} "
        f"replicas={len(engines)} jobs={len(res.jcts)} "
        f"avg_jct={res.avg_jct:.2f}s makespan={res.makespan:.1f}s "
        f"tokens={res.tokens_generated} overhead={res.avg_overhead_ms:.2f}ms "
        f"preemptions={res.preemptions} migrations={res.migrations} "
        f"prefill={res.prefill_tokens} prefill_saved={res.prefill_saved_tokens}"
        f"{slo_part}{cost_part}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
