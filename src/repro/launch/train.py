"""Training launcher: real steps on CPU (smoke configs) or any mesh.

Production workflow (what this script encodes, runnable end-to-end on the
smoke configs in this container):

  1. build mesh + resolve shardings from the logical rules;
  2. restore the latest checkpoint if present (crash/preemption restart —
     elastic: the checkpoint reshards onto the current mesh);
  3. jit the train step with donated params/opt-state;
  4. step the synthetic LM data pipeline, checkpointing every
     ``--ckpt-every`` steps (atomic publish);
  5. optional int8 gradient compression across the ``pod`` axis.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --smoke --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.distributed import sharding as shd
from repro.distributed.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.distributed.optimizer import OptConfig, init_opt_state
from repro.models import init_params
from repro.models.zoo import build_train_step


def synthetic_batch(rng: np.random.Generator, cfg, batch: int, seq: int):
    """Synthetic LM data pipeline: Zipf-ish token stream + shifted targets."""
    z = rng.zipf(1.3, size=(batch, seq + 1)) % cfg.vocab
    toks = jnp.asarray(z[:, :-1], jnp.int32)
    tgts = jnp.asarray(z[:, 1:], jnp.int32)
    out = {"tokens": toks, "targets": tgts}
    if cfg.family in ("vlm", "audio"):
        out["enc_input"] = jnp.full(
            (batch, cfg.encoder.n_ctx, cfg.d_model), 0.01, cfg.jdtype
        )
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt-state", default="float32", choices=["float32", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(lr=args.lr, state_dtype=args.opt_state, warmup_steps=5)
    step_fn = jax.jit(build_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    params, _ = init_params(cfg, jax.random.key(args.seed))
    opt_state = init_opt_state(params, opt_cfg)
    start = 0

    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, like=(params, opt_state)
        )
        print(f"[train] resumed from step {start}")

    rng = np.random.default_rng(args.seed)
    losses = []
    t0 = time.perf_counter()
    for s in range(start, args.steps):
        batch = synthetic_batch(rng, cfg, args.batch, args.seq)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        print(f"[train] step={s+1:4d} loss={loss:8.4f} "
              f"gnorm={float(metrics['grad_norm']):8.3f}", flush=True)
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            p = save_checkpoint(args.ckpt_dir, s + 1, (params, opt_state))
            print(f"[train] checkpointed -> {p}")
    dt = time.perf_counter() - t0
    print(f"[train] {args.steps - start} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
