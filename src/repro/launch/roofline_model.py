"""Analytic FLOP/byte accounting per (architecture × shape) — the
compute/memory roofline terms.

XLA-CPU ``cost_analysis()`` reports per-device numbers with while-loop
bodies counted once (verified empirically: identical flops for 4- and
24-layer compiles), so the compute/memory terms use exact transformer
accounting instead; the raw XLA numbers are kept in the artifacts for
reference.  Collective bytes ARE taken from the compiled HLO via a
structured parser that multiplies loop bodies by their trip counts
(see dryrun.collective_bytes_structured).
"""

from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    return sum(
        1
        for i in range(cfg.n_layers)
        if cfg.is_attn_layer(i) and not cfg.is_cross_layer(i)
    )


def _cross_layers(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.is_cross_layer(i))


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Whole-step (global) FLOPs.

    Matmul term: 2 FLOPs/param/token over *active* non-embedding params +
    the LM head.  Attention score term: 4·ctx·H·hd per attn layer per
    token (÷2 for the causal triangle during full-seq passes).  Train
    multiplies by 3 (fwd+bwd) + 1 extra fwd when remat=full.
    """
    total, active = cfg.param_count()
    d, V = cfg.d_model, cfg.vocab
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    mat_params = max(0, active - emb)          # matmul-visible params
    H, hd = cfg.n_heads, cfg.hd
    B, S = shape.global_batch, shape.seq_len
    La = _attn_layers(cfg) + _cross_layers(cfg)

    if shape.kind in ("train", "prefill"):
        tokens = B * S
        mat = 2.0 * mat_params * tokens + 2.0 * d * V * tokens  # + head
        attn = 4.0 * (S / 2) * H * hd * La * tokens             # causal avg ctx
        fwd = mat + attn
        if shape.kind == "prefill":
            return fwd
        mult = 3.0 + (1.0 if cfg.remat == "full" else 0.0)
        return mult * fwd
    # decode: 1 token/request against a ctx of S
    tokens = B
    mat = 2.0 * mat_params * tokens + 2.0 * d * V * tokens
    attn = 4.0 * S * H * hd * La * tokens
    return mat + attn


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig,
                   opt_state_bytes_per_param: float = 2.0) -> float:
    """Whole-step (global) HBM traffic estimate.

    - weights: streamed once per pass (fwd, bwd, remat-fwd); grads written
      +read, optimizer state read+write (int8 m/v default = 2 B/param);
    - activations: ~12 intermediate tensors of (tokens × d) per layer per
      pass at 2 B (bf16), halved by fusion;
    - logits: (tokens × V) in f32 for the loss (train) / bf16 (serve);
    - decode: weights once + the KV cache read for every request.
    """
    total, active = cfg.param_count()
    d, V = cfg.d_model, cfg.vocab
    B, S = shape.global_batch, shape.seq_len
    L = cfg.n_layers
    wb = 2.0  # bf16 weights

    if shape.kind == "train":
        tokens = B * S
        passes = 3.0 if cfg.remat == "full" else 2.0
        weights = active * wb * passes               # fwd + bwd (+ remat fwd)
        grads = 2.0 * active * wb                    # write + read
        opt = active * (2.0 * opt_state_bytes_per_param + 2.0 * wb)
        acts = 6.0 * L * tokens * d * 2.0 * 2.0      # fwd+bwd, fused estimate
        logits = tokens * V * (4.0 + 4.0)            # f32 fwd + bwd
        return weights + grads + opt + acts + logits
    if shape.kind == "prefill":
        tokens = B * S
        weights = active * wb
        acts = 6.0 * L * tokens * d * 2.0
        kv_write = tokens * cfg.kv_bytes_per_token()
        return weights + acts + kv_write
    # decode
    weights = active * wb
    kv_read = B * S * cfg.kv_bytes_per_token()
    acts = 12.0 * L * B * d * 2.0
    logits = B * V * 2.0
    return weights + kv_read + acts + logits


def analytic_terms(cfg: ModelConfig, shape: ShapeConfig, n_chips: int,
                   peak_flops: float, hbm_bw: float) -> Dict[str, float]:
    return {
        "compute_s": analytic_flops(cfg, shape) / (n_chips * peak_flops),
        "memory_s": analytic_bytes(cfg, shape) / (n_chips * hbm_bw),
    }
