"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing one
CPU device; only dryrun.py sets XLA_FLAGS for 512 host devices.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips with a leading
    ``pod`` axis (data parallel across the inter-pod DCN/ICI links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Optional[Mesh]:
    """Single-host debug mesh over however many devices exist (≥2)."""
    n = len(jax.devices())
    if n < 2:
        return None
    return jax.make_mesh((1, n), ("data", "model"))
