"""Event-driven cluster simulator (paper §V "Simulator").

Models the provider's cluster:
- ``n_regular`` regular executors — one regular task each;
- ``n_llm`` LLM executors — up to ``max_batch`` concurrent LLM tasks.

LLM tasks are token streams: a task with T output tokens finishes after
its executor decodes T of its tokens.  The per-token latency depends on
the executor's *current* batch size via a :class:`LatencyProfile`, so —
exactly like the paper's simulator — the remaining duration of every
running LLM task is re-stretched whenever the batch composition changes.

Optional fault injection: executor failures re-queue running tasks
(checkpoint/restart at the scheduling layer) and straggler tasks are
re-issued once they exceed ``straggler_factor`` × their expected duration
(speculative execution), mirroring what the large-scale runtime needs.

Multi-replica serving is mirrored from ``repro.serving``: ``max_batch``
may be a per-replica sequence (heterogeneous replicas), LLM dispatch
honours the scheduler's placement hints (``Decision.placement``), and
``kv_budget_tokens`` gives each replica a finite KV pool whose usage
grows as its tasks decode — the simulator analog of the paged engines'
page pools.  When a replica's KV overflows, its youngest task is
preempted (recompute restart: all decoded tokens lost), exactly like
the paged engine's LIFO eviction; with ``migrate=True`` the task is
instead live-migrated to the replica with the most KV headroom, paying
``migration_cost_s`` of decode-time stall (the KV transfer).  Without
KV budgets, ``migrate=True`` falls back to batch-gap rebalancing.
This lets fig7/fig9 sweep replica counts and migration on/off with the
same cost mechanics the testbed measures for real.

Shared-prefix reuse is mirrored too: with ``prompt_tokens_per_task``
set, every LLM task pays modeled prefill work, and ``prefix_cache=True``
lets a replica that already served the same application skip the shared
system-prompt tokens (per-replica LRU residency, capacity-capped) —
the discrete-event analog of the paged engines' radix prefix index.
Per-job prefill token totals are recorded so the sim↔testbed parity
canary can detect cache-model drift.
"""

from __future__ import annotations

import heapq
import math
import time as _time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.calibration import LatencyProfile, roofline_profile
from ..core.dag import Job, Stage, Task, TaskState
from ..core.metrics import RunMetrics
from ..core.scheduler import ClusterView, Decision, Scheduler
from .workloads import (
    TOKEN_LATENCY_B1,
    GeneratedJob,
    get_generators,
    reveal_after_stage,
)


def default_latency_profile(max_batch: int = 16) -> LatencyProfile:
    """l(b) with l(1) = TOKEN_LATENCY_B1 and sub-linear growth in b —
    the memory-bound decode roofline (weights amortize, KV does not)."""
    bs = np.arange(1, max_batch + 1)
    # weights ≫ per-request KV: l(b) grows gently; matches H800 profiles
    lat = TOKEN_LATENCY_B1 * (0.85 + 0.15 * bs ** 0.7)
    return LatencyProfile(batch_sizes=bs, latency=lat)


@dataclass
class RunningLLMTask:
    task: Task
    remaining_tokens: float
    executor: int


# Backwards-compatible alias: the simulator's historical result type is
# now the unified schema shared with the serving testbed.
SimResult = RunMetrics


class ClusterSim:
    """Event-driven simulation of one provider cluster.

    Parameters
    ----------
    scheduler : Scheduler
        Policy under test.
    n_regular : int, optional
        Regular executor count.
    n_llm : int, optional
        LLM replica count.
    max_batch : int or sequence of int, optional
        Per-replica batch capacity; a scalar applies to every replica,
        a sequence of length ``n_llm`` models heterogeneous replicas.
    latency_profile : LatencyProfile, optional
        ``l(b)`` per-token decode latency (default: memory-bound
        roofline shape).
    failure_rate : float, optional
        Executor failures per sim-second (0 disables).
    straggler_factor : float, optional
        Speculative re-issue threshold multiplier (0 disables).
    migrate : bool, optional
        Enable cross-replica live migration of running LLM tasks.
    migration_cost_s : float, optional
        Decode-time stall a migrated task pays (KV transfer cost),
        converted to tokens at the batch-1 rate.
    kv_budget_tokens : int or sequence of int, optional
        Per-replica KV capacity in tokens (scalar applies to all).
        ``None`` (default) models unbounded KV — the historical
        behaviour.  With a budget, a replica whose running tasks'
        decoded tokens exceed it preempts (or, with ``migrate=True``,
        migrates away) its youngest task, mirroring the paged engine.
    prompt_tokens_per_task : float, optional
        When set, every LLM task pays this much prompt-prefill work
        (charged as extra tokens decoded at the batch rate — the sim
        analog of chunked prefill interleaving with decode).  ``None``
        (default) keeps the historical decode-only model byte-for-byte.
    shared_prompt_tokens : float, optional
        Of ``prompt_tokens_per_task``, the tokens belonging to the
        application's shared system prompt — the reusable part.
    prefix_cache : bool, optional
        Model shared-prefix KV reuse: a replica that already served a
        task of the same application skips the shared prompt tokens
        (the testbed's radix-index hit), tracked per replica with LRU
        eviction under ``prefix_cache_capacity_tokens``.  Mirrors the
        paged engine's prefix cache so fig-level sweeps and the
        sim↔testbed parity canary agree on the savings model.
    prefix_cache_capacity_tokens : float, optional
        Per-replica cap on resident shared-prefix tokens; the least
        recently used application's prefix is evicted beyond it.
    model_tiers : sequence of str, optional
        Per-replica model-zoo names (length ``n_llm``; any spelling
        :func:`repro.models.zoo.resolve_tier` accepts) declaring a
        heterogeneous pool.  Each replica decodes at its tier's
        ``latency_scale`` × the baseline ``l(b)``, charges its tier's
        per-token cost into ``RunMetrics.cost_by_job`` on every
        completed LLM attempt, and advertises the cost through
        ``ClusterView.llm_model_costs`` so LLMSched can route by
        uncertainty-reduction-per-cost.  ``None`` (default) keeps the
        historical single-tier model byte-for-byte; a homogeneous list
        (every replica the same tier) also schedules byte-identically
        when its ``latency_scale`` is 1.0, since the cost signal gates
        itself off.
    gate : QualityGate, optional
        Pluggable verifier over LLM stage outputs (requires
        ``model_tiers`` — the gate judges against the serving tier's
        quality).  A rejected output either escalates (``cascade=True``
        and a higher tier exists) or marks the job quality-failed in
        ``RunMetrics.quality_by_job``.
    cascade : bool, optional
        Re-enqueue gate-rejected LLM tasks with ``tier_floor`` one cost
        rank above the tier that failed (counted in
        ``RunMetrics.escalations``).  Requires ``gate``.
    seed : int, optional
        RNG seed for fault/straggler injection.  The quality gate's
        draws are hash-derived per attempt and consume nothing from
        this stream, so enabling the gate perturbs no other event.
    """

    def __init__(
        self,
        scheduler: Scheduler,
        n_regular: int = 4,
        n_llm: int = 1,
        max_batch=8,
        latency_profile: Optional[LatencyProfile] = None,
        failure_rate: float = 0.0,       # executor failures per sim-second
        straggler_factor: float = 0.0,   # 0 disables re-issue
        migrate: bool = False,
        migration_cost_s: float = 0.05,
        kv_budget_tokens=None,
        prompt_tokens_per_task: Optional[float] = None,
        shared_prompt_tokens: float = 0.0,
        prefix_cache: bool = False,
        prefix_cache_capacity_tokens: float = math.inf,
        model_tiers: Optional[Sequence[str]] = None,
        gate=None,
        cascade: bool = False,
        seed: int = 0,
    ) -> None:
        self.scheduler = scheduler
        self.n_regular = n_regular
        self.n_llm = n_llm
        if isinstance(max_batch, (list, tuple)):
            if len(max_batch) != n_llm:
                raise ValueError(
                    f"max_batch list length {len(max_batch)} != n_llm {n_llm}"
                )
            self._mb = [int(m) for m in max_batch]
        else:
            self._mb = [int(max_batch)] * n_llm
        self.max_batch = max(self._mb) if self._mb else int(max_batch)
        self.profile = latency_profile or default_latency_profile(self.max_batch)
        self.failure_rate = failure_rate
        self.straggler_factor = straggler_factor
        self.migrate = bool(migrate)
        self.migration_cost_s = float(migration_cost_s)
        if kv_budget_tokens is None:
            self._kv: Optional[List[float]] = None
        elif isinstance(kv_budget_tokens, (list, tuple)):
            if len(kv_budget_tokens) != n_llm:
                raise ValueError(
                    f"kv_budget_tokens list length {len(kv_budget_tokens)} "
                    f"!= n_llm {n_llm}"
                )
            self._kv = [float(k) for k in kv_budget_tokens]
        else:
            self._kv = [float(kv_budget_tokens)] * n_llm
        # KV mechanics (token analogs of the paged engine's page pool):
        # one relief event must free at least a quantum (whole pages, not
        # single tokens) and admission requires a reserve of headroom
        # (can_admit refuses when the pool is nearly dry) — both prevent
        # admit/evict churn storms around a saturated replica.
        self.prompt_tokens_per_task = (
            None if prompt_tokens_per_task is None
            else float(prompt_tokens_per_task)
        )
        self.shared_prompt_tokens = float(shared_prompt_tokens)
        self.prefix_cache = bool(prefix_cache)
        self.prefix_cache_capacity_tokens = float(prefix_cache_capacity_tokens)
        if (
            self.prompt_tokens_per_task is not None
            and self.shared_prompt_tokens > self.prompt_tokens_per_task
        ):
            raise ValueError(
                "shared_prompt_tokens cannot exceed prompt_tokens_per_task"
            )
        # heterogeneous pool: per-replica tier economics from the model
        # zoo.  Tier names must resolve — a typo'd model silently priced
        # at 0 would corrupt every cost artifact.
        self.gate = gate
        self.cascade = bool(cascade)
        if model_tiers is None:
            if gate is not None or cascade:
                raise ValueError(
                    "gate/cascade require model_tiers (the gate judges "
                    "against the serving tier's quality)"
                )
            self._tier_cost: Optional[List[float]] = None
            self._tier_quality: Optional[List[float]] = None
            self._ranks: Optional[List[int]] = None
            self._lat_scale: List[float] = [1.0] * n_llm
        else:
            from ..core.cascade import fleet_ranks
            from ..models.zoo import tier_spec

            if len(model_tiers) != n_llm:
                raise ValueError(
                    f"model_tiers list length {len(model_tiers)} "
                    f"!= n_llm {n_llm}"
                )
            if cascade and gate is None:
                raise ValueError("cascade=True requires a gate")
            specs = []
            for name in model_tiers:
                spec = tier_spec(name)
                if spec is None:
                    raise ValueError(f"unknown model tier: {name!r}")
                specs.append(spec)
            self._tier_cost = [s.usd_per_mtok / 1e6 for s in specs]
            self._tier_quality = [s.quality for s in specs]
            self._ranks = fleet_ranks(self._tier_cost)
            self._lat_scale = [s.latency_scale for s in specs]
        self.kv_relief_quantum = 64.0
        self.kv_admission_reserve = 256.0
        if self._kv is not None and any(
            k < self.kv_admission_reserve for k in self._kv
        ):
            raise ValueError(
                "kv_budget_tokens must be >= the admission reserve "
                f"({self.kv_admission_reserve:.0f} tokens); smaller pools "
                "would refuse every dispatch and deadlock the workload"
            )
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------------------ run
    def run(self, workload: Sequence[GeneratedJob]) -> SimResult:
        gens = get_generators()
        jobs: List[Job] = [gj.job for gj in workload]
        res = SimResult()

        now = 0.0
        arrivals = sorted(jobs, key=lambda j: j.arrival_time)
        ai = 0
        active: List[Job] = []

        # fault injection: next executor-failure time (Poisson process over
        # all executors); straggler injection probability for regular tasks
        n_exec = self.n_regular + self.n_llm
        def _next_failure(t0: float) -> float:
            if self.failure_rate <= 0:
                return math.inf
            return t0 + float(self.rng.exponential(1.0 / (self.failure_rate * n_exec)))
        t_fail = _next_failure(0.0)
        straggler_prob = 0.05 if self.straggler_factor > 0 else 0.0
        # regular duplicates: task id -> (deadline, executor) of the backup
        backups: Dict[int, Tuple[float, int]] = {}

        # regular executors: list of (finish_time, task) or None
        reg_running: List[Optional[Tuple[float, Task]]] = [None] * self.n_regular
        # LLM executors: running task lists
        llm_running: List[List[RunningLLMTask]] = [[] for _ in range(self.n_llm)]
        # prefix-cache model: per-replica {app name -> last-use time} of
        # resident shared prompts (the radix index's app-level analog)
        pcache: List[Dict[str, float]] = [{} for _ in range(self.n_llm)]

        def prefix_resident_tokens(e: int) -> int:
            return int(len(pcache[e]) * self.shared_prompt_tokens)

        def charge_prefill(e: int, task: Task) -> float:
            """Prompt work (tokens) task pays when dispatched to ``e``.

            A hit on the replica's resident shared prompt skips the
            shared tokens; the residency is refreshed LRU-style and
            capped by the capacity budget — mirroring the paged
            engine's adopt / insert / LRU-evict cycle.
            """
            if self.prompt_tokens_per_task is None:
                return 0.0
            prefill = self.prompt_tokens_per_task
            if self.prefix_cache and self.shared_prompt_tokens > 0:
                app = job_by_id[task.job_id].app.name
                cap = self.prefix_cache_capacity_tokens
                if app in pcache[e]:
                    prefill -= self.shared_prompt_tokens
                    res.prefill_saved_tokens += self.shared_prompt_tokens
                # a prefix only becomes (or stays) resident when it fits
                # the capacity at all — a capacity-starved testbed
                # replica cannot retain dormant pages either
                if self.shared_prompt_tokens <= cap:
                    pcache[e][app] = now
                    while len(pcache[e]) * self.shared_prompt_tokens > cap:
                        del pcache[e][min(pcache[e], key=pcache[e].get)]
            res.prefill_tokens += prefill
            res.prefill_by_job[task.job_id] = (
                res.prefill_by_job.get(task.job_id, 0.0) + prefill
            )
            return prefill

        def llm_batch(e: int) -> int:
            return len(llm_running[e])

        def advance_llm(dt: float) -> None:
            if dt <= 0:
                return
            for e in range(self.n_llm):
                b = llm_batch(e)
                if b == 0:
                    continue
                # tokens/sec per request; the tier's latency_scale
                # stretches l(b) (×1.0 exactly for single-tier pools)
                rate = 1.0 / (self.profile.l(b) * self._lat_scale[e])
                for rt in llm_running[e]:
                    rt.remaining_tokens -= dt * rate

        def next_llm_completion() -> Tuple[float, Optional[RunningLLMTask]]:
            best_t, best = math.inf, None
            for e in range(self.n_llm):
                b = llm_batch(e)
                if b == 0:
                    continue
                per_tok = self.profile.l(b) * self._lat_scale[e]
                for rt in llm_running[e]:
                    t = now + max(0.0, rt.remaining_tokens) * per_tok
                    if t < best_t:
                        best_t, best = t, rt
            return best_t, best

        def next_regular_completion() -> Tuple[float, int]:
            best_t, best_e = math.inf, -1
            for e, slot in enumerate(reg_running):
                if slot is not None and slot[0] < best_t:
                    best_t, best_e = slot[0], e
            return best_t, best_e

        def kv_usage(e: int) -> float:
            """Decoded tokens currently cached on replica ``e``.

            Clamped per task to [0, out_tokens]: the migration stall is
            charged as extra ``remaining_tokens``, which must not show
            up as negative KV usage on the destination.
            """
            return sum(
                max(
                    0.0,
                    rt.task.out_tokens - max(0.0, rt.remaining_tokens),
                )
                for rt in llm_running[e]
            )

        def kv_headroom(e: int) -> Optional[float]:
            if self._kv is None:
                return None
            return self._kv[e] - kv_usage(e)

        def sheddable_victim(e: int) -> Optional[RunningLLMTask]:
            """Youngest task on ``e`` holding KV — never the oldest.

            The oldest task is exempt (it may legitimately outgrow the
            budget alone and must run to completion), the exact progress
            guarantee the paged engine's strict-age eviction provides.
            """
            for rt in reversed(llm_running[e][1:]):
                if rt.task.out_tokens - max(0.0, rt.remaining_tokens) > 0:
                    return rt
            return None

        def next_kv_overflow() -> Tuple[float, int]:
            """Earliest time a replica's KV usage reaches its budget."""
            if self._kv is None:
                return math.inf, -1
            best_t, best_e = math.inf, -1
            for e in range(self.n_llm):
                b = llm_batch(e)
                if b < 2:
                    continue  # a lone request always runs to completion
                head = self._kv[e] - kv_usage(e)
                if head <= 0:
                    if sheddable_victim(e) is not None:
                        return now, e  # already over: relieve immediately
                    continue  # only the exempt oldest holds KV
                # usage grows at b tasks x 1/(l(b)·scale) tokens/s each
                t = now + head * self.profile.l(b) * self._lat_scale[e] / b
                if t < best_t:
                    best_t, best_e = t, e
            return best_t, best_e

        def relieve_kv(e: int) -> None:
            """Replica ``e`` hit its KV budget: shed youngest tasks until a
            relief quantum of headroom exists (the paged engine frees whole
            pages, not single tokens — the quantum prevents an event storm
            of ever-smaller evictions).  Each victim is live-migrated to
            the peer with the most KV headroom when ``migrate=True`` and a
            fit exists, else preempted (recompute restart — its decoded
            tokens are lost), mirroring the paged engine's LIFO eviction.
            """
            quantum = self.kv_relief_quantum
            cost_tokens = self.migration_cost_s / self.profile.l(1)
            while (kv_headroom(e) or 0.0) < quantum:
                victim = sheddable_victim(e)  # youngest holder, never oldest
                if victim is None:
                    return  # nothing sheddable holds KV
                used = victim.task.out_tokens - max(0.0, victim.remaining_tokens)
                migrated = False
                if self.migrate:
                    best = None
                    for x in range(self.n_llm):
                        if x == e or llm_batch(x) >= self._mb[x]:
                            continue
                        head = kv_headroom(x)
                        if head is not None and head > used + cost_tokens + quantum:
                            if best is None or head > best[0]:
                                best = (head, x)
                    if best is not None:
                        llm_running[e].remove(victim)
                        victim.executor = best[1]
                        victim.remaining_tokens += cost_tokens
                        llm_running[best[1]].append(victim)
                        res.migrations += 1
                        migrated = True
                if not migrated:
                    llm_running[e].remove(victim)
                    victim.task.state = TaskState.PENDING
                    victim.task.start_time = -1.0
                    victim.remaining_tokens = float(victim.task.out_tokens)
                    job_by_id[victim.task.job_id].bump_evidence()
                    res.preemptions += 1

        def on_stage_complete(job: Job, stage: Stage) -> None:
            # chain reveals + dynamic expansion + evidence-version bump
            reveal_after_stage(job, stage, gens)

        def dispatch(dec: Decision) -> bool:
            did = False
            # regular
            for t in dec.regular:
                if t.state is not TaskState.PENDING:
                    continue
                for e in range(self.n_regular):
                    if reg_running[e] is None:
                        t.state = TaskState.RUNNING
                        t.start_time = now
                        job = job_by_id[t.job_id]
                        job.stages[t.stage_name].dispatched_tasks += 1
                        job.bump_evidence()  # running/unscheduled sets changed
                        dur = t.true_duration
                        if straggler_prob and self.rng.random() < straggler_prob:
                            dur *= 4.0 + 6.0 * self.rng.random()  # straggler
                        reg_running[e] = (now + dur, t)
                        did = True
                        break
            # llm: scheduler placement hint first (uncertainty/KV-aware),
            # falling back to least-loaded (paper §IV-D) — the exact
            # pre-placement behaviour for schedulers without hints
            for t in dec.llm:
                if t.state is not TaskState.PENDING:
                    continue
                def admissible(x: int) -> bool:
                    if llm_batch(x) >= self._mb[x]:
                        return False
                    if self._ranks is not None and self._ranks[x] < t.tier_floor:
                        return False  # cascade retry must run one tier up
                    head = kv_headroom(x)
                    return head is None or head >= self.kv_admission_reserve

                e = dec.replica_for(t)
                if e is None or not (0 <= e < self.n_llm) or not admissible(e):
                    loads = [
                        (llm_batch(x), x)
                        for x in range(self.n_llm)
                        if admissible(x)
                    ]
                    if not loads:
                        break
                    _, e = min(loads)
                t.state = TaskState.RUNNING
                t.start_time = now
                job = job_by_id[t.job_id]
                job.stages[t.stage_name].dispatched_tasks += 1
                job.bump_evidence()  # running/unscheduled sets changed
                # prompt prefill is charged as extra tokens at the batch
                # rate (the chunked-prefill-interleaved-with-decode model)
                prefill = charge_prefill(e, t)
                llm_running[e].append(
                    RunningLLMTask(
                        task=t,
                        remaining_tokens=float(t.out_tokens) + prefill,
                        executor=e,
                    )
                )
                did = True
            return did

        def rebalance() -> None:
            """Without KV budgets, ``migrate=True`` degrades to batch-gap
            balancing: move running LLM tasks from the most- to the
            least-loaded replica, each paying the KV-transfer stall as
            extra decode tokens at the batch-1 rate.  (With KV budgets,
            migration is driven by KV overflow instead — ``relieve_kv``.)
            """
            if not self.migrate or self.n_llm < 2 or self._kv is not None:
                return
            cost_tokens = self.migration_cost_s / self.profile.l(1)
            while True:
                bs = [llm_batch(e) for e in range(self.n_llm)]
                recv = [e for e in range(self.n_llm) if bs[e] < self._mb[e]]
                if not recv:
                    return
                e_max = max(range(self.n_llm), key=lambda e: bs[e])
                e_min = min(recv, key=lambda e: bs[e])
                if bs[e_max] - bs[e_min] < 2 or not llm_running[e_max]:
                    return
                rt = llm_running[e_max][-1]  # youngest dispatch (LIFO)
                llm_running[e_max].remove(rt)
                rt.executor = e_min
                rt.remaining_tokens += cost_tokens
                llm_running[e_min].append(rt)
                res.migrations += 1

        def invoke_scheduler() -> None:
            view = ClusterView.assemble(
                now=now,
                free_regular=sum(1 for s in reg_running if s is None),
                llm_loads=[
                    (llm_batch(e), self._mb[e]) for e in range(self.n_llm)
                ],
                latency_profile=self.profile,
                llm_free_tokens=(
                    None
                    if self._kv is None
                    else [
                        max(0, int(kv_headroom(e) or 0))
                        for e in range(self.n_llm)
                    ]
                ),
                llm_prefix_hit_tokens=(
                    [prefix_resident_tokens(e) for e in range(self.n_llm)]
                    if self.prefix_cache
                    else None
                ),
                llm_model_costs=self._tier_cost,
            )
            t0 = _time.perf_counter()
            dec = self.scheduler.schedule(active, view)
            res.sched_overhead_s.append(_time.perf_counter() - t0)
            dispatch(dec)
            rebalance()

        job_by_id = {j.job_id: j for j in jobs}

        # ---------------- event loop ----------------
        while ai < len(arrivals) or active:
            t_arr = arrivals[ai].arrival_time if ai < len(arrivals) else math.inf
            t_llm, llm_rt = next_llm_completion()
            t_reg, reg_e = next_regular_completion()
            t_kv, kv_e = next_kv_overflow()
            t_next = min(t_arr, t_llm, t_reg, t_fail, t_kv)
            if math.isinf(t_next):
                break  # deadlock guard (should not happen)
            dt = t_next - now
            advance_llm(dt)
            now = t_next

            if t_next == t_kv and kv_e >= 0:
                # KV pool overflow: live-migrate or preempt (LIFO)
                relieve_kv(kv_e)
            elif t_next == t_fail:
                # executor failure: requeue its running work (the tasks are
                # re-dispatched by the very next scheduling invocation —
                # checkpoint/restart at the scheduling layer)
                victim = int(self.rng.integers(0, n_exec))
                if victim < self.n_regular:
                    slot = reg_running[victim]
                    if slot is not None:
                        slot[1].state = TaskState.PENDING
                        slot[1].start_time = -1.0
                        job_by_id[slot[1].job_id].bump_evidence()
                        reg_running[victim] = None
                        res.preemptions += 1
                else:
                    e = victim - self.n_regular
                    for rt in llm_running[e]:
                        rt.task.state = TaskState.PENDING
                        rt.task.start_time = -1.0
                        job_by_id[rt.task.job_id].bump_evidence()
                        res.preemptions += 1
                    llm_running[e] = []
                t_fail = _next_failure(now)
            elif t_next == t_arr:
                job = arrivals[ai]
                ai += 1
                active.append(job)
            elif t_next == t_reg and reg_e >= 0:
                _, task = reg_running[reg_e]  # type: ignore[misc]
                reg_running[reg_e] = None
                if task.state is TaskState.DONE:
                    pass  # backup of an already-finished task: discard
                else:
                    self._finish_task(task, now, job_by_id, on_stage_complete,
                                      active, res)
                # cancel sibling copies (speculative execution: first wins)
                for e2, slot2 in enumerate(reg_running):
                    if slot2 is not None and slot2[1] is task:
                        reg_running[e2] = None
            elif llm_rt is not None:
                e = llm_rt.executor
                llm_running[e].remove(llm_rt)
                task = llm_rt.task
                if self._tier_cost is not None:
                    # every completed attempt pays its tier's price —
                    # including attempts the gate is about to reject
                    # (wasted spend is real spend)
                    res.cost_by_job[task.job_id] = (
                        res.cost_by_job.get(task.job_id, 0.0)
                        + task.out_tokens * self._tier_cost[e]
                    )
                if self.gate is not None:
                    app = job_by_id[task.job_id].app.name
                    ok = self.gate.passes(
                        app, task.stage_name, task.index,
                        task.attempt, self._tier_quality[e],
                    )
                    if (
                        not ok
                        and self.cascade
                        and self._ranks[e] < max(self._ranks)
                    ):
                        # cascade retry: back to PENDING one tier up;
                        # the prompt re-enters through dispatch and hits
                        # the destination's prefix cache where resident
                        task.tier_floor = self._ranks[e] + 1
                        task.attempt += 1
                        task.state = TaskState.PENDING
                        task.start_time = -1.0
                        job_by_id[task.job_id].bump_evidence()
                        res.escalations += 1
                    else:
                        # accepted, or rejected with nowhere to go
                        # (top tier / no cascade): output stands, the
                        # job's quality records the verdict
                        res.quality_by_job[task.job_id] = (
                            res.quality_by_job.get(task.job_id, True) and ok
                        )
                        self._finish_task(
                            task, now, job_by_id, on_stage_complete,
                            active, res,
                        )
                else:
                    self._finish_task(
                        task, now, job_by_id, on_stage_complete, active, res
                    )

            # straggler mitigation: speculatively re-issue regular tasks
            # that exceed straggler_factor x their nominal duration on a
            # free executor (first finisher wins)
            if self.straggler_factor > 0:
                running_ids = {id(s[1]) for s in reg_running if s is not None}
                for e, slot in enumerate(reg_running):
                    if slot is None:
                        continue
                    deadline, task = slot
                    overdue = now - task.start_time > (
                        self.straggler_factor * max(task.true_duration, 1e-9)
                    )
                    dup_exists = sum(
                        1 for s2 in reg_running
                        if s2 is not None and s2[1] is task
                    ) > 1
                    if overdue and not dup_exists:
                        for e2 in range(self.n_regular):
                            if reg_running[e2] is None:
                                reg_running[e2] = (now + task.true_duration, task)
                                res.reissues += 1
                                break

            invoke_scheduler()

        res.makespan = now
        res.retractions = int(getattr(self.scheduler, "retractions", 0))
        return res

    def _finish_task(
        self,
        task: Task,
        now: float,
        job_by_id: Dict[int, Job],
        on_stage_complete: Callable[[Job, Stage], None],
        active: List[Job],
        res: SimResult,
    ) -> None:
        task.state = TaskState.DONE
        task.finish_time = now
        job = job_by_id[task.job_id]
        job.bump_evidence()  # new completed-duration evidence
        stage = job.stages[task.stage_name]
        if stage.done():
            on_stage_complete(job, stage)
        if job.done():
            job.finish_time = now
            res.jcts.append(job.jct())
            res.jct_by_job[job.job_id] = job.jct()
            if job.slo is not None:
                res.tier_by_job[job.job_id] = job.slo.tier
                res.deadline_by_job[job.job_id] = job.slo.deadline
                met = job.met_slo()
                if met is not None:
                    res.slo_met_by_job[job.job_id] = met
            if job in active:
                active.remove(job)
            self.scheduler.observe_completion(job, now)


# ---------------------------------------------------------------------------
# Cluster sizing (paper §V: resources set for ~85% average load)
# ---------------------------------------------------------------------------
def configure_cluster(
    mix: str,
    arrival_rate: float = 0.9,
    target_load: float = 0.85,
    max_batch: int = 8,
    profile: Optional[LatencyProfile] = None,
    probe_jobs: int = 300,
    seed: int = 99,
) -> Dict[str, int]:
    """Pick (n_llm, n_regular) so offered load ≈ ``target_load``.

    Offered LLM load = token arrival rate ÷ executor token throughput at
    full batch; regular load = task-seconds per second.
    """
    from .workloads import generate_workload

    profile = profile or default_latency_profile(max_batch)
    wl = generate_workload(mix, probe_jobs, arrival_rate, seed=seed)
    span = max(gj.job.arrival_time for gj in wl) - min(
        gj.job.arrival_time for gj in wl
    )
    span = max(span, 1e-9)
    llm_tokens = 0.0
    reg_seconds = 0.0
    for gj in wl:
        for st in gj.job.stages.values():
            for t in st.tasks:
                if not st.will_execute:
                    continue
                if t.is_llm:
                    llm_tokens += t.out_tokens
                else:
                    reg_seconds += t.true_duration
        for dyn, durs in getattr(gj.job, "_dyn_durs", {}).items():
            pass  # inner dynamic tasks already counted via stages after expand
        for dname, (cands, _) in gj.job.dynamic_realization.items():
            gen_durs = getattr(gj.job, "_dyn_durs", {}).get(dname, {})
            for c in cands:
                d = gen_durs.get(c, 0.0)
                # planning inner stages: LLM candidates expressed in seconds
                reg_seconds += d  # conservative: treat as regular-side load
    tok_rate = llm_tokens / span
    reg_rate = reg_seconds / span
    # search (n_llm, max_batch) for the load closest to target; prefer few,
    # large executors (a vLLM-style engine per accelerator, not one slot
    # per request) — ties broken toward larger batches / fewer engines.
    best = None
    for mb in (16, 8, 4):
        if profile.batch_sizes.max() < mb:
            continue
        thr = mb / profile.l(mb)
        for n in range(1, 33):
            load = tok_rate / (n * thr)
            if load > 1.02:  # refuse unstable configs
                continue
            score = (abs(load - target_load), n, -mb)
            if best is None or score < best[0]:
                best = (score, n, mb, load)
    _, n_llm, mb, _ = best if best else ((0,), 1, max_batch, 1.0)
    n_regular = max(2, math.ceil(reg_rate / target_load))
    return {"n_llm": n_llm, "n_regular": n_regular, "max_batch": mb}


# ---------------------------------------------------------------------------
# Convenience runner
# ---------------------------------------------------------------------------
def simulate(
    scheduler: Scheduler,
    mix: str = "mixed",
    n_jobs: int = 100,
    arrival_rate: float = 0.9,
    n_regular: int = 4,
    n_llm: int = 1,
    max_batch: int = 8,
    seed: int = 0,
    **kw,
) -> SimResult:
    from .workloads import generate_workload

    wl = generate_workload(mix, n_jobs, arrival_rate, seed=seed)
    sim = ClusterSim(
        scheduler,
        n_regular=n_regular,
        n_llm=n_llm,
        max_batch=max_batch,
        seed=seed,
        **kw,
    )
    return sim.run(wl)
