"""Event-driven cluster simulator (paper §V "Simulator").

Models the provider's cluster:
- ``n_regular`` regular executors — one regular task each;
- ``n_llm`` LLM executors — up to ``max_batch`` concurrent LLM tasks.

LLM tasks are token streams: a task with T output tokens finishes after
its executor decodes T of its tokens.  The per-token latency depends on
the executor's *current* batch size via a :class:`LatencyProfile`, so —
exactly like the paper's simulator — the remaining duration of every
running LLM task is re-stretched whenever the batch composition changes.

Optional fault injection: executor failures re-queue running tasks
(checkpoint/restart at the scheduling layer) and straggler tasks are
re-issued once they exceed ``straggler_factor`` × their expected duration
(speculative execution), mirroring what the large-scale runtime needs.
"""

from __future__ import annotations

import heapq
import math
import time as _time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.calibration import LatencyProfile, roofline_profile
from ..core.dag import Job, Stage, Task, TaskState
from ..core.scheduler import ClusterView, Decision, Scheduler
from .workloads import (
    TOKEN_LATENCY_B1,
    GeneratedJob,
    get_generators,
    reveal_after_stage,
)


def default_latency_profile(max_batch: int = 16) -> LatencyProfile:
    """l(b) with l(1) = TOKEN_LATENCY_B1 and sub-linear growth in b —
    the memory-bound decode roofline (weights amortize, KV does not)."""
    bs = np.arange(1, max_batch + 1)
    # weights ≫ per-request KV: l(b) grows gently; matches H800 profiles
    lat = TOKEN_LATENCY_B1 * (0.85 + 0.15 * bs ** 0.7)
    return LatencyProfile(batch_sizes=bs, latency=lat)


@dataclass
class RunningLLMTask:
    task: Task
    remaining_tokens: float
    executor: int


@dataclass
class SimResult:
    jcts: List[float] = field(default_factory=list)
    jct_by_job: Dict[int, float] = field(default_factory=dict)
    sched_overhead_s: List[float] = field(default_factory=list)
    makespan: float = 0.0
    preemptions: int = 0
    reissues: int = 0

    @property
    def avg_jct(self) -> float:
        return float(np.mean(self.jcts)) if self.jcts else 0.0

    @property
    def p95_jct(self) -> float:
        return float(np.percentile(self.jcts, 95)) if self.jcts else 0.0

    @property
    def avg_overhead_ms(self) -> float:
        return 1e3 * float(np.mean(self.sched_overhead_s)) if self.sched_overhead_s else 0.0


class ClusterSim:
    def __init__(
        self,
        scheduler: Scheduler,
        n_regular: int = 4,
        n_llm: int = 1,
        max_batch: int = 8,
        latency_profile: Optional[LatencyProfile] = None,
        failure_rate: float = 0.0,       # executor failures per sim-second
        straggler_factor: float = 0.0,   # 0 disables re-issue
        seed: int = 0,
    ) -> None:
        self.scheduler = scheduler
        self.n_regular = n_regular
        self.n_llm = n_llm
        self.max_batch = max_batch
        self.profile = latency_profile or default_latency_profile(max_batch)
        self.failure_rate = failure_rate
        self.straggler_factor = straggler_factor
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------------------ run
    def run(self, workload: Sequence[GeneratedJob]) -> SimResult:
        gens = get_generators()
        jobs: List[Job] = [gj.job for gj in workload]
        res = SimResult()

        now = 0.0
        arrivals = sorted(jobs, key=lambda j: j.arrival_time)
        ai = 0
        active: List[Job] = []

        # fault injection: next executor-failure time (Poisson process over
        # all executors); straggler injection probability for regular tasks
        n_exec = self.n_regular + self.n_llm
        def _next_failure(t0: float) -> float:
            if self.failure_rate <= 0:
                return math.inf
            return t0 + float(self.rng.exponential(1.0 / (self.failure_rate * n_exec)))
        t_fail = _next_failure(0.0)
        straggler_prob = 0.05 if self.straggler_factor > 0 else 0.0
        # regular duplicates: task id -> (deadline, executor) of the backup
        backups: Dict[int, Tuple[float, int]] = {}

        # regular executors: list of (finish_time, task) or None
        reg_running: List[Optional[Tuple[float, Task]]] = [None] * self.n_regular
        # LLM executors: running task lists
        llm_running: List[List[RunningLLMTask]] = [[] for _ in range(self.n_llm)]

        def llm_batch(e: int) -> int:
            return len(llm_running[e])

        def advance_llm(dt: float) -> None:
            if dt <= 0:
                return
            for e in range(self.n_llm):
                b = llm_batch(e)
                if b == 0:
                    continue
                rate = 1.0 / self.profile.l(b)  # tokens/sec per request
                for rt in llm_running[e]:
                    rt.remaining_tokens -= dt * rate

        def next_llm_completion() -> Tuple[float, Optional[RunningLLMTask]]:
            best_t, best = math.inf, None
            for e in range(self.n_llm):
                b = llm_batch(e)
                if b == 0:
                    continue
                per_tok = self.profile.l(b)
                for rt in llm_running[e]:
                    t = now + max(0.0, rt.remaining_tokens) * per_tok
                    if t < best_t:
                        best_t, best = t, rt
            return best_t, best

        def next_regular_completion() -> Tuple[float, int]:
            best_t, best_e = math.inf, -1
            for e, slot in enumerate(reg_running):
                if slot is not None and slot[0] < best_t:
                    best_t, best_e = slot[0], e
            return best_t, best_e

        def on_stage_complete(job: Job, stage: Stage) -> None:
            # chain reveals + dynamic expansion + evidence-version bump
            reveal_after_stage(job, stage, gens)

        def dispatch(dec: Decision) -> bool:
            did = False
            # regular
            for t in dec.regular:
                if t.state is not TaskState.PENDING:
                    continue
                for e in range(self.n_regular):
                    if reg_running[e] is None:
                        t.state = TaskState.RUNNING
                        t.start_time = now
                        job = job_by_id[t.job_id]
                        job.stages[t.stage_name].dispatched_tasks += 1
                        job.bump_evidence()  # running/unscheduled sets changed
                        dur = t.true_duration
                        if straggler_prob and self.rng.random() < straggler_prob:
                            dur *= 4.0 + 6.0 * self.rng.random()  # straggler
                        reg_running[e] = (now + dur, t)
                        did = True
                        break
            # llm: least-loaded placement (paper §IV-D)
            for t in dec.llm:
                if t.state is not TaskState.PENDING:
                    continue
                loads = [(llm_batch(e), e) for e in range(self.n_llm)]
                b, e = min(loads)
                if b >= self.max_batch:
                    break
                t.state = TaskState.RUNNING
                t.start_time = now
                job = job_by_id[t.job_id]
                job.stages[t.stage_name].dispatched_tasks += 1
                job.bump_evidence()  # running/unscheduled sets changed
                llm_running[e].append(
                    RunningLLMTask(task=t, remaining_tokens=float(t.out_tokens), executor=e)
                )
                did = True
            return did

        def invoke_scheduler() -> None:
            view = ClusterView(
                now=now,
                free_regular=sum(1 for s in reg_running if s is None),
                llm_loads=[(llm_batch(e), self.max_batch) for e in range(self.n_llm)],
                latency_profile=self.profile,
            )
            t0 = _time.perf_counter()
            dec = self.scheduler.schedule(active, view)
            res.sched_overhead_s.append(_time.perf_counter() - t0)
            dispatch(dec)

        job_by_id = {j.job_id: j for j in jobs}

        # ---------------- event loop ----------------
        while ai < len(arrivals) or active:
            t_arr = arrivals[ai].arrival_time if ai < len(arrivals) else math.inf
            t_llm, llm_rt = next_llm_completion()
            t_reg, reg_e = next_regular_completion()
            t_next = min(t_arr, t_llm, t_reg, t_fail)
            if math.isinf(t_next):
                break  # deadlock guard (should not happen)
            dt = t_next - now
            advance_llm(dt)
            now = t_next

            if t_next == t_fail:
                # executor failure: requeue its running work (the tasks are
                # re-dispatched by the very next scheduling invocation —
                # checkpoint/restart at the scheduling layer)
                victim = int(self.rng.integers(0, n_exec))
                if victim < self.n_regular:
                    slot = reg_running[victim]
                    if slot is not None:
                        slot[1].state = TaskState.PENDING
                        slot[1].start_time = -1.0
                        job_by_id[slot[1].job_id].bump_evidence()
                        reg_running[victim] = None
                        res.preemptions += 1
                else:
                    e = victim - self.n_regular
                    for rt in llm_running[e]:
                        rt.task.state = TaskState.PENDING
                        rt.task.start_time = -1.0
                        job_by_id[rt.task.job_id].bump_evidence()
                        res.preemptions += 1
                    llm_running[e] = []
                t_fail = _next_failure(now)
            elif t_next == t_arr:
                job = arrivals[ai]
                ai += 1
                active.append(job)
            elif t_next == t_reg and reg_e >= 0:
                _, task = reg_running[reg_e]  # type: ignore[misc]
                reg_running[reg_e] = None
                if task.state is TaskState.DONE:
                    pass  # backup of an already-finished task: discard
                else:
                    self._finish_task(task, now, job_by_id, on_stage_complete,
                                      active, res)
                # cancel sibling copies (speculative execution: first wins)
                for e2, slot2 in enumerate(reg_running):
                    if slot2 is not None and slot2[1] is task:
                        reg_running[e2] = None
            elif llm_rt is not None:
                llm_running[llm_rt.executor].remove(llm_rt)
                self._finish_task(
                    llm_rt.task, now, job_by_id, on_stage_complete, active, res
                )

            # straggler mitigation: speculatively re-issue regular tasks
            # that exceed straggler_factor x their nominal duration on a
            # free executor (first finisher wins)
            if self.straggler_factor > 0:
                running_ids = {id(s[1]) for s in reg_running if s is not None}
                for e, slot in enumerate(reg_running):
                    if slot is None:
                        continue
                    deadline, task = slot
                    overdue = now - task.start_time > (
                        self.straggler_factor * max(task.true_duration, 1e-9)
                    )
                    dup_exists = sum(
                        1 for s2 in reg_running
                        if s2 is not None and s2[1] is task
                    ) > 1
                    if overdue and not dup_exists:
                        for e2 in range(self.n_regular):
                            if reg_running[e2] is None:
                                reg_running[e2] = (now + task.true_duration, task)
                                res.reissues += 1
                                break

            invoke_scheduler()

        res.makespan = now
        return res

    def _finish_task(
        self,
        task: Task,
        now: float,
        job_by_id: Dict[int, Job],
        on_stage_complete: Callable[[Job, Stage], None],
        active: List[Job],
        res: SimResult,
    ) -> None:
        task.state = TaskState.DONE
        task.finish_time = now
        job = job_by_id[task.job_id]
        job.bump_evidence()  # new completed-duration evidence
        stage = job.stages[task.stage_name]
        if stage.done():
            on_stage_complete(job, stage)
        if job.done():
            job.finish_time = now
            res.jcts.append(job.jct())
            res.jct_by_job[job.job_id] = job.jct()
            if job in active:
                active.remove(job)
            self.scheduler.observe_completion(job, now)


# ---------------------------------------------------------------------------
# Cluster sizing (paper §V: resources set for ~85% average load)
# ---------------------------------------------------------------------------
def configure_cluster(
    mix: str,
    arrival_rate: float = 0.9,
    target_load: float = 0.85,
    max_batch: int = 8,
    profile: Optional[LatencyProfile] = None,
    probe_jobs: int = 300,
    seed: int = 99,
) -> Dict[str, int]:
    """Pick (n_llm, n_regular) so offered load ≈ ``target_load``.

    Offered LLM load = token arrival rate ÷ executor token throughput at
    full batch; regular load = task-seconds per second.
    """
    from .workloads import generate_workload

    profile = profile or default_latency_profile(max_batch)
    wl = generate_workload(mix, probe_jobs, arrival_rate, seed=seed)
    span = max(gj.job.arrival_time for gj in wl) - min(
        gj.job.arrival_time for gj in wl
    )
    span = max(span, 1e-9)
    llm_tokens = 0.0
    reg_seconds = 0.0
    for gj in wl:
        for st in gj.job.stages.values():
            for t in st.tasks:
                if not st.will_execute:
                    continue
                if t.is_llm:
                    llm_tokens += t.out_tokens
                else:
                    reg_seconds += t.true_duration
        for dyn, durs in getattr(gj.job, "_dyn_durs", {}).items():
            pass  # inner dynamic tasks already counted via stages after expand
        for dname, (cands, _) in gj.job.dynamic_realization.items():
            gen_durs = getattr(gj.job, "_dyn_durs", {}).get(dname, {})
            for c in cands:
                d = gen_durs.get(c, 0.0)
                # planning inner stages: LLM candidates expressed in seconds
                from ..core.dag import StageType as _ST
                reg_seconds += d  # conservative: treat as regular-side load
    tok_rate = llm_tokens / span
    reg_rate = reg_seconds / span
    # search (n_llm, max_batch) for the load closest to target; prefer few,
    # large executors (a vLLM-style engine per accelerator, not one slot
    # per request) — ties broken toward larger batches / fewer engines.
    best = None
    for mb in (16, 8, 4):
        if profile.batch_sizes.max() < mb:
            continue
        thr = mb / profile.l(mb)
        for n in range(1, 33):
            load = tok_rate / (n * thr)
            if load > 1.02:  # refuse unstable configs
                continue
            score = (abs(load - target_load), n, -mb)
            if best is None or score < best[0]:
                best = (score, n, mb, load)
    _, n_llm, mb, _ = best if best else ((0,), 1, max_batch, 1.0)
    n_regular = max(2, math.ceil(reg_rate / target_load))
    return {"n_llm": n_llm, "n_regular": n_regular, "max_batch": mb}


# ---------------------------------------------------------------------------
# Convenience runner
# ---------------------------------------------------------------------------
def simulate(
    scheduler: Scheduler,
    mix: str = "mixed",
    n_jobs: int = 100,
    arrival_rate: float = 0.9,
    n_regular: int = 4,
    n_llm: int = 1,
    max_batch: int = 8,
    seed: int = 0,
    **kw,
) -> SimResult:
    from .workloads import generate_workload

    wl = generate_workload(mix, n_jobs, arrival_rate, seed=seed)
    sim = ClusterSim(
        scheduler,
        n_regular=n_regular,
        n_llm=n_llm,
        max_batch=max_batch,
        seed=seed,
        **kw,
    )
    return sim.run(wl)
