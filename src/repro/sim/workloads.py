"""Workload generators: the paper's six compound LLM applications (§V).

Each generator builds an :class:`ApplicationTemplate` and samples runtime
jobs with ground-truth durations/structures.  Ground truth is *hidden*
from schedulers: they see stage durations only after completion, chain
lengths only as iterations reveal themselves, and dynamic-stage contents
only after the planner LLM stage finishes.

Duration models follow the paper's measured characteristics (§III):
- sequence sorting : job duration ~10–300 s, stage durations strongly
  correlated through the latent sequence length (Fig. 5a: r≈0.7);
- code generation  : chain length 3–15 stages (Fig. 1b), iterations
  correlated (Fig. 5b: r≈0.9) via a latent task complexity;
- task automation  : 1–8 generated stages (Fig. 1c), job 1–116 s;
- doc merging / web search / LLMCompiler follow the same recipes.

LLM-task durations are expressed as ``out_tokens`` × per-token latency at
batch size 1; the simulator stretches them with the batching profile, so
batching-aware calibration (Eq. 2) has a real effect to correct for.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.dag import (
    SLO,
    ApplicationTemplate,
    Job,
    Stage,
    StageTemplate,
    StageType,
    Task,
    make_job,
)
from ..core.profiler import JobTrace

# per-token decode latency at batch size 1 used to convert token counts
# into seconds (the simulator's l(1); see repro.core.calibration).
TOKEN_LATENCY_B1 = 0.02  # 20 ms/token — Llama2-7B-class on one accelerator


# ---------------------------------------------------------------------------
# Generator base
# ---------------------------------------------------------------------------
@dataclass
class GeneratedJob:
    job: Job
    # ground-truth per-stage durations (for traces/inspection)
    durations: Dict[str, float] = field(default_factory=dict)


class AppGenerator:
    """Base class: builds the template and samples jobs."""

    name: str = "base"

    def __init__(self) -> None:
        self.template = self.build_template()

    def build_template(self) -> ApplicationTemplate:
        raise NotImplementedError

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        raise NotImplementedError

    # -- shared helpers -----------------------------------------------------
    def _set_llm_stage(self, job: Job, name: str, out_tokens: int,
                       n_tasks: Optional[int] = None) -> float:
        st = job.stages[name]
        dur = out_tokens * TOKEN_LATENCY_B1
        for t in st.tasks:
            t.true_duration = dur
            t.out_tokens = out_tokens
        return dur

    def _set_regular_stage(self, job: Job, name: str, duration: float) -> float:
        st = job.stages[name]
        for t in st.tasks:
            t.true_duration = duration
        return duration

    def trace_of(self, gj: GeneratedJob) -> JobTrace:
        """Offline trace (durations at batch size 1) for BN training."""
        dyn_durs: Dict[str, Dict[str, float]] = {}
        for dname, (cands, _e) in gj.job.dynamic_realization.items():
            dyn_durs[dname] = {
                c: gj.durations.get(f"{dname}.{c}", 0.0) for c in cands
            }
        return JobTrace(
            app_name=self.name,
            durations={
                k: v for k, v in gj.durations.items() if "." not in k
            },
            dynamic=dict(gj.job.dynamic_realization),
            dynamic_durations=dyn_durs,
        )


# ---------------------------------------------------------------------------
# 1. Sequence sorting (predefined — Graph-of-Thoughts)
# ---------------------------------------------------------------------------
class SequenceSorting(AppGenerator):
    """GoT sorting: split → per-part candidate generation (multi-task LLM)
    → scoring (regular) → merge (LLM) → refine (LLM) → final score.
    Stage durations proportional to the latent sequence length."""

    name = "seq_sort"

    def build_template(self) -> ApplicationTemplate:
        stages = [
            StageTemplate("split", StageType.LLM),
            StageTemplate("sort_p1", StageType.LLM, num_tasks=3),
            StageTemplate("sort_p2", StageType.LLM, num_tasks=3),
            StageTemplate("score_p1", StageType.REGULAR),
            StageTemplate("score_p2", StageType.REGULAR),
            StageTemplate("merge", StageType.LLM),
            StageTemplate("refine", StageType.LLM, num_tasks=2),
            StageTemplate("final_score", StageType.REGULAR),
        ]
        edges = [
            ("split", "sort_p1"), ("split", "sort_p2"),
            ("sort_p1", "score_p1"), ("sort_p2", "score_p2"),
            ("score_p1", "merge"), ("score_p2", "merge"),
            ("merge", "refine"), ("refine", "final_score"),
        ]
        return ApplicationTemplate(self.name, stages, edges)

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        job = make_job(self.template, arrival_time)
        seq_len = int(rng.integers(16, 65))  # paper: 16–64
        latent = float(rng.lognormal(0.0, 0.5))      # job-level difficulty
        noise = lambda: latent * float(rng.lognormal(0.0, 0.2))
        durs: Dict[str, float] = {}
        durs["split"] = self._set_llm_stage(job, "split", int(seq_len * 2 * noise()))
        half = seq_len / 2
        durs["sort_p1"] = self._set_llm_stage(job, "sort_p1", int(half * 16 * noise()))
        durs["sort_p2"] = self._set_llm_stage(job, "sort_p2", int(half * 16 * noise()))
        durs["score_p1"] = self._set_regular_stage(job, "score_p1", 0.2 + 0.01 * half * noise())
        durs["score_p2"] = self._set_regular_stage(job, "score_p2", 0.2 + 0.01 * half * noise())
        durs["merge"] = self._set_llm_stage(job, "merge", int(seq_len * 10 * noise()))
        durs["refine"] = self._set_llm_stage(job, "refine", int(seq_len * 8 * noise()))
        durs["final_score"] = self._set_regular_stage(job, "final_score", 0.3 * noise())
        for s in job.stages.values():
            s.revealed = True  # predefined: structure known upfront
        return GeneratedJob(job, durs)


# ---------------------------------------------------------------------------
# 2. Document merging (predefined — Graph-of-Thoughts)
# ---------------------------------------------------------------------------
class DocMerging(AppGenerator):
    name = "doc_merge"

    def build_template(self) -> ApplicationTemplate:
        stages = [
            StageTemplate("gen_merge", StageType.LLM, num_tasks=4),
            StageTemplate("score_cand", StageType.REGULAR, num_tasks=4),
            StageTemplate("select", StageType.REGULAR),
            StageTemplate("final_merge", StageType.LLM),
            StageTemplate("final_score", StageType.REGULAR),
        ]
        edges = [
            ("gen_merge", "score_cand"), ("score_cand", "select"),
            ("select", "final_merge"), ("final_merge", "final_score"),
        ]
        return ApplicationTemplate(self.name, stages, edges)

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        job = make_job(self.template, arrival_time)
        doc_size = float(rng.lognormal(math.log(600), 0.7))  # latent doc tokens
        noise = lambda: float(rng.lognormal(0.0, 0.2))
        durs: Dict[str, float] = {}
        durs["gen_merge"] = self._set_llm_stage(job, "gen_merge", int(doc_size * noise()))
        durs["score_cand"] = self._set_regular_stage(job, "score_cand", 0.4 + doc_size * 4e-4 * noise())
        durs["select"] = self._set_regular_stage(job, "select", 0.1)
        durs["final_merge"] = self._set_llm_stage(job, "final_merge", int(doc_size * 0.8 * noise()))
        durs["final_score"] = self._set_regular_stage(job, "final_score", 0.3 * noise())
        for s in job.stages.values():
            s.revealed = True
        return GeneratedJob(job, durs)


# ---------------------------------------------------------------------------
# Chain-like base: padded iterations + early stopping
# ---------------------------------------------------------------------------
class ChainApp(AppGenerator):
    """Chain pattern: prologue + N iterations of (llm → regular → llm).
    Padded to MAX_ITERS (paper §IV-A); unexecuted stages get duration 0."""

    MAX_ITERS: int = 5
    PATTERN: List[Tuple[str, StageType]] = []
    PROLOGUE: List[Tuple[str, StageType]] = []

    def build_template(self) -> ApplicationTemplate:
        stages: List[StageTemplate] = []
        edges: List[Tuple[str, str]] = []
        prev: Optional[str] = None
        for n, st in self.PROLOGUE:
            stages.append(StageTemplate(n, st))
            if prev:
                edges.append((prev, n))
            prev = n
        for i in range(self.MAX_ITERS):
            for n, st in self.PATTERN:
                name = f"{n}_{i}"
                stages.append(StageTemplate(name, st, exec_prob=1.0))
                if prev:
                    edges.append((prev, name))
                prev = name
        return ApplicationTemplate(self.name, stages, edges)

    def _chain_iters(self, rng: np.random.Generator) -> int:
        """Number of executed iterations, 1..MAX_ITERS (geometric-ish)."""
        n = 1
        while n < self.MAX_ITERS and rng.random() > self.stop_prob:
            n += 1
        return n

    stop_prob = 0.45

    def mark_chain(self, job: Job, iters: int) -> None:
        """Set will_execute + reveal rules: finishing the last stage of
        iteration i reveals whether iteration i+1 runs."""
        for i in range(self.MAX_ITERS):
            execute = i < iters
            for n, _ in self.PATTERN:
                job.stages[f"{n}_{i}"].will_execute = execute
        # prologue + iteration 0 visible upfront
        for n, _ in self.PROLOGUE:
            job.stages[n].revealed = True
        for n, _ in self.PATTERN:
            job.stages[f"{n}_0"].revealed = True
        last = self.PATTERN[-1][0]
        for i in range(self.MAX_ITERS - 1):
            trigger = f"{last}_{i}"
            job.reveal_rules[trigger] = [f"{n}_{i+1}" for n, _ in self.PATTERN]


# ---------------------------------------------------------------------------
# 3. Code generation (chain-like — Reflexion on MBPP)
# ---------------------------------------------------------------------------
class CodeGeneration(ChainApp):
    name = "code_gen"
    MAX_ITERS = 5  # pattern of 3 → chain length 3–15+prologue ≈ paper Fig. 1b
    PROLOGUE = [("gen_tests", StageType.LLM)]
    PATTERN = [
        ("code_gen", StageType.LLM),
        ("code_exec", StageType.REGULAR),
        ("reflect", StageType.LLM),
    ]

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        job = make_job(self.template, arrival_time)
        iters = self._chain_iters(rng)
        self.mark_chain(job, iters)
        complexity = float(rng.lognormal(math.log(140), 0.8))  # latent tokens/iter
        noise = lambda: float(rng.lognormal(0.0, 0.15))
        durs: Dict[str, float] = {}
        durs["gen_tests"] = self._set_llm_stage(job, "gen_tests", int(60 * noise()))
        for i in range(self.MAX_ITERS):
            if i < iters:
                # iterations correlated through `complexity` (Fig. 5b r≈0.9)
                durs[f"code_gen_{i}"] = self._set_llm_stage(
                    job, f"code_gen_{i}", int(complexity * noise())
                )
                durs[f"code_exec_{i}"] = self._set_regular_stage(
                    job, f"code_exec_{i}", 0.3 + 0.2 * noise()
                )
                durs[f"reflect_{i}"] = self._set_llm_stage(
                    job, f"reflect_{i}", int(0.5 * complexity * noise())
                )
            else:
                for n, _ in self.PATTERN:
                    durs[f"{n}_{i}"] = 0.0
        return GeneratedJob(job, durs)


# ---------------------------------------------------------------------------
# 4. Web search (chain-like — ReAct on HotpotQA)
# ---------------------------------------------------------------------------
class WebSearch(ChainApp):
    name = "web_search"
    MAX_ITERS = 4
    PROLOGUE: List[Tuple[str, StageType]] = []
    PATTERN = [
        ("think", StageType.LLM),
        ("search", StageType.REGULAR),
    ]
    stop_prob = 0.5

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        job = make_job(self.template, arrival_time)
        iters = self._chain_iters(rng)
        self.mark_chain(job, iters)
        hop = float(rng.lognormal(math.log(45), 0.7))
        noise = lambda: float(rng.lognormal(0.0, 0.2))
        durs: Dict[str, float] = {}
        for i in range(self.MAX_ITERS):
            if i < iters:
                durs[f"think_{i}"] = self._set_llm_stage(job, f"think_{i}", int(hop * noise()))
                durs[f"search_{i}"] = self._set_regular_stage(job, f"search_{i}", 0.5 + 0.5 * noise())
            else:
                durs[f"think_{i}"] = 0.0
                durs[f"search_{i}"] = 0.0
        return GeneratedJob(job, durs)


# ---------------------------------------------------------------------------
# Planning base: LLM plan stage + dynamic stage
# ---------------------------------------------------------------------------
class PlanningApp(AppGenerator):
    CANDIDATES: List[Tuple[str, StageType, float]] = []  # (name, type, select prob)
    CAND_EDGES: List[Tuple[str, str, float]] = []        # (u, v, prob | both chosen)
    MAX_STAGES = 8

    def expand_dynamic(self, job: Job, dyn_name: str) -> List[Stage]:
        """Realize the dynamic stage: create inner stages + dependencies.
        Called by the runtime when the preceding LLM stage finishes."""
        chosen, edges = job.dynamic_realization.get(dyn_name, ((), ()))
        dyn = job.stages[dyn_name]
        created: List[Stage] = []
        parent_names = job.parents_of(dyn_name)
        for c in chosen:
            full = f"{dyn_name}.{c}"
            tpl = StageTemplate(full, self._cand_type(c))
            st = Stage(job_id=job.job_id, template=tpl, revealed=True)
            st.tasks = [
                Task(
                    job_id=job.job_id,
                    stage_name=full,
                    index=0,
                    is_llm=(tpl.stype is StageType.LLM),
                    true_duration=job._dyn_durs[dyn_name][c],  # type: ignore[attr-defined]
                    out_tokens=int(job._dyn_durs[dyn_name][c] / TOKEN_LATENCY_B1),  # type: ignore[attr-defined]
                )
            ]
            job.stages[full] = st
            job.extra_parents[full] = list(parent_names)
            created.append(st)
        for u, v in edges:
            job.extra_parents.setdefault(f"{dyn_name}.{v}", []).append(f"{dyn_name}.{u}")
        # dynamic stage children wait on the inner sinks (stages with no
        # outgoing edge inside the plan)
        sinks = [f"{dyn_name}.{c}" for c in chosen if all(u != c for u, _v in edges)]
        for child in job.app.children(dyn_name):
            job.extra_parents.setdefault(child, []).extend(
                sinks or [f"{dyn_name}.{c}" for c in chosen]
            )
        # the placeholder itself becomes a structural no-op
        dyn.will_execute = False
        dyn.revealed = True
        return created

    def _cand_type(self, cand: str) -> StageType:
        for n, t, _ in self.CANDIDATES:
            if n == cand:
                return t
        return StageType.REGULAR

    def _sample_plan(
        self, rng: np.random.Generator
    ) -> Tuple[Tuple[str, ...], Tuple[Tuple[str, str], ...]]:
        chosen = [n for n, _, p in self.CANDIDATES if rng.random() < p]
        if not chosen:
            chosen = [self.CANDIDATES[int(rng.integers(len(self.CANDIDATES)))][0]]
        chosen = chosen[: self.MAX_STAGES]
        edges = [
            (u, v)
            for u, v, p in self.CAND_EDGES
            if u in chosen and v in chosen and rng.random() < p
        ]
        return tuple(chosen), tuple(edges)


# ---------------------------------------------------------------------------
# 5. Task automation (planning — TaskBench / HuggingGPT)
# ---------------------------------------------------------------------------
class TaskAutomation(PlanningApp):
    name = "task_auto"
    CANDIDATES = [
        ("translate", StageType.REGULAR, 0.55),
        ("img_seg", StageType.REGULAR, 0.45),
        ("obj_detect", StageType.REGULAR, 0.5),
        ("asr", StageType.REGULAR, 0.3),
        ("summarize", StageType.LLM, 0.4),
        ("caption", StageType.LLM, 0.35),
        ("qa", StageType.LLM, 0.3),
        ("tts", StageType.REGULAR, 0.2),
    ]
    CAND_EDGES = [
        ("obj_detect", "caption", 0.6),
        ("img_seg", "obj_detect", 0.5),
        ("asr", "translate", 0.5),
        ("translate", "summarize", 0.5),
        ("caption", "qa", 0.4),
        ("summarize", "tts", 0.5),
    ]

    def build_template(self) -> ApplicationTemplate:
        stages = [
            StageTemplate("plan", StageType.LLM),
            StageTemplate(
                "auto_tools",
                StageType.DYNAMIC,
                candidates=tuple(n for n, _, _ in self.CANDIDATES),
                candidate_edges=tuple((u, v) for u, v, _ in self.CAND_EDGES),
            ),
            StageTemplate("respond", StageType.LLM),
        ]
        edges = [("plan", "auto_tools"), ("auto_tools", "respond")]
        return ApplicationTemplate(self.name, stages, edges)

    TOOL_DUR = {
        "translate": (1.2, 0.4), "img_seg": (2.0, 0.6), "obj_detect": (1.5, 0.5),
        "asr": (2.5, 0.8), "summarize": (150, 0.8), "caption": (80, 0.7),
        "qa": (120, 0.8), "tts": (1.8, 0.5),
    }

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        job = make_job(self.template, arrival_time)
        chosen, edges = self._sample_plan(rng)
        job.dynamic_realization["auto_tools"] = (chosen, edges)
        noise = lambda s: float(rng.lognormal(0.0, s))
        durs: Dict[str, float] = {}
        durs["plan"] = self._set_llm_stage(job, "plan", int(40 * (1 + 0.3 * len(chosen)) * noise(0.2)))
        dyn_durs: Dict[str, float] = {}
        total_inner = 0.0
        for c in chosen:
            mu, sig = self.TOOL_DUR[c]
            if self._cand_type(c) is StageType.LLM:
                d = mu * TOKEN_LATENCY_B1 * 10 * noise(sig)  # token-count based
            else:
                d = mu * noise(sig)
            dyn_durs[c] = d
            durs[f"auto_tools.{c}"] = d
            total_inner += d
        job._dyn_durs = {"auto_tools": dyn_durs}  # type: ignore[attr-defined]
        durs["auto_tools"] = total_inner  # BN variable: total inner duration
        durs["respond"] = self._set_llm_stage(job, "respond", int(50 * noise(0.3)))
        job.stages["plan"].revealed = True
        job.stages["respond"].revealed = True
        # dynamic stage: existence known, contents not; carries no tasks itself
        job.stages["auto_tools"].tasks = []
        job.stages["auto_tools"].revealed = False
        return GeneratedJob(job, durs)


# ---------------------------------------------------------------------------
# 6. LLMCompiler (planning — parallel function calling on HotpotQA)
# ---------------------------------------------------------------------------
class LLMCompiler(PlanningApp):
    name = "llm_compiler"
    CANDIDATES = [
        (f"call_{i}", StageType.REGULAR, p)
        for i, p in enumerate([0.9, 0.8, 0.6, 0.5, 0.4, 0.3, 0.2, 0.15])
    ]
    CAND_EDGES: List[Tuple[str, str, float]] = []  # high stage parallelism

    def build_template(self) -> ApplicationTemplate:
        stages = [
            StageTemplate("plan", StageType.LLM),
            StageTemplate(
                "calls",
                StageType.DYNAMIC,
                candidates=tuple(n for n, _, _ in self.CANDIDATES),
                candidate_edges=(),
            ),
            StageTemplate("join", StageType.LLM),
        ]
        return ApplicationTemplate(self.name, stages, [("plan", "calls"), ("calls", "join")])

    def sample(self, rng: np.random.Generator, arrival_time: float) -> GeneratedJob:
        job = make_job(self.template, arrival_time)
        chosen, edges = self._sample_plan(rng)
        job.dynamic_realization["calls"] = (chosen, edges)
        noise = lambda s: float(rng.lognormal(0.0, s))
        durs: Dict[str, float] = {}
        durs["plan"] = self._set_llm_stage(job, "plan", int(60 * noise(0.3)))
        dyn_durs: Dict[str, float] = {}
        total = 0.0
        for c in chosen:
            d = 0.8 * noise(0.5)
            dyn_durs[c] = d
            durs[f"calls.{c}"] = d
            total += d
        job._dyn_durs = {"calls": dyn_durs}  # type: ignore[attr-defined]
        durs["calls"] = total
        durs["join"] = self._set_llm_stage(job, "join", int(90 * noise(0.4)))
        job.stages["plan"].revealed = True
        job.stages["join"].revealed = True
        job.stages["calls"].tasks = []
        job.stages["calls"].revealed = False
        return GeneratedJob(job, durs)


# ---------------------------------------------------------------------------
# Stage-completion bookkeeping shared by every runtime
# ---------------------------------------------------------------------------
def reveal_after_stage(
    job: Job, stage: Stage, gens: Dict[str, AppGenerator]
) -> None:
    """Apply the observable consequences of ``stage`` finishing.

    Used by the discrete-event simulator, the serving testbed, and the
    scheduling benchmarks so all runtimes emit identical evidence events:
    chain reveals, dynamic-stage expansion, and the ``evidence_version``
    bump that invalidates incremental-scheduler caches for this job.
    """
    stage.revealed = True
    # chain reveals
    for name in job.reveal_rules.get(stage.name, []):
        job.stages[name].revealed = True
    # dynamic expansion: when the parent LLM stage finishes
    gen = gens.get(job.app.name)
    for child in job.app.children(stage.name):
        cst = job.stages.get(child)
        if (
            cst is not None
            and cst.stype is StageType.DYNAMIC
            and not cst.revealed
            and isinstance(gen, PlanningApp)
        ):
            gen.expand_dynamic(job, child)
    job.bump_evidence()


# ---------------------------------------------------------------------------
# Workload mixes (paper §V "Workload generation")
# ---------------------------------------------------------------------------
ALL_GENERATORS: Dict[str, AppGenerator] = {}


def get_generators() -> Dict[str, AppGenerator]:
    global ALL_GENERATORS
    if not ALL_GENERATORS:
        ALL_GENERATORS = {
            g.name: g
            for g in [
                SequenceSorting(), DocMerging(), CodeGeneration(),
                WebSearch(), TaskAutomation(), LLMCompiler(),
            ]
        }
    return ALL_GENERATORS


WORKLOAD_MIXES: Dict[str, Dict[str, float]] = {
    "mixed": {n: 1 / 6 for n in
              ["seq_sort", "doc_merge", "code_gen", "web_search",
               "task_auto", "llm_compiler"]},
    "predefined": {"seq_sort": 0.5, "doc_merge": 0.5},
    "chain": {"code_gen": 0.5, "web_search": 0.5},
    "planning": {"task_auto": 0.5, "llm_compiler": 0.5},
}


def generate_workload(
    mix: str,
    n_jobs: int,
    arrival_rate: float = 0.9,
    seed: int = 0,
) -> List[GeneratedJob]:
    """Poisson arrivals at rate λ, apps drawn from the mix distribution."""
    gens = get_generators()
    probs = WORKLOAD_MIXES[mix]
    rng = np.random.default_rng(seed)
    names = list(probs)
    p = np.array([probs[n] for n in names])
    p /= p.sum()
    t = 0.0
    out: List[GeneratedJob] = []
    for _ in range(n_jobs):
        t += float(rng.exponential(1.0 / arrival_rate))
        g = gens[str(rng.choice(names, p=p))]
        out.append(g.sample(rng, arrival_time=t))
    return out


# ---------------------------------------------------------------------------
# SLO-tiered workloads (deadline scheduling; ISSUE 6)
# ---------------------------------------------------------------------------
# Default tier mix: mostly latency-sensitive traffic with a batch tail,
# mirroring production serving splits (SLO-aware LLM scheduling papers).
SLO_TIER_PROBS: Dict[str, float] = {
    "interactive": 0.4,
    "batch": 0.4,
    "best_effort": 0.2,
}

# Deadline = arrival + slack_factor × ground-truth duration / tightness.
# Interactive jobs get the least headroom; best-effort deadlines are so
# loose they only miss under heavy queueing.
SLO_SLACK_FACTORS: Dict[str, float] = {
    "interactive": 2.5,
    "batch": 5.0,
    "best_effort": 12.0,
}


def _ground_truth_duration(gj: GeneratedJob) -> float:
    """Total batch-1 work of a generated job (serial execution bound).

    Sums top-level stage durations only (dotted keys are dynamic-stage
    inner durations already counted in the placeholder's total), giving
    a deterministic per-job scale for deadline assignment.
    """
    return sum(v for k, v in gj.durations.items() if "." not in k)


def assign_slos(
    jobs: Sequence[GeneratedJob],
    tier_probs: Optional[Dict[str, float]] = None,
    slack_factors: Optional[Dict[str, float]] = None,
    tightness: float = 1.0,
    seed: int = 0,
) -> List[GeneratedJob]:
    """Attach an :class:`~repro.core.dag.SLO` to each generated job.

    Tiers are drawn i.i.d. from ``tier_probs`` and deadlines are set to
    ``arrival + slack_factor[tier] * work / tightness`` where ``work`` is
    the job's ground-truth serial duration.  ``tightness`` > 1 shrinks
    every deadline proportionally, which is the knob the monotonicity
    property test sweeps.  Mutates ``jobs`` in place and returns them.

    Parameters
    ----------
    jobs : sequence of GeneratedJob
        Output of :func:`generate_workload` (or compatible).
    tier_probs : dict, optional
        ``tier → probability``; defaults to :data:`SLO_TIER_PROBS`.
    slack_factors : dict, optional
        ``tier → slack multiplier``; defaults to
        :data:`SLO_SLACK_FACTORS`.
    tightness : float
        Global deadline-tightening factor (1.0 = defaults).
    seed : int
        RNG seed for the tier draw (independent of workload sampling).
    """
    probs = dict(SLO_TIER_PROBS if tier_probs is None else tier_probs)
    slack = dict(SLO_SLACK_FACTORS if slack_factors is None else slack_factors)
    rng = np.random.default_rng(seed)
    names = list(probs)
    p = np.array([probs[n] for n in names], dtype=float)
    p /= p.sum()
    for gj in jobs:
        tier = str(rng.choice(names, p=p))
        work = _ground_truth_duration(gj)
        deadline = gj.job.arrival_time + slack[tier] * work / max(tightness, 1e-9)
        gj.job.slo = SLO(tier=tier, deadline=deadline)
    return list(jobs)


def generate_tiered_workload(
    mix: str,
    n_jobs: int,
    arrival_rate: float = 0.9,
    seed: int = 0,
    tier_probs: Optional[Dict[str, float]] = None,
    slack_factors: Optional[Dict[str, float]] = None,
    tightness: float = 1.0,
) -> List[GeneratedJob]:
    """Poisson-arrival workload where every job carries a tiered SLO.

    Identical job stream to :func:`generate_workload` with the same
    ``(mix, n_jobs, arrival_rate, seed)`` — SLOs are assigned by a
    *separate* RNG stream (``seed + 1``) so adding deadlines never
    perturbs job structure, which the golden-trajectory degeneracy test
    relies on.
    """
    jobs = generate_workload(mix, n_jobs, arrival_rate=arrival_rate, seed=seed)
    return assign_slos(
        jobs,
        tier_probs=tier_probs,
        slack_factors=slack_factors,
        tightness=tightness,
        seed=seed + 1,
    )


def generate_traces(mix: str, n_jobs: int, seed: int = 1234) -> List[JobTrace]:
    """Offline history for BN training (paper: recorded runtime durations)."""
    gens = get_generators()
    out: List[JobTrace] = []
    for gj in generate_workload(mix, n_jobs, arrival_rate=1.0, seed=seed):
        g = gens[gj.job.app.name]
        out.append(g.trace_of(gj))
    return out


# ---------------------------------------------------------------------------
# Heterogeneous replica-pool presets (cascade benchmark currency)
# ---------------------------------------------------------------------------
#: Named per-replica model-tier pools shared by the fig10 cascade
#: benchmark and the sim tests, so "the 3-replica cheap/mid/top fleet"
#: means the same thing everywhere.  Keys of each entry are model-zoo
#: names accepted by :func:`repro.models.zoo.resolve_tier`.
TIER_POOLS: Dict[str, Tuple[str, ...]] = {
    # one replica per rung of a cheap → capable ladder
    "ladder3": ("stablelm_1_6b", "internlm2_20b", "kimi_k2_1t_a32b"),
    # single-tier control pools of the ladder's extremes
    "cheap3": ("stablelm_1_6b",) * 3,
    "large3": ("kimi_k2_1t_a32b",) * 3,
}


def tier_pool(name: str, n_llm: Optional[int] = None) -> Tuple[str, ...]:
    """Return a named replica pool, optionally resized.

    Parameters
    ----------
    name : str
        A :data:`TIER_POOLS` key.
    n_llm : int, optional
        Desired replica count; the pool is cycled to length (so
        ``ladder3`` at 6 replicas repeats the ladder twice).  ``None``
        keeps the preset size.

    Returns
    -------
    tuple of str
        Per-replica model names for ``ClusterSim(model_tiers=...)`` or
        ``ServeConfig(models=...)``.
    """
    pool = TIER_POOLS[name]
    if n_llm is None:
        return pool
    return tuple(pool[i % len(pool)] for i in range(int(n_llm)))
