"""Cluster simulator + compound-LLM workload generators (paper §V)."""

from .simulator import ClusterSim, SimResult, default_latency_profile, simulate
from .workloads import (
    ALL_GENERATORS,
    WORKLOAD_MIXES,
    AppGenerator,
    CodeGeneration,
    DocMerging,
    GeneratedJob,
    LLMCompiler,
    SequenceSorting,
    TaskAutomation,
    WebSearch,
    TIER_POOLS,
    generate_traces,
    generate_workload,
    get_generators,
    tier_pool,
)

__all__ = [
    "ClusterSim", "SimResult", "default_latency_profile", "simulate",
    "ALL_GENERATORS", "WORKLOAD_MIXES", "AppGenerator", "CodeGeneration",
    "DocMerging", "GeneratedJob", "LLMCompiler", "SequenceSorting",
    "TaskAutomation", "WebSearch", "TIER_POOLS", "generate_traces",
    "generate_workload", "get_generators", "tier_pool",
]
