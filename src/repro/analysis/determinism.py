"""Determinism lint: AST passes flagging nondeterminism sources.

The sim/scheduler/serving paths must be seed-reproducible — every golden
trajectory hash and differential token-equality test assumes it.  These
rules flag the constructs that historically break that assumption:

- ``wall-clock`` — ``time.time()`` / ``datetime.now()`` and friends.
  ``time.time()`` is non-monotonic (NTP slews it backwards), so even
  *duration* measurements must use ``time.perf_counter()``; wall-clock
  timestamps that genuinely need calendar time carry a suppression.
- ``unordered-set`` — iterating a freshly-built ``set`` (literal,
  ``set(...)``/``frozenset(...)`` call, or set comprehension) in an
  order-sensitive position (``for``, comprehension, ``list``/``tuple``/
  ``enumerate``/``iter``).  Set iteration order depends on insertion
  history and hash seeding; wrap in ``sorted(...)`` to fix the order.
- ``mutable-default`` — mutable default argument values (``[]``,
  ``{}``, ``set()``, …) shared across calls: state leaks between
  invocations and, with it, run-order dependence.

Only syntactically-evident cases are flagged (no type inference): the
lint is meant to stay zero-noise so the repo can be kept suppress-free.
"""

from __future__ import annotations

import ast
from typing import List

from .framework import Checker, Finding, Source, register


def _attr_chain(node: ast.AST) -> List[str]:
    """Flatten ``a.b.c`` into ``["a", "b", "c"]`` (empty if not a chain)."""
    out: List[str] = []
    while isinstance(node, ast.Attribute):
        out.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        out.append(node.id)
        return list(reversed(out))
    return []


@register
class WallClockChecker(Checker):
    """Flag non-monotonic wall-clock reads."""

    rules = {
        "wall-clock": (
            "time.time()/datetime.now() is non-monotonic and "
            "run-dependent; use time.perf_counter() for durations"
        ),
    }

    #: ``datetime``-style constructors that read the wall clock
    _DT_ATTRS = {"now", "utcnow", "today"}

    def check(self, src: Source) -> List[Finding]:
        """Return one finding per wall-clock call in ``src``."""
        out: List[Finding] = []
        # names bound by `from time import time` count as bare calls
        bare_time = False
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                bare_time |= any(a.name == "time" for a in node.names)
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            if isinstance(node.func, ast.Name):
                if bare_time and node.func.id == "time":
                    out.append(self.finding(
                        src, node, "wall-clock",
                        "time() (from time import time) is non-monotonic; "
                        "use time.perf_counter()",
                    ))
                continue
            chain = _attr_chain(node.func)
            if not chain:
                continue
            if len(chain) >= 2 and chain[-1] == "time" and chain[-2] == "time":
                out.append(self.finding(
                    src, node, "wall-clock",
                    "time.time() is non-monotonic; use time.perf_counter() "
                    "for durations (suppress if calendar time is required)",
                ))
            elif chain[-1] in self._DT_ATTRS and any(
                c in ("datetime", "date") for c in chain[:-1]
            ):
                out.append(self.finding(
                    src, node, "wall-clock",
                    f"datetime wall-clock read .{chain[-1]}() makes runs "
                    "time-dependent; thread an explicit timestamp instead",
                ))
        return out


def _is_set_expr(node: ast.AST) -> bool:
    """True for expressions that are syntactically guaranteed sets."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False


@register
class UnorderedSetChecker(Checker):
    """Flag iteration over freshly-built sets in ordering positions."""

    rules = {
        "unordered-set": (
            "iterating a set feeds hash-order into downstream decisions; "
            "wrap in sorted(...) with an explicit key"
        ),
    }

    #: calls whose output order mirrors the iterable's order
    _ORDER_SINKS = {"list", "tuple", "enumerate", "iter"}

    def check(self, src: Source) -> List[Finding]:
        """Return one finding per order-sensitive set iteration."""
        out: List[Finding] = []
        msg = (
            "set iteration order is nondeterministic across runs; "
            "use sorted(...) before iterating"
        )
        for node in ast.walk(src.tree):
            if isinstance(node, ast.For) and _is_set_expr(node.iter):
                out.append(self.finding(src, node.iter, "unordered-set", msg))
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
                for comp in node.generators:
                    if _is_set_expr(comp.iter):
                        out.append(self.finding(
                            src, comp.iter, "unordered-set", msg
                        ))
            elif (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in self._ORDER_SINKS
                and node.args
                and _is_set_expr(node.args[0])
            ):
                out.append(self.finding(
                    src, node, "unordered-set",
                    f"{node.func.id}(set(...)) materializes hash order; "
                    "use sorted(...) instead",
                ))
        return out


@register
class MutableDefaultChecker(Checker):
    """Flag mutable default argument values."""

    rules = {
        "mutable-default": (
            "mutable default argument is shared across calls; "
            "default to None and construct inside the function"
        ),
    }

    _MUTABLE_CTORS = {"list", "dict", "set", "bytearray", "deque"}

    def _is_mutable(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            return node.func.id in self._MUTABLE_CTORS
        return False

    def check(self, src: Source) -> List[Finding]:
        """Return one finding per mutable default in any function def."""
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None
            ]
            for d in defaults:
                if self._is_mutable(d):
                    out.append(self.finding(
                        src, d, "mutable-default",
                        f"mutable default in {node.name}(...) is shared "
                        "across calls; use None and build per-call",
                    ))
        return out
