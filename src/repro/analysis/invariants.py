"""Declarative Decision-level invariants for :class:`LLMSched`.

The SLO plan-ahead/retraction machinery (PR 6) is correct only while a
few properties hold on *every* decision — properties the golden-hash
suites witness indirectly (a violation eventually drifts the stream)
but cannot name.  This module states them declaratively and
``LLMSched(check_invariants=True)`` evaluates all of them at the end of
each :meth:`~repro.core.scheduler.LLMSched.schedule` call:

- ``no-running-retraction`` — preference lists contain only ``PENDING``
  tasks: a retraction may reorder queued work but must never touch a
  task that already started (token-equality and migration both assume
  dispatched work is immutable);
- ``demoted-unplaced`` — jobs demoted as provably deadline-infeasible
  receive no placement entry (no KV reservation): demotion exists to
  *stop* spending pages on lost causes;
- ``placement-bounds`` — every placement hint names a real replica, and
  one round never over-commits a replica beyond its free batch slots;
- ``plan-pinned`` — each SLO job's cached :class:`_SloPlan` snapshot
  matches the job's **current** ``evidence_version`` and the current
  calibration signature: a decision built from a stale plan is exactly
  the bug retraction exists to prevent;
- ``edf-urgent-order`` — the urgent bucket emitted by ``_slo_order`` is
  sorted by its ``(tier, pessimistic-slack, deadline, arrival)`` key —
  deadline-carrying urgent jobs drain earliest-deadline-first.

Each invariant is a pure predicate over ``(scheduler, jobs, view,
decision)``; violations aggregate into one :class:`InvariantViolation`
so a single bad round reports every broken property at once.

Checking is observation-only: enabling it never alters the decision
stream (asserted by golden-equality tests in ``tests/test_analysis.py``).
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

from ..core.dag import TaskState


class InvariantViolation(AssertionError):
    """A scheduler decision broke one or more declared invariants."""


def _iter_tasks(decision):
    for t in decision.regular:
        yield t
    for t in decision.llm:
        yield t


def _no_running_retraction(sched, jobs, view, decision) -> List[str]:
    """Preference lists must only ever contain pending tasks."""
    out = []
    for t in _iter_tasks(decision):
        if t.state is not TaskState.PENDING:
            out.append(
                f"task ({t.job_id}, {t.stage_name!r}, {t.index}) is "
                f"{t.state.name} yet appears in the preference lists — "
                "running/finished work must never be (re)scheduled"
            )
    return out


def _demoted_unplaced(sched, jobs, view, decision) -> List[str]:
    """Provably-infeasible jobs must hold no placement (KV) reservation."""
    demoted = getattr(sched, "_demoted", set())
    if not demoted:
        return []
    out = []
    for key, replica in decision.placement.items():
        if key[0] in demoted:
            out.append(
                f"job {key[0]} is demoted (provably deadline-infeasible) "
                f"but task {key} was placed on replica {replica} — demoted "
                "jobs must reserve no KV headroom"
            )
    return out


def _placement_bounds(sched, jobs, view, decision) -> List[str]:
    """Placement hints must name real replicas and respect free slots."""
    out = []
    n = len(view.llm_loads)
    counts = [0] * n
    for key, replica in decision.placement.items():
        if not (0 <= replica < n):
            out.append(
                f"task {key} placed on replica {replica}, but the view "
                f"has only {n} replicas"
            )
            continue
        counts[replica] += 1
    for e, c in enumerate(counts):
        b, mb = view.llm_loads[e]
        free = max(0, mb - b)
        if c > free:
            out.append(
                f"replica {e} received {c} placements but has only "
                f"{free} free batch slots (batch {b}/{mb}) — one round "
                "must not overcommit a replica"
            )
    return out


def _plan_pinned(sched, jobs, view, decision) -> List[str]:
    """Cached SLO plans must match current evidence + calibration."""
    plans = getattr(sched, "_slo_plans", None)
    if not plans or not sched.slo_aware:
        return []
    if not any(j.slo is not None for j in jobs):
        return []  # _slo_order did not run: plans are legitimately idle
    sig = sched._calib_sig(view)
    out = []
    for job in jobs:
        if job.slo is None:
            continue
        plan = plans.get(job.job_id)
        if plan is None:
            continue
        if plan.version != job.evidence_version:
            out.append(
                f"job {job.job_id}'s plan snapshot is pinned to evidence "
                f"version {plan.version} but the job is at "
                f"{job.evidence_version} — the stale plan must be "
                "retracted before deciding"
            )
        elif plan.calib != sig:
            out.append(
                f"job {job.job_id}'s plan snapshot was calibrated under "
                f"{plan.calib} but the view implies {sig} — the plan must "
                "be rebuilt against the current l(b) model"
            )
    return out


def _edf_urgent_order(sched, jobs, view, decision) -> List[str]:
    """The urgent bucket must be sorted by its EDF key."""
    keys = getattr(sched, "_last_urgent_keys", None)
    if not keys:
        return []
    for a, b in zip(keys, keys[1:]):
        if a > b:
            return [
                f"urgent bucket is not in EDF order: key {a} precedes "
                f"{b} — tight-deadline jobs must drain "
                "(tier, slack, deadline, arrival)-first"
            ]
    return []


#: The declarative catalog: (name, predicate) pairs, all evaluated on
#: every decision when ``LLMSched(check_invariants=True)``.
INVARIANTS: List[Tuple[str, Callable]] = [
    ("no-running-retraction", _no_running_retraction),
    ("demoted-unplaced", _demoted_unplaced),
    ("placement-bounds", _placement_bounds),
    ("plan-pinned", _plan_pinned),
    ("edf-urgent-order", _edf_urgent_order),
]


def check_decision(sched, jobs: Sequence, view, decision) -> None:
    """Evaluate every declared invariant against one decision.

    Parameters
    ----------
    sched : LLMSched
        The scheduler that produced the decision (its ``_demoted`` /
        ``_slo_plans`` / ``_last_urgent_keys`` state is inspected).
    jobs : sequence of Job
        The unfinished jobs passed to ``schedule`` (pre-filtering).
    view : ClusterView
        The cluster view the decision was made against.
    decision : Decision
        The decision to validate.

    Raises
    ------
    InvariantViolation
        Listing every violated invariant with an actionable message.
    """
    violations: List[str] = []
    for name, pred in INVARIANTS:
        for msg in pred(sched, jobs, view, decision):
            violations.append(f"[{name}] {msg}")
    if violations:
        raise InvariantViolation(
            "scheduler invariant violation(s) at t="
            f"{view.now:.6f}:\n  " + "\n  ".join(violations)
        )
