"""Pluggable static-analysis framework (stdlib-only; runs offline).

The repository's correctness story rests on *determinism*: golden-hash
tests pin seeded scheduler trajectories, and the differential serving
suites prove token-for-token equality across engine features.  Those
tests detect drift but cannot localize it — this framework hosts AST
passes that flag the drift *sources* (wall clocks, unseeded RNGs,
unordered set iteration, mutable default arguments) before they ever
reach a golden hash.

Design:

- a :class:`Checker` declares the rule ids it can emit and implements
  ``check(src)`` over a parsed :class:`Source`;
- checkers self-register via :func:`register`, so adding a pass is one
  decorated class (see ``determinism.py`` / ``seeds.py``);
- findings are suppressed per line with ``# analysis: ignore[rule]``
  (or a bare ``# analysis: ignore`` for every rule on that line) — the
  suppression lives next to the code it excuses, greppable and
  reviewable;
- :func:`run_paths` walks files/directories and returns unsuppressed
  :class:`Finding` objects; ``tools/run_analysis.py`` is the CLI.

Everything here is importable without numpy/jax so the CI analysis job
needs no dependency install.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Type


@dataclass(frozen=True)
class Finding:
    """One rule violation at a specific source location.

    Attributes
    ----------
    path : str
        File the violation was found in.
    line : int
        1-based line number (the AST node's ``lineno``).
    rule : str
        Rule identifier (kebab-case; see ``--list-rules``).
    message : str
        Human-readable description with enough context to act on.
    """

    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


_SUPPRESS_RE = re.compile(
    r"#\s*analysis:\s*ignore(?:\[([A-Za-z0-9_,\- ]*)\])?"
)


class Source:
    """A parsed Python file plus its per-line suppression table."""

    def __init__(self, path: str, text: str) -> None:
        self.path = str(path)
        self.text = text
        self.tree = ast.parse(text, filename=self.path)
        # line -> set of suppressed rule ids ("*" suppresses every rule)
        self.suppressions: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = m.group(1)
            if rules is None or not rules.strip():
                self.suppressions[lineno] = {"*"}
            else:
                self.suppressions[lineno] = {
                    r.strip() for r in rules.split(",") if r.strip()
                }

    def suppressed(self, line: int, rule: str) -> bool:
        """Check whether ``rule`` is suppressed on ``line``."""
        sup = self.suppressions.get(line)
        return sup is not None and ("*" in sup or rule in sup)


class Checker:
    """Base class of one analysis pass.

    Subclasses set :attr:`rules` (``{rule_id: one-line description}``)
    and implement :meth:`check`.  Register with :func:`register` so the
    driver picks the pass up automatically.
    """

    #: rule id -> one-line description (the ``--list-rules`` catalog)
    rules: Dict[str, str] = {}

    def check(self, src: Source) -> List[Finding]:
        """Return every (pre-suppression) finding in ``src``."""
        raise NotImplementedError

    def finding(self, src: Source, node: ast.AST, rule: str, msg: str) -> Finding:
        """Build a :class:`Finding` anchored at ``node``."""
        assert rule in self.rules, f"checker emits undeclared rule {rule!r}"
        return Finding(src.path, getattr(node, "lineno", 0), rule, msg)


_REGISTRY: List[Type[Checker]] = []


def register(cls: Type[Checker]) -> Type[Checker]:
    """Class decorator adding a checker to the global registry."""
    _REGISTRY.append(cls)
    return cls


def all_checkers(rules: Optional[Set[str]] = None) -> List[Checker]:
    """Instantiate registered checkers, optionally restricted to rules.

    Parameters
    ----------
    rules : set of str, optional
        When given, only checkers emitting at least one of these rule
        ids are instantiated (rule-level filtering of their findings
        happens in :func:`run_paths`).

    Returns
    -------
    list of Checker
        One instance per selected registered class.
    """
    out = []
    for cls in _REGISTRY:
        if rules is None or rules & set(cls.rules):
            out.append(cls())
    return out


def rule_catalog() -> Dict[str, str]:
    """Return ``{rule_id: description}`` over every registered checker."""
    cat: Dict[str, str] = {}
    for cls in _REGISTRY:
        cat.update(cls.rules)
    return cat


def iter_py_files(paths: Sequence[str]) -> Iterator[Path]:
    """Yield ``.py`` files under the given files/directories, sorted."""
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def check_source(
    src: Source,
    checkers: Iterable[Checker],
    rules: Optional[Set[str]] = None,
) -> List[Finding]:
    """Run checkers over one parsed source, applying suppressions."""
    out: List[Finding] = []
    for checker in checkers:
        for f in checker.check(src):
            if rules is not None and f.rule not in rules:
                continue
            if not src.suppressed(f.line, f.rule):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def run_paths(
    paths: Sequence[str],
    rules: Optional[Set[str]] = None,
) -> List[Finding]:
    """Analyze every Python file under ``paths``.

    Parameters
    ----------
    paths : sequence of str
        Files and/or directories.
    rules : set of str, optional
        Restrict to these rule ids (default: every registered rule).

    Returns
    -------
    list of Finding
        Unsuppressed findings, sorted by (path, line, rule).
    """
    checkers = all_checkers(rules)
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        text = path.read_text()
        try:
            src = Source(str(path), text)
        except SyntaxError as e:  # report instead of crashing the sweep
            findings.append(
                Finding(str(path), e.lineno or 0, "parse-error", str(e.msg))
            )
            continue
        findings.extend(check_source(src, checkers, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
