"""Seed-discipline checker (absorbs the old ``tools/check_seeds.py``).

Every benchmark, test, and example must thread an **explicit seed** into
each randomness source it touches, so artifacts are reproducible and two
modes of one comparison (cache off/on, migration off/on) see the same
trace.  Three rules:

- ``seed-missing`` — a workload/trace generator, ``simulate``, or
  quality-gate (``DeterministicGate``) call without a ``seed=``
  keyword (or the corresponding positional);
- ``unseeded-rng`` — ``numpy.random.default_rng()`` /
  ``jax.random.key()`` / ``PRNGKey()`` called with no argument (an
  OS-seeded RNG makes the run unreproducible);
- ``global-rng`` — module-level global-RNG use (``np.random.<dist>()``
  or stdlib ``random.<dist>()``): the global RNG's state is shared and
  unseedable per call site — use ``default_rng(seed)``.

This is the same rule set the standalone script enforced, now emitted
through :mod:`repro.analysis.framework` so findings share the
suppression syntax and the single CI driver.
"""

from __future__ import annotations

import ast
from typing import List

from .determinism import _attr_chain
from .framework import Checker, Finding, Source, register


@register
class SeedDisciplineChecker(Checker):
    """Flag randomness sources that do not carry an explicit seed."""

    rules = {
        "seed-missing": (
            "workload/trace generator called without an explicit seed"
        ),
        "unseeded-rng": (
            "RNG constructor called without a seed argument"
        ),
        "global-rng": (
            "module-level global RNG use; construct default_rng(seed)"
        ),
    }

    #: calls that must carry an explicit seed argument
    SEED_KW_FUNCS = {
        "generate_workload", "generate_traces", "simulate",
        "generate_tiered_workload", "assign_slos", "DeterministicGate",
    }
    #: positional index at which the generators accept seed
    SEED_POS = {
        "generate_workload": 3,
        "generate_traces": 2,
        "generate_tiered_workload": 3,
        "assign_slos": 4,
        "DeterministicGate": 1,
    }
    #: calls that must receive at least one (seed) argument
    NONEMPTY_FUNCS = {"default_rng", "key", "PRNGKey"}
    #: module-level global-RNG attributes banned outright
    BANNED_NP_RANDOM = {
        "rand", "randn", "randint", "random", "choice", "shuffle",
        "permutation", "uniform", "normal", "exponential", "poisson",
    }
    #: stdlib ``random.<attr>()`` module-level calls banned outright
    BANNED_STD_RANDOM = {
        "random", "randint", "randrange", "choice", "choices", "shuffle",
        "sample", "uniform", "normalvariate", "gauss", "expovariate",
    }

    @staticmethod
    def _call_name(node: ast.Call) -> str:
        fn = node.func
        if isinstance(fn, ast.Name):
            return fn.id
        if isinstance(fn, ast.Attribute):
            return fn.attr
        return ""

    def check(self, src: Source) -> List[Finding]:
        """Return every seed-discipline violation in ``src``."""
        out: List[Finding] = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            name = self._call_name(node)
            kwargs = {kw.arg for kw in node.keywords}
            if name in self.SEED_KW_FUNCS:
                has_kw = "seed" in kwargs or None in kwargs  # None: **kw splat
                has_pos = len(node.args) > self.SEED_POS.get(name, 99)
                if not (has_kw or has_pos):
                    out.append(self.finding(
                        src, node, "seed-missing",
                        f"{name}(...) without an explicit seed",
                    ))
            elif name in self.NONEMPTY_FUNCS:
                chain = _attr_chain(node.func)
                # attribute calls must come off a `random` module; bare
                # names (``from numpy.random import default_rng``) count
                # too when the name is unambiguous (`key` alone is not)
                if isinstance(node.func, ast.Attribute):
                    relevant = "random" in chain
                else:
                    relevant = name in ("default_rng", "PRNGKey")
                if relevant and not node.args and not node.keywords:
                    out.append(self.finding(
                        src, node, "unseeded-rng",
                        f"{'.'.join(chain) or name}() without a seed",
                    ))
            elif isinstance(node.func, ast.Attribute):
                chain = _attr_chain(node.func)
                if (
                    len(chain) >= 3
                    and chain[0] in ("np", "numpy")
                    and chain[1] == "random"
                    and chain[2] in self.BANNED_NP_RANDOM
                ):
                    out.append(self.finding(
                        src, node, "global-rng",
                        f"global RNG {'.'.join(chain)}() — use "
                        "default_rng(seed) instead",
                    ))
                elif (
                    len(chain) == 2
                    and chain[0] == "random"
                    and chain[1] in self.BANNED_STD_RANDOM
                ):
                    out.append(self.finding(
                        src, node, "global-rng",
                        f"global RNG random.{chain[1]}() — use "
                        "random.Random(seed) instead",
                    ))
        return out
