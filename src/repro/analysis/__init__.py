"""Static analysis + runtime sanitizers for the reproduction.

Three layers, one package:

- **determinism lint** (:mod:`.framework`, :mod:`.determinism`,
  :mod:`.seeds`) — stdlib-only AST passes run by
  ``tools/run_analysis.py`` and the CI ``analysis`` job;
- **kvsan** (:mod:`.kvsan`) — the KV-page shadow-state sanitizer behind
  ``PageAllocator(sanitize=True)`` / ``REPRO_SANITIZE=1``;
- **scheduler invariants** (:mod:`.invariants`) — Decision-level checks
  behind ``LLMSched(check_invariants=True)``.

The lint layer imports eagerly (it must work without numpy/jax, e.g. in
the dependency-free CI analysis job).  The runtime layers are exposed
lazily so ``import repro.analysis`` never drags in the serving or
scheduler stacks.
"""

from .framework import (  # noqa: F401
    Checker,
    Finding,
    Source,
    all_checkers,
    check_source,
    iter_py_files,
    register,
    rule_catalog,
    run_paths,
)
from . import determinism as _determinism  # noqa: F401  (registers checkers)
from . import perf as _perf  # noqa: F401  (registers checkers)
from . import seeds as _seeds  # noqa: F401  (registers checkers)

_LAZY = {
    "KVSanError": "kvsan",
    "KVSanitizer": "kvsan",
    "InvariantViolation": "invariants",
    "check_decision": "invariants",
    "INVARIANTS": "invariants",
}


def __getattr__(name):
    """Resolve runtime-layer symbols on first access (PEP 562)."""
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)


__all__ = [
    "Checker",
    "Finding",
    "Source",
    "all_checkers",
    "check_source",
    "iter_py_files",
    "register",
    "rule_catalog",
    "run_paths",
    "KVSanError",
    "KVSanitizer",
    "InvariantViolation",
    "check_decision",
    "INVARIANTS",
]
