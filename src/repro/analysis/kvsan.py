"""KV-page sanitizer ("kvsan"): shadow-state tracking for the paged KV pool.

Llumnix-style lossless migration, copy-on-write prefix sharing, and
preemption-by-eviction all rest on the same handful of page-ownership
invariants.  The :class:`~repro.serving.paged_cache.PageAllocator`
enforces the alloc/free balance itself, but it cannot see *writes* —
the engine scatters KV into pages it believes it owns exclusively, and
a missing copy-on-write or a stale block table corrupts a co-owner's
(or the prefix index's) KV silently: the bug surfaces rounds later as a
wrong token, far from its cause.

``PageAllocator(sanitize=True)`` attaches a :class:`KVSanitizer` that
mirrors every allocator transition in an independent shadow state and
additionally receives engine-side events (block-table registration,
per-page write notifications, migration-ticket refcounts).  It raises
:class:`KVSanError` — with a journal of the most recent page operations
for context — on:

- **use-after-free** — a write to a page with no live owner;
- **double free / refcount underflow** — validated *before* any state
  (shadow or allocator) mutates;
- **CoW bypass** — a write to a shared (refcount > 1) or
  index-registered page without copy-on-write;
- **block-table aliasing** — an exclusively-owned page appearing in two
  rows' block tables;
- **ticket drift** — a migration ticket whose recorded
  ``page_refcounts`` disagree with allocator state at export time;
- **scale-pool mismatch** — with int8 KV pages, exporting (or adopting
  into a ticket) a page whose per-page scales were never written: its
  int8 payload would dequantize through stale scales on the importer;
- **EDF violation** — draining the paged engine's waiting queue past a
  strictly-more-urgent (lower priority value) request;
- **shadow divergence** — :meth:`crosscheck` compares the shadow
  against the allocator's own books (run from ``check_no_leaks``).

The sanitizer only *observes*: a clean run with ``sanitize=True`` is
byte-identical to ``sanitize=False`` (asserted by the mutation suite in
``tests/test_analysis.py``).  Set ``REPRO_SANITIZE=1`` to switch it on
fleet-wide — nightly CI runs the paged-engine/prefix-cache/migration
suites that way.

Stdlib-only on purpose: importable wherever the allocator is.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Set


class KVSanError(ValueError):
    """A page-ownership invariant was violated.

    Subclasses :class:`ValueError` so call sites (and tests) that guard
    against the allocator's own errors keep working when the sanitizer
    reports first with more context.
    """


class KVSanitizer:
    """Shadow page-ownership state mirroring one ``PageAllocator``.

    Parameters
    ----------
    num_pages : int
        Pool size including the reserved trash page 0.
    page_size : int
        Tokens per page (reported in messages only).
    journal_len : int, optional
        Number of recent operations kept for error context.
    """

    def __init__(
        self, num_pages: int, page_size: int, journal_len: int = 24
    ) -> None:
        self.num_pages = num_pages
        self.page_size = page_size
        self._ref: Dict[int, int] = {}
        self._indexed: Set[int] = set()
        self._free: Set[int] = set(range(1, num_pages))
        self._tables: Dict[int, List[int]] = {}   # row -> block-table pages
        self._scaled: Set[int] = set()   # pages with written per-page scales
        self._journal: Deque[str] = deque(maxlen=journal_len)
        self._op = 0
        #: writes validated (clean-run observability)
        self.writes_checked = 0

    # -- internals -----------------------------------------------------------
    def _log(self, msg: str) -> None:
        self._op += 1
        self._journal.append(f"#{self._op} {msg}")

    def _fail(self, msg: str) -> None:
        tail = "\n    ".join(self._journal) or "(empty)"
        raise KVSanError(
            f"kvsan: {msg}\n  recent page ops:\n    {tail}"
        )

    def _rows_holding(self, page: int) -> List[int]:
        return sorted(
            row for row, pages in self._tables.items() if page in pages
        )

    # -- allocator transitions (called BEFORE the allocator mutates) ---------
    def on_alloc(self, pages: Sequence[int], owner: int) -> None:
        """Mirror an ``alloc``: pages must come off the free set."""
        for p in pages:
            if p not in self._free:
                state = "live" if p in self._ref else (
                    "dormant" if p in self._indexed else "unknown"
                )
                self._fail(
                    f"alloc handed out non-free page {p} ({state}) "
                    f"to owner {owner}"
                )
        for p in pages:
            self._free.discard(p)
            self._ref[p] = 1
        self._log(f"alloc {list(pages)} owner={owner}")

    def on_fork(self, pages: Sequence[int], owner: int) -> None:
        """Mirror a ``fork``: every page must be live."""
        for p in pages:
            if p not in self._ref:
                self._fail(f"fork of non-live page {p} by owner {owner}")
        for p in pages:
            self._ref[p] += 1
        self._log(f"fork {list(pages)} owner={owner}")

    def on_adopt(self, pages: Sequence[int], owner: int) -> None:
        """Mirror an ``adopt``: every page must be index-registered."""
        for p in pages:
            if p not in self._indexed:
                self._fail(f"adopt of non-indexed page {p} by owner {owner}")
        for p in pages:
            self._ref[p] = self._ref.get(p, 0) + 1
        self._log(f"adopt {list(pages)} owner={owner}")

    def on_free(self, pages: Sequence[int]) -> None:
        """Mirror a ``free``; validates fully before mutating anything.

        Raises
        ------
        KVSanError
            On a double free (including duplicate ids within one call),
            a foreign page, or a refcount underflow — *before* either
            the shadow or the allocator changes state.
        """
        counts: Dict[int, int] = {}
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
        for p, c in counts.items():
            have = self._ref.get(p, 0)
            if have < c:
                kind = "double free" if p in self._free or have == 0 \
                    else "refcount underflow"
                self._fail(
                    f"{kind} of page {p} (freeing x{c}, live refcount "
                    f"{have}; holders: rows {self._rows_holding(p)})"
                )
        for p, c in counts.items():
            self._ref[p] -= c
            if self._ref[p] == 0:
                del self._ref[p]
                if p not in self._indexed:
                    self._free.add(p)
                    self._scaled.discard(p)  # freed content is garbage again
        self._log(f"free {list(pages)}")

    def on_mark_indexed(self, pages: Sequence[int]) -> None:
        """Mirror ``mark_indexed``: pages must be live."""
        for p in pages:
            if p not in self._ref:
                self._fail(f"mark_indexed of non-live page {p}")
        self._indexed.update(pages)
        self._log(f"mark_indexed {list(pages)}")

    def on_unmark_indexed(self, pages: Sequence[int]) -> None:
        """Mirror ``unmark_indexed``: dormant pages return to free."""
        for p in pages:
            if p not in self._indexed:
                self._fail(f"unmark_indexed of non-indexed page {p}")
        for p in pages:
            self._indexed.discard(p)
            if p not in self._ref:
                self._free.add(p)
                self._scaled.discard(p)  # freed content is garbage again
        self._log(f"unmark_indexed {list(pages)}")

    def on_defrag(self, mapping: Dict[int, int]) -> None:
        """Remap the shadow state after an allocator ``defrag``."""
        remap = lambda p: mapping.get(p, p)  # noqa: E731
        self._ref = {remap(p): r for p, r in self._ref.items()}
        self._indexed = {remap(p) for p in self._indexed}
        self._scaled = {remap(p) for p in self._scaled}
        self._tables = {
            row: [remap(p) for p in pages]
            for row, pages in self._tables.items()
        }
        live = set(self._ref) | self._indexed
        self._free = set(range(1, self.num_pages)) - live
        self._log(f"defrag moved={len(mapping)}")

    # -- engine-side events --------------------------------------------------
    def note_table(self, row: int, pages: Sequence[int]) -> None:
        """Register row's block-table pages; detect exclusive aliasing."""
        self._tables[row] = list(pages)
        for p in pages:
            holders = self._rows_holding(p)
            if len(holders) > self._ref.get(p, 0):
                self._fail(
                    f"block-table aliasing: page {p} appears in rows "
                    f"{holders} but has refcount {self._ref.get(p, 0)}"
                )

    def drop_table(self, row: int) -> None:
        """Forget row's block table (row released or exported)."""
        self._tables.pop(row, None)

    def note_write(self, row: int, page: int, quantized: bool = False) -> None:
        """Validate one engine write into ``page`` on behalf of ``row``.

        Parameters
        ----------
        row : int
            The writing sequence row.
        page : int
            The physical page written.
        quantized : bool, optional
            True on int8-KV engines: the write also updated the page's
            per-page scale pool entries, so the page joins the shadow
            ``scaled`` set that :meth:`validate_scale_export` checks.

        Raises
        ------
        KVSanError
            When the page is free (use-after-free), not owned at all,
            shared or index-registered (copy-on-write bypass), absent
            from the row's registered block table, or exclusively owned
            yet present in another row's table (aliasing).
        """
        if page in self._free:
            self._fail(
                f"use-after-free: row {row} wrote to freed page {page}"
            )
        ref = self._ref.get(page, 0)
        if ref == 0:
            self._fail(
                f"use-after-free: row {row} wrote to page {page} with no "
                f"live owner (dormant={page in self._indexed})"
            )
        if ref > 1:
            self._fail(
                f"copy-on-write bypass: row {row} wrote to shared page "
                f"{page} (refcount {ref}, holders: rows "
                f"{self._rows_holding(page)})"
            )
        if page in self._indexed:
            self._fail(
                f"copy-on-write bypass: row {row} wrote to "
                f"index-registered page {page} — its content must keep "
                "matching the radix index's token-block key"
            )
        table = self._tables.get(row)
        if table is not None and page not in table:
            self._fail(
                f"stray write: page {page} is not in row {row}'s "
                f"registered block table {table}"
            )
        holders = self._rows_holding(page)
        if holders and holders != [row]:
            self._fail(
                f"block-table aliasing: exclusive page {page} written by "
                f"row {row} but registered to rows {holders}"
            )
        if quantized:
            self._scaled.add(page)
        self.writes_checked += 1

    def note_scale_copy(self, src: int, dst: int) -> None:
        """Mirror a copy-on-write page copy's effect on the scale pools.

        The engine's CoW copies every pool leaf — including ``k_s``/
        ``v_s`` on int8 engines — so ``dst`` inherits ``src``'s scale
        validity.  A no-op when ``src`` has no recorded scales.
        """
        if src in self._scaled:
            self._scaled.add(dst)
            self._log(f"scale-copy {src} -> {dst}")

    def validate_scale_export(self, pages: Sequence[int]) -> None:
        """Check every exported page carries written per-page scales.

        Called by ``export_request`` on int8-KV engines before the
        ticket leaves: an exported page whose scale-pool entries were
        never written would dequantize its int8 payload through stale
        scales on the importing replica — silent KV corruption that
        surfaces tokens later.

        Parameters
        ----------
        pages : sequence of int
            The exported pages, block-table order.

        Raises
        ------
        KVSanError
            Naming the first page with no recorded quantized write.
        """
        for p in pages:
            if p not in self._scaled:
                self._fail(
                    f"scale-pool mismatch: exporting page {p} but its "
                    "per-page scales were never written (int8 payload "
                    "would dequantize through stale scales)"
                )

    def validate_ticket(
        self, pages: Sequence[int], refcounts: Optional[Sequence[int]]
    ) -> None:
        """Check a migration ticket's refcounts against shadow state.

        Parameters
        ----------
        pages : sequence of int
            The exported pages, block-table order.
        refcounts : sequence of int, optional
            ``MigrationTicket.page_refcounts`` as recorded at export.

        Raises
        ------
        KVSanError
            When the recorded refcounts disagree with the shadow's live
            counts — the ticket was built from stale allocator state.
        """
        if refcounts is None:
            return
        if len(refcounts) != len(pages):
            self._fail(
                f"migration ticket covers {len(pages)} pages but records "
                f"{len(refcounts)} refcounts"
            )
        for p, rc in zip(pages, refcounts):
            have = self._ref.get(p, 0)
            if rc != have:
                self._fail(
                    f"migration ticket refcount drift: page {p} recorded "
                    f"at {rc} but allocator holds {have} — the ticket was "
                    "built from stale state"
                )

    def check_edf_drain(
        self, chosen_priority: float, waiting_priorities: Iterable[float]
    ) -> None:
        """Assert the waiting queue drains earliest-deadline-first.

        Parameters
        ----------
        chosen_priority : float
            Priority of the request just re-admitted.
        waiting_priorities : iterable of float
            Priorities still waiting *after* the choice.

        Raises
        ------
        KVSanError
            If some still-waiting request is strictly more urgent than
            the one admitted.
        """
        for p in waiting_priorities:
            if p < chosen_priority:
                self._fail(
                    f"EDF violation: re-admitted priority "
                    f"{chosen_priority} while priority {p} still waits"
                )

    # -- cross-validation ----------------------------------------------------
    def crosscheck(self, allocator) -> None:
        """Compare the shadow against the allocator's own books.

        Parameters
        ----------
        allocator : PageAllocator
            The allocator this sanitizer shadows.

        Raises
        ------
        KVSanError
            On any divergence in refcounts, the indexed set, or the
            free list — evidence of an allocator-internal bug or a
            state mutation that bypassed the sanitizer hooks.
        """
        if dict(allocator._ref) != self._ref:
            self._fail(
                f"shadow refcount divergence: allocator {allocator._ref} "
                f"vs shadow {self._ref}"
            )
        if set(allocator._indexed) != self._indexed:
            self._fail(
                f"shadow index divergence: allocator "
                f"{sorted(allocator._indexed)} vs shadow "
                f"{sorted(self._indexed)}"
            )
        if set(allocator._free) != self._free:
            self._fail(
                f"shadow free-list divergence: allocator "
                f"{sorted(allocator._free)} vs shadow {sorted(self._free)}"
            )
