"""Performance lint: AST passes flagging hot-path slowdowns.

One rule so far:

- ``hot-loop-import`` — an ``import`` statement lexically inside a
  ``for``/``while`` loop body, or anywhere inside a function named
  ``step``/``_step`` (the serving engines' per-iteration entry points).
  ``import`` is not free even when the module is cached: every
  execution takes the import lock and does a ``sys.modules`` dict
  round-trip, and the first execution can hide a multi-second JAX
  import inside what profiles as "one engine step".  The paged engine
  shipped exactly this bug — a ``from ..kernels.paged_attention
  import ...`` inside ``PagedLLMEngine.step()`` paid the lookup on
  every sanitized iteration.  Hoist the import to module level (or to
  function scope *outside* the loop when breaking an import cycle —
  with a suppression explaining why).

Intentional lazy imports at function top level (e.g. keeping jax out of
the dependency-free lint job) are not flagged — only loops and the
``step`` hot path are.
"""

from __future__ import annotations

import ast
from typing import List

from .framework import Checker, Finding, Source, register


def _describe(node: ast.AST) -> str:
    """Render an import statement back to (approximate) source."""
    if isinstance(node, ast.Import):
        return "import " + ", ".join(a.name for a in node.names)
    assert isinstance(node, ast.ImportFrom)
    mod = "." * node.level + (node.module or "")
    return f"from {mod} import " + ", ".join(a.name for a in node.names)


class _HotImportVisitor(ast.NodeVisitor):
    """Track loop / hot-function nesting while collecting imports."""

    _HOT_FUNCS = {"step", "_step"}

    def __init__(self, checker: "HotLoopImportChecker", src: Source) -> None:
        self._checker = checker
        self._src = src
        self._in_loop = False
        self._in_hot = False
        self.findings: List[Finding] = []

    def _flag(self, node: ast.AST, why: str) -> None:
        self.findings.append(self._checker.finding(
            self._src, node, "hot-loop-import",
            f"`{_describe(node)}` {why}; hoist it to module level",
        ))

    def visit_Import(self, node: ast.Import) -> None:
        if self._in_loop:
            self._flag(node, "runs on every loop iteration")
        elif self._in_hot:
            self._flag(node, "inside a step() hot path runs once per "
                             "engine iteration")

    visit_ImportFrom = visit_Import

    def _visit_loop(self, node: ast.AST) -> None:
        was = self._in_loop
        self._in_loop = True
        self.generic_visit(node)
        self._in_loop = was

    visit_For = visit_AsyncFor = visit_While = _visit_loop

    def _visit_func(self, node: ast.AST) -> None:
        # a nested def's body runs when *called*, not per enclosing
        # iteration — reset loop context; but any def inside step()
        # stays hot (closures there are invoked per step)
        was_loop, was_hot = self._in_loop, self._in_hot
        self._in_loop = False
        self._in_hot = was_hot or node.name in self._HOT_FUNCS
        self.generic_visit(node)
        self._in_loop, self._in_hot = was_loop, was_hot

    visit_FunctionDef = visit_AsyncFunctionDef = _visit_func


@register
class HotLoopImportChecker(Checker):
    """Flag import statements executed once per loop iteration/step."""

    rules = {
        "hot-loop-import": (
            "import inside a loop body or a step() hot path re-runs the "
            "sys.modules lookup every iteration; hoist to module level"
        ),
    }

    def check(self, src: Source) -> List[Finding]:
        """Return one finding per hot-path import in ``src``."""
        visitor = _HotImportVisitor(self, src)
        visitor.visit(src.tree)
        return visitor.findings
