"""Serving runtime: continuous-batching engines + compound-job testbed."""

from .engine import LLMEngine, Request
from .cluster import ServingCluster, TestbedResult

__all__ = ["LLMEngine", "Request", "ServingCluster", "TestbedResult"]
