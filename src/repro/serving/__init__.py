"""Serving runtime: continuous-batching engines + compound-job testbed.

Two interchangeable executors:
- :class:`LLMEngine` — slot-based (dense per-slot KV, max_batch slots);
- :class:`PagedLLMEngine` — paged KV pool + block tables (vLLM-style),
  capacity-based admission, chunked prefill, preemption-by-eviction.

Multi-replica serving: :class:`ServingCluster` drives N replicas,
honouring the scheduler's per-task placement hints, and — when
``migrate=True`` — runs a :class:`Rebalancer` that live-migrates
decoding requests (KV pages and all, via :class:`MigrationTicket`) off
KV-starved replicas onto peers with headroom.
"""

from .config import ServeConfig, build_engines
from .engine import LLMEngine, Request
from .paged_cache import PageAllocator, TRASH_PAGE
from .prefix_cache import RadixPrefixIndex
from .paged_engine import MigrationTicket, PagedLLMEngine
from .migration import Rebalancer, migrate_request
from .cluster import ServingCluster, TestbedResult

__all__ = [
    "LLMEngine", "PagedLLMEngine", "Request", "PageAllocator", "TRASH_PAGE",
    "RadixPrefixIndex", "MigrationTicket", "Rebalancer", "migrate_request",
    "ServeConfig", "ServingCluster", "TestbedResult", "build_engines",
]
