"""Serving runtime: continuous-batching engines + compound-job testbed.

Two interchangeable executors:
- :class:`LLMEngine` — slot-based (dense per-slot KV, max_batch slots);
- :class:`PagedLLMEngine` — paged KV pool + block tables (vLLM-style),
  capacity-based admission, chunked prefill, preemption-by-eviction.
"""

from .engine import LLMEngine, Request
from .paged_cache import PageAllocator, TRASH_PAGE
from .paged_engine import PagedLLMEngine
from .cluster import ServingCluster, TestbedResult

__all__ = [
    "LLMEngine", "PagedLLMEngine", "Request", "PageAllocator", "TRASH_PAGE",
    "ServingCluster", "TestbedResult",
]
