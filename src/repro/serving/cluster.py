"""Testbed runtime: compound LLM jobs over REAL engines (paper §V-B analog).

Wall-clock event loop driving:
- ``n_llm`` :class:`LLMEngine` instances (tiny model, real jitted decode);
- ``n_regular`` executor slots (deadline-based task completion);
- any :class:`repro.core.scheduler.Scheduler` making admission decisions.

LLM tasks become engine requests whose token budget is the task's
``out_tokens`` (scaled by ``token_scale`` so CPU runs finish quickly);
the engines' *measured* l(b) feeds Eq. 2 calibration, closing the same
loop the paper's vLLM testbed closes.

Multi-replica serving: the cluster exposes per-replica batch load *and*
KV headroom to the scheduler (``ClusterView.llm_free_tokens``), honours
the scheduler's per-task placement hints (``Decision.placement``), and
— with ``migrate=True`` — runs a :class:`~repro.serving.migration.
Rebalancer` each loop iteration to live-migrate decoding requests off
KV-starved paged replicas.

Prefix-cache fleets additionally report per-replica resident prefix
tokens (``ClusterView.llm_prefix_hit_tokens``) so cache-aware placement
can steer an application's tasks to the replica already holding its
shared system prompt; ``shared_prompt_tokens`` synthesizes exactly that
workload shape, and per-job prefill token totals are recorded for the
sim↔testbed cache-model parity canary.
"""

from __future__ import annotations

import math
import time
import zlib
from typing import List, Optional, Sequence, Tuple

from ..core.cascade import QualityGate, fleet_ranks
from ..core.dag import Job, Stage, Task, TaskState
from ..core.metrics import RunMetrics
from ..core.scheduler import ClusterView, Decision, Scheduler
from ..models.zoo import tier_spec
from ..sim.workloads import GeneratedJob, get_generators, reveal_after_stage
from .config import ServeConfig
from .engine import LLMEngine, Request
from .migration import Rebalancer

# Backwards-compatible alias: the testbed's historical result type is
# now the unified schema shared with the simulator.
TestbedResult = RunMetrics


class ServingCluster:
    """Wall-clock event loop over real engines + regular executors.

    Parameters
    ----------
    scheduler : Scheduler
        Admission/placement policy (LLMSched or any baseline).
    engines : list of LLMEngine or PagedLLMEngine
        The LLM replica fleet; may mix capacities (heterogeneous KV
        budgets).  Replicas must share weights for migration to be
        lossless.
    config : ServeConfig, optional
        Runtime configuration (executor slots, scaling factors, prompt
        synthesis, migration).  Defaults to ``ServeConfig()``.  Note
        the cluster consumes the *runtime* fields; fleet-shape fields
        (``engine``/``replicas``/``kv_pages``…) describe the supplied
        ``engines`` and are used by :func:`repro.serving.build_engines`.
    rebalancer : Rebalancer, optional
        Custom policy instance; built with defaults when
        ``config.migrate`` is set and none is given.
    gate : QualityGate, optional
        Verifier run over every finished LLM task whose replica has
        known tier economics.  With ``config.cascade`` and a
        heterogeneous priced fleet, a rejection re-enqueues the task
        one cost tier up (``Task.tier_floor``); otherwise rejections
        mark the job in ``RunMetrics.quality_by_job``.  ``None``
        (default) disables gating — byte-identical to the historical
        cluster.
    """

    def __init__(
        self,
        scheduler: Scheduler,
        engines: List[LLMEngine],
        config: Optional[ServeConfig] = None,
        *,
        rebalancer: Optional[Rebalancer] = None,
        gate: Optional[QualityGate] = None,
    ) -> None:
        config = config or ServeConfig()
        self.config = config
        self.scheduler = scheduler
        self.engines = engines
        self.n_regular = config.n_regular
        self.token_scale = config.token_scale
        self.time_scale = config.time_scale
        self.min_tokens = config.min_tokens
        self.migrate = config.migrate
        self.rebalancer = rebalancer
        self.shared_prompt_tokens = int(config.shared_prompt_tokens)
        if self.migrate and self.rebalancer is None:
            self.rebalancer = Rebalancer(engines)
        self.gate = gate
        self.cascade = bool(config.cascade)
        # per-replica tier economics: None entries (models absent from
        # the zoo price table, e.g. ad-hoc test configs) gate the cost
        # signal off in ClusterView.assemble rather than invent a price
        self._tier_specs = [
            tier_spec(e.cfg.name) if getattr(e, "cfg", None) is not None
            else None
            for e in engines
        ]
        self._costs = [
            None if s is None else s.usd_per_mtok / 1e6
            for s in self._tier_specs
        ]
        # escalation floors need the whole fleet priced; same dense
        # cost-rank rule the scheduler applies, so runtime escalation
        # and scheduler placement agree on what "one tier up" means
        if self._costs and all(c is not None for c in self._costs):
            self._ranks: Optional[List[int]] = fleet_ranks(self._costs)
            self._rank_top = max(self._ranks)
        else:
            self._ranks = None
            self._rank_top = 0
        self._eidx = {id(e): i for i, e in enumerate(engines)}

    def _prompt_for(self, task: Task, app_name: str) -> List[int]:
        """Synthesize the engine prompt for an LLM task.

        With ``shared_prompt_tokens`` set, tasks of one application
        share a deterministic system-prompt prefix (page-alignable, so
        prefix-cache replicas deduplicate it) and differ only in a
        short stage/index suffix.  Uses ``zlib.crc32`` — not ``hash``
        — for the shared part so the token stream is stable across
        processes and runs.

        Parameters
        ----------
        task : Task
            The LLM task being dispatched.
        app_name : str
            The owning job's application template name.

        Returns
        -------
        list of int
            Token ids for the engine request.
        """
        if self.shared_prompt_tokens <= 0:
            return [1 + (hash(task.stage_name) % 32), 2 + task.index % 7]
        base = zlib.crc32(app_name.encode())
        sys_prompt = [
            1 + (base + 31 * k) % 97 for k in range(self.shared_prompt_tokens)
        ]
        return sys_prompt + [
            1 + (zlib.crc32(task.stage_name.encode()) % 32),
            2 + task.index % 7,
        ]

    def run(self, workload: Sequence[GeneratedJob]) -> TestbedResult:
        """Serve a compound-job workload to completion.

        Parameters
        ----------
        workload : sequence of GeneratedJob
            Jobs with arrival times (compressed by ``time_scale``).

        Returns
        -------
        TestbedResult
            JCTs, throughput, preemption and migration counters.
        """
        gens = get_generators()
        res = TestbedResult()
        t_start = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t_start

        jobs = sorted((gj.job for gj in workload), key=lambda j: j.arrival_time)
        # arrival times are compressed by time_scale as well
        arrivals = [(j.arrival_time / self.time_scale, j) for j in jobs]
        job_by_id = {j.job_id: j for j in jobs}
        active: List[Job] = []
        ai = 0
        reg_running: List[Optional[Tuple[float, Task]]] = [None] * self.n_regular
        rid_counter = [0]

        def on_stage_complete(job: Job, stage: Stage) -> None:
            # chain reveals + dynamic expansion + evidence-version bump
            reveal_after_stage(job, stage, gens)

        def finish_task(task: Task) -> None:
            task.state = TaskState.DONE
            task.finish_time = now()
            job = job_by_id[task.job_id]
            job.bump_evidence()  # new completed-duration evidence
            stage = job.stages[task.stage_name]
            if stage.done():
                on_stage_complete(job, stage)
            if job.done():
                job.finish_time = now()
                jct = job.finish_time - job.arrival_time / self.time_scale
                res.jcts.append(jct)
                res.jct_by_job[job.job_id] = jct
                if job.slo is not None:
                    res.tier_by_job[job.job_id] = job.slo.tier
                    res.deadline_by_job[job.job_id] = job.slo.deadline
                    met = job.met_slo(self.time_scale)
                    if met is not None:
                        res.slo_met_by_job[job.job_id] = met
                if job in active:
                    active.remove(job)
                self.scheduler.observe_completion(job, now())

        def dispatch(dec: Decision) -> None:
            for t in dec.regular:
                if t.state is not TaskState.PENDING:
                    continue
                placed = False
                for e in range(self.n_regular):
                    if reg_running[e] is None:
                        t.state = TaskState.RUNNING
                        t.start_time = now()
                        job = job_by_id[t.job_id]
                        job.stages[t.stage_name].dispatched_tasks += 1
                        job.bump_evidence()  # running/unscheduled sets changed
                        deadline = now() + t.true_duration / self.time_scale
                        reg_running[e] = (deadline, t)
                        placed = True
                        break
                if not placed:
                    break
            for t in dec.llm:
                if t.state is not TaskState.PENDING:
                    continue
                # scheduler placement hint first, then least-loaded
                # admissible engines (paper §IV-D); paged engines refuse
                # admission when their page pool is exhausted, so
                # placement is KV-capacity-aware and the scheduler's
                # dispatch order decides who gets the memory
                cands = [e for e in self.engines if e.can_admit()]
                if not cands:
                    break
                # cascade floor: an escalated task may only run on
                # replicas at or above its minimum cost rank (floors
                # only arise when the whole fleet is priced)
                if self._ranks is not None and t.tier_floor > 0:
                    cands = [
                        e for e in cands
                        if self._ranks[self._eidx[id(e)]] >= t.tier_floor
                    ]
                    if not cands:
                        continue  # eligible tiers busy; retry next round
                cands.sort(
                    key=lambda e: (
                        e.batch_size,
                        -getattr(e, "free_token_capacity", 0)
                        - getattr(e, "reclaimable_token_capacity", 0),
                    )
                )
                placed = dec.replica_for(t)
                if placed is not None and 0 <= placed < len(self.engines):
                    pe = self.engines[placed]
                    if pe in cands:
                        cands.remove(pe)
                        cands.insert(0, pe)
                rid_counter[0] += 1
                n_tok = max(self.min_tokens, int(t.out_tokens / self.token_scale))
                prompt = self._prompt_for(t, job_by_id[t.job_id].app.name)
                task = t

                # deadline-aware admission ordering: SLO jobs carry
                # their scaled deadline as the request priority, so a
                # paged engine drains its waiting queue EDF-first;
                # SLO-less requests keep priority=inf (pure FIFO, the
                # historical order, byte-for-byte)
                slo = job_by_id[t.job_id].slo
                req = Request(
                    rid=rid_counter[0],
                    prompt=prompt,
                    max_new_tokens=n_tok,
                    submitted_at=now(),
                    priority=(
                        math.inf if slo is None
                        else slo.deadline / self.time_scale
                    ),
                )
                # can_admit() is a cheap pre-filter; a paged engine may
                # still refuse a multi-page prompt, so fall through to
                # the next-best candidate before giving up on the task
                admitted = None
                for e in cands:
                    if e.admit(req):
                        admitted = e
                        break
                if admitted is None:
                    break  # no engine can take it; retry next round
                # on_finish needs the admitting replica's tier (cost
                # accounting + gate quality); assigning after admission
                # is safe — finishes only ever fire inside step()
                ei = self._eidx[id(admitted)]

                def _done(req: Request, task=task, ei=ei) -> None:
                    res.tokens_generated += len(req.out_tokens)
                    res.prefill_tokens += req.prefill_tokens
                    res.prefill_by_job[task.job_id] = (
                        res.prefill_by_job.get(task.job_id, 0)
                        + req.prefill_tokens
                    )
                    spec = self._tier_specs[ei]
                    if spec is not None:
                        # real spend: tokens actually generated on this
                        # attempt, at the serving replica's tier price
                        res.cost_by_job[task.job_id] = (
                            res.cost_by_job.get(task.job_id, 0.0)
                            + len(req.out_tokens) * spec.usd_per_mtok / 1e6
                        )
                    if self.gate is not None and spec is not None:
                        job = job_by_id[task.job_id]
                        ok = self.gate.passes(
                            job.app.name, task.stage_name, task.index,
                            task.attempt, spec.quality,
                        )
                        can_up = (
                            self.cascade
                            and self._ranks is not None
                            and self._ranks[ei] < self._rank_top
                        )
                        if not ok and can_up:
                            # cascade escalation: re-enqueue one cost
                            # tier up; the attempt bump re-keys the
                            # gate's deterministic draw
                            task.tier_floor = self._ranks[ei] + 1
                            task.attempt += 1
                            task.state = TaskState.PENDING
                            task.start_time = -1.0
                            job.bump_evidence()
                            res.escalations += 1
                            return
                        res.quality_by_job[task.job_id] = (
                            res.quality_by_job.get(task.job_id, True) and ok
                        )
                    finish_task(task)

                req.on_finish = _done
                t.state = TaskState.RUNNING
                t.start_time = now()
                job = job_by_id[t.job_id]
                job.stages[t.stage_name].dispatched_tasks += 1
                job.bump_evidence()  # running/unscheduled sets changed

        def view() -> ClusterView:
            prof = None
            for e in self.engines:
                prof = e.latency_profile() or prof
            # dormant prefix pages are reclaimable on admission, so a
            # cache-heavy replica must not read as KV-starved — that
            # would starve exactly the replica the cache-affinity term
            # wants to prefer (reclaimable is 0 without a prefix cache)
            free_tok = [
                None
                if getattr(e, "free_token_capacity", None) is None
                else e.free_token_capacity
                + getattr(e, "reclaimable_token_capacity", 0)
                for e in self.engines
            ]
            hit_tok = [
                getattr(e, "prefix_cached_tokens", None) for e in self.engines
            ]
            # assemble() owns the all-or-nothing gating (KV accounting /
            # cache-affinity only when every replica reports it)
            return ClusterView.assemble(
                now=now(),
                free_regular=sum(1 for s in reg_running if s is None),
                llm_loads=[(e.batch_size, e.max_batch) for e in self.engines],
                latency_profile=prof,
                llm_free_tokens=free_tok,
                llm_prefix_hit_tokens=hit_tok,
                llm_model_costs=self._costs,
            )

        # ------------------------- main loop -------------------------
        while ai < len(arrivals) or active:
            t = now()
            # arrivals
            while ai < len(arrivals) and arrivals[ai][0] <= t:
                active.append(arrivals[ai][1])
                ai += 1
            # regular completions
            for e in range(self.n_regular):
                slot = reg_running[e]
                if slot is not None and slot[0] <= t:
                    reg_running[e] = None
                    finish_task(slot[1])
            # schedule + dispatch
            t0 = time.perf_counter()
            dec = self.scheduler.schedule(active, view())
            res.sched_overhead_s.append(time.perf_counter() - t0)
            dispatch(dec)
            # live migration: relieve KV-starved replicas before stepping
            if self.migrate and self.rebalancer is not None:
                res.migrations += self.rebalancer.step()
            # decode step on each engine (the real compute); paged engines
            # also need steps to re-admit evicted (requeued) requests
            stepped = False
            for eng in self.engines:
                if eng.batch_size or getattr(eng, "waiting", ()):
                    eng.step()
                    stepped = True
            if not stepped:
                # idle: wait for next arrival or regular completion
                nxt = [arrivals[ai][0]] if ai < len(arrivals) else []
                nxt += [s[0] for s in reg_running if s is not None]
                if nxt:
                    time.sleep(max(0.0, min(nxt) - now()) + 1e-4)
                elif not active:
                    break
                else:
                    time.sleep(1e-3)
        res.makespan = now()
        res.preemptions = sum(getattr(e, "preemptions", 0) for e in self.engines)
        res.prefill_saved_tokens = sum(
            getattr(e, "prefill_skipped_tokens", 0) for e in self.engines
        )
        res.retractions = int(getattr(self.scheduler, "retractions", 0))
        return res
