"""Testbed runtime: compound LLM jobs over REAL engines (paper §V-B analog).

Wall-clock event loop driving:
- ``n_llm`` :class:`LLMEngine` instances (tiny model, real jitted decode);
- ``n_regular`` executor slots (deadline-based task completion);
- any :class:`repro.core.scheduler.Scheduler` making admission decisions.

LLM tasks become engine requests whose token budget is the task's
``out_tokens`` (scaled by ``token_scale`` so CPU runs finish quickly);
the engines' *measured* l(b) feeds Eq. 2 calibration, closing the same
loop the paper's vLLM testbed closes.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.dag import Job, Stage, Task, TaskState
from ..core.scheduler import ClusterView, Decision, Scheduler
from ..sim.workloads import GeneratedJob, get_generators, reveal_after_stage
from .engine import LLMEngine, Request


@dataclass
class TestbedResult:
    jcts: List[float] = field(default_factory=list)
    jct_by_job: Dict[int, float] = field(default_factory=dict)
    sched_overhead_s: List[float] = field(default_factory=list)
    makespan: float = 0.0
    tokens_generated: int = 0
    preemptions: int = 0  # paged-engine evictions (pages freed + requeue)

    @property
    def avg_jct(self) -> float:
        return float(np.mean(self.jcts)) if self.jcts else 0.0

    @property
    def avg_overhead_ms(self) -> float:
        return (
            1e3 * float(np.mean(self.sched_overhead_s))
            if self.sched_overhead_s
            else 0.0
        )


class ServingCluster:
    def __init__(
        self,
        scheduler: Scheduler,
        engines: List[LLMEngine],
        n_regular: int = 4,
        token_scale: float = 8.0,
        time_scale: float = 8.0,
        min_tokens: int = 2,
    ) -> None:
        self.scheduler = scheduler
        self.engines = engines
        self.n_regular = n_regular
        self.token_scale = token_scale
        self.time_scale = time_scale
        self.min_tokens = min_tokens

    def run(self, workload: Sequence[GeneratedJob]) -> TestbedResult:
        gens = get_generators()
        res = TestbedResult()
        t_start = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t_start

        jobs = sorted((gj.job for gj in workload), key=lambda j: j.arrival_time)
        # arrival times are compressed by time_scale as well
        arrivals = [(j.arrival_time / self.time_scale, j) for j in jobs]
        job_by_id = {j.job_id: j for j in jobs}
        active: List[Job] = []
        ai = 0
        reg_running: List[Optional[Tuple[float, Task]]] = [None] * self.n_regular
        rid_counter = [0]

        def on_stage_complete(job: Job, stage: Stage) -> None:
            # chain reveals + dynamic expansion + evidence-version bump
            reveal_after_stage(job, stage, gens)

        def finish_task(task: Task) -> None:
            task.state = TaskState.DONE
            task.finish_time = now()
            job = job_by_id[task.job_id]
            job.bump_evidence()  # new completed-duration evidence
            stage = job.stages[task.stage_name]
            if stage.done():
                on_stage_complete(job, stage)
            if job.done():
                job.finish_time = now()
                jct = job.finish_time - job.arrival_time / self.time_scale
                res.jcts.append(jct)
                res.jct_by_job[job.job_id] = jct
                if job in active:
                    active.remove(job)
                self.scheduler.observe_completion(job, now())

        def dispatch(dec: Decision) -> None:
            for t in dec.regular:
                if t.state is not TaskState.PENDING:
                    continue
                placed = False
                for e in range(self.n_regular):
                    if reg_running[e] is None:
                        t.state = TaskState.RUNNING
                        t.start_time = now()
                        job = job_by_id[t.job_id]
                        job.stages[t.stage_name].dispatched_tasks += 1
                        job.bump_evidence()  # running/unscheduled sets changed
                        deadline = now() + t.true_duration / self.time_scale
                        reg_running[e] = (deadline, t)
                        placed = True
                        break
                if not placed:
                    break
            for t in dec.llm:
                if t.state is not TaskState.PENDING:
                    continue
                # least-loaded admissible engine (paper §IV-D); paged
                # engines refuse admission when their page pool is
                # exhausted, so placement is KV-capacity-aware and the
                # scheduler's dispatch order decides who gets the memory
                cands = [e for e in self.engines if e.can_admit()]
                if not cands:
                    break
                cands.sort(
                    key=lambda e: (
                        e.batch_size,
                        -getattr(e, "free_token_capacity", 0),
                    )
                )
                rid_counter[0] += 1
                n_tok = max(self.min_tokens, int(t.out_tokens / self.token_scale))
                prompt = [1 + (hash(t.stage_name) % 32), 2 + t.index % 7]
                task = t

                def _done(req: Request, task=task) -> None:
                    res.tokens_generated += len(req.out_tokens)
                    finish_task(task)

                req = Request(
                    rid=rid_counter[0],
                    prompt=prompt,
                    max_new_tokens=n_tok,
                    submitted_at=now(),
                    on_finish=_done,
                )
                # can_admit() is a cheap pre-filter; a paged engine may
                # still refuse a multi-page prompt, so fall through to
                # the next-best candidate before giving up on the task
                if not any(e.admit(req) for e in cands):
                    break  # no engine can take it; retry next round
                t.state = TaskState.RUNNING
                t.start_time = now()
                job = job_by_id[t.job_id]
                job.stages[t.stage_name].dispatched_tasks += 1
                job.bump_evidence()  # running/unscheduled sets changed

        def view() -> ClusterView:
            prof = None
            for e in self.engines:
                prof = e.latency_profile() or prof
            return ClusterView(
                now=now(),
                free_regular=sum(1 for s in reg_running if s is None),
                llm_loads=[(e.batch_size, e.max_batch) for e in self.engines],
                latency_profile=prof,
            )

        # ------------------------- main loop -------------------------
        while ai < len(arrivals) or active:
            t = now()
            # arrivals
            while ai < len(arrivals) and arrivals[ai][0] <= t:
                active.append(arrivals[ai][1])
                ai += 1
            # regular completions
            for e in range(self.n_regular):
                slot = reg_running[e]
                if slot is not None and slot[0] <= t:
                    reg_running[e] = None
                    finish_task(slot[1])
            # schedule + dispatch
            t0 = time.perf_counter()
            dec = self.scheduler.schedule(active, view())
            res.sched_overhead_s.append(time.perf_counter() - t0)
            dispatch(dec)
            # decode step on each engine (the real compute); paged engines
            # also need steps to re-admit evicted (requeued) requests
            stepped = False
            for eng in self.engines:
                if eng.batch_size or getattr(eng, "waiting", ()):
                    eng.step()
                    stepped = True
            if not stepped:
                # idle: wait for next arrival or regular completion
                nxt = [arrivals[ai][0]] if ai < len(arrivals) else []
                nxt += [s[0] for s in reg_running if s is not None]
                if nxt:
                    time.sleep(max(0.0, min(nxt) - now()) + 1e-4)
                elif not active:
                    break
                else:
                    time.sleep(1e-3)
        res.makespan = now()
        res.preemptions = sum(getattr(e, "preemptions", 0) for e in self.engines)
        return res
