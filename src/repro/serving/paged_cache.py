"""Block-table page allocator for the paged KV cache.

Pages are position-independent fixed-size chunks of KV storage; the
allocator hands out physical page ids and enforces the invariants the
engine's correctness rests on:

- every page carries a **refcount** — the number of live owners whose
  block tables reference it.  Exclusive ownership (refcount 1) is the
  historical regime; shared prefixes and copy-on-write forks raise it;
- every acquisition (:meth:`alloc`, :meth:`fork`, :meth:`adopt`) is
  balanced by exactly one :meth:`free` (no leaks, no double frees) —
  violations raise immediately instead of corrupting caches;
- a page whose refcount drops to zero returns to the free list *unless*
  it is marked **indexed** (registered in a
  :class:`~repro.serving.prefix_cache.RadixPrefixIndex`): indexed pages
  become *dormant* — content retained, re-sharable via :meth:`adopt`,
  reclaimed to the free list only when the index evicts them
  (:meth:`unmark_indexed`) under memory pressure.

Page 0 is reserved as the *trash page*: padding rows in a decode batch
point their block tables at it, so their (discarded) writes can never
land in a live request's pages.

``defrag`` compacts the content-bearing set (live + dormant) onto the
lowest physical page ids (improving DMA locality after heavy churn) and
returns the old→new mapping so the engine can permute pools and patch
block tables and the prefix index.

Live migration composes from these primitives: the source engine
``free``\\ s a request's pages after gathering their contents into a
:class:`~repro.serving.paged_engine.MigrationTicket`, and the
destination ``alloc``\\ s fresh pages to scatter the KV back in — the
invariants above guarantee the handoff can neither leak nor alias, and
shared prefix pages survive on the source as long as any other owner
(or the index) still holds them.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Set

from ..analysis.kvsan import KVSanitizer


TRASH_PAGE = 0


def _env_sanitize() -> bool:
    """Resolve the ``REPRO_SANITIZE`` environment default."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


class PageAllocator:
    """Refcounting free-list allocator over ``num_pages`` KV pages.

    Every physical page (except the reserved trash page 0) is in exactly
    one of three states:

    - **free** — on the free list, content dead, allocatable;
    - **live** — refcount ≥ 1, owned by one or more sequences;
    - **dormant** — refcount 0 but marked indexed (prefix-cache
      resident): content retained, acquirable via :meth:`adopt`,
      reclaimable via :meth:`unmark_indexed`.

    Parameters
    ----------
    num_pages : int
        Total physical pages including the reserved trash page 0;
        must be at least 2.
    page_size : int
        Tokens of KV per page.
    sanitize : bool, optional
        Attach a :class:`~repro.analysis.kvsan.KVSanitizer` that
        mirrors every transition in shadow state and additionally
        validates engine-side events (writes, block tables, migration
        tickets), raising :class:`~repro.analysis.kvsan.KVSanError` on
        ownership violations.  Observation-only: clean runs are
        byte-identical with it on or off.  Defaults to the
        ``REPRO_SANITIZE`` environment variable (any value other than
        empty/``0`` enables it).

    Raises
    ------
    ValueError
        If ``num_pages < 2`` (there would be no allocatable page).
    """

    def __init__(
        self,
        num_pages: int,
        page_size: int,
        sanitize: Optional[bool] = None,
    ) -> None:
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> low ids first
        self._owner: Dict[int, int] = {}  # page id -> owner tag (first live owner)
        self._ref: Dict[int, int] = {}    # page id -> live-owner count (>= 1)
        self._indexed: Set[int] = set()   # pages registered in a prefix index
        if sanitize is None:
            sanitize = _env_sanitize()
        #: the attached shadow-state sanitizer (None when disabled)
        self.sanitizer: Optional[KVSanitizer] = (
            KVSanitizer(num_pages, page_size) if sanitize else None
        )

    # -- capacity ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Number of pages currently available for allocation.

        Returns
        -------
        int
            Free-list length (trash and dormant pages are never counted).
        """
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Number of pages currently owned by requests (refcount ≥ 1).

        Returns
        -------
        int
            Live page count.
        """
        return len(self._ref)

    @property
    def dormant_pages(self) -> int:
        """Number of refcount-0 pages retained by the prefix index.

        These are reclaimable under pressure: the engine evicts them
        from the index (LRU) and calls :meth:`unmark_indexed` to return
        them to the free list.

        Returns
        -------
        int
            Indexed pages with no live owner.
        """
        return sum(1 for p in self._indexed if p not in self._ref)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to store ``n_tokens`` tokens of KV.

        Parameters
        ----------
        n_tokens : int
            Token count (negative values are treated as 0).

        Returns
        -------
        int
            ``ceil(n_tokens / page_size)``.
        """
        return -(-max(0, n_tokens) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        """Check whether ``n`` pages can be allocated atomically.

        Parameters
        ----------
        n : int
            Requested page count.

        Returns
        -------
        bool
            True when the free list holds at least ``n`` pages (dormant
            pages do not count — reclaim them first).
        """
        return n <= len(self._free)

    # -- refcount queries ----------------------------------------------------
    def refcount(self, page: int) -> int:
        """Return the live-owner count of ``page`` (0 for free/dormant).

        Parameters
        ----------
        page : int
            Physical page id.

        Returns
        -------
        int
            Number of live owners currently referencing the page.
        """
        return self._ref.get(page, 0)

    def is_indexed(self, page: int) -> bool:
        """Check whether ``page`` is registered in a prefix index.

        Indexed pages must be treated as read-only by the engine: a
        write would desynchronize the index's token-block key from the
        page's KV content, so writers copy-on-write first.

        Parameters
        ----------
        page : int
            Physical page id.

        Returns
        -------
        bool
            True when the page is index-registered (live or dormant).
        """
        return page in self._indexed

    # -- alloc/free ----------------------------------------------------------
    def alloc(self, n: int, owner: int = -1) -> Optional[List[int]]:
        """Atomically allocate ``n`` fresh pages at refcount 1.

        Parameters
        ----------
        n : int
            Page count; the request is all-or-nothing (no partial
            allocation is ever observable).
        owner : int, optional
            Opaque owner tag recorded per page (typically the sequence
            row); queried via :meth:`owned_by` and reported in error
            messages.

        Returns
        -------
        list of int or None
            The allocated physical page ids (lowest-id-first), or
            ``None`` when the free list cannot satisfy the request.
        """
        if n > len(self._free):
            return None
        pages = [self._free[-(i + 1)] for i in range(n)]
        if self.sanitizer is not None:
            self.sanitizer.on_alloc(pages, owner)
        del self._free[len(self._free) - n:]
        for p in pages:
            self._owner[p] = owner
            self._ref[p] = 1
        return pages

    def fork(self, pages: List[int], owner: int = -1) -> List[int]:
        """Copy-on-write fork: add an owner to already-live pages.

        Increments each page's refcount without copying any KV.  The
        new owner shares the physical pages until it writes; the engine
        detects the write (``refcount > 1`` or :meth:`is_indexed`) and
        copies the page first, so forks are O(1) until divergence.

        Parameters
        ----------
        pages : list of int
            Live page ids (refcount ≥ 1).
        owner : int, optional
            Owner tag of the forked copy (informational).

        Returns
        -------
        list of int
            The same page ids, now co-owned (balanced by one
            :meth:`free` from the new owner).

        Raises
        ------
        ValueError
            If any page is not currently live — forking a free or
            dormant page would alias dead or index-owned content
            (use :meth:`adopt` for dormant prefix pages).
        """
        for p in pages:
            if p not in self._ref:
                raise ValueError(
                    f"fork of non-live page {p} (refs: {self._ref})"
                )
        if self.sanitizer is not None:
            self.sanitizer.on_fork(pages, owner)
        for p in pages:
            self._ref[p] += 1
        return list(pages)

    def adopt(self, pages: List[int], owner: int = -1) -> List[int]:
        """Acquire index-registered prefix pages on a cache hit.

        Works for both live shared pages (another request still holds
        the prefix — refcount +1) and dormant ones (the prefix outlived
        its last owner — refcount 0 → 1, content still valid).

        Parameters
        ----------
        pages : list of int
            Indexed page ids returned by a prefix-index match.
        owner : int, optional
            Owner tag recorded when reviving a dormant page.

        Returns
        -------
        list of int
            The same page ids, now co-owned by ``owner`` (balanced by
            one :meth:`free`).

        Raises
        ------
        ValueError
            If any page is not index-registered — adopting an arbitrary
            page would alias content the index never vouched for.
        """
        for p in pages:
            if p not in self._indexed:
                raise ValueError(
                    f"adopt of non-indexed page {p} (indexed: "
                    f"{sorted(self._indexed)})"
                )
        if self.sanitizer is not None:
            self.sanitizer.on_adopt(pages, owner)
        for p in pages:
            if p in self._ref:
                self._ref[p] += 1
            else:
                self._ref[p] = 1
                self._owner[p] = owner
        return list(pages)

    def free(self, pages: List[int]) -> None:
        """Drop one ownership reference per page.

        A page whose refcount reaches 0 returns to the free list,
        unless it is index-registered — then it turns dormant (content
        retained for future :meth:`adopt`) until the index evicts it.

        Parameters
        ----------
        pages : list of int
            Page ids previously handed out by :meth:`alloc`,
            :meth:`fork`, or :meth:`adopt`.

        Raises
        ------
        ValueError
            On a double free (including a duplicate page id within one
            call) or a page this allocator never allocated — the error
            fires *before* any state is corrupted.
        """
        if self.sanitizer is not None:
            # validates fully before either side mutates, with journal
            # context the allocator's own error below cannot provide
            self.sanitizer.on_free(pages)
        counts: Dict[int, int] = {}
        for p in pages:
            counts[p] = counts.get(p, 0) + 1
        for p, c in counts.items():
            if self._ref.get(p, 0) < c:
                raise ValueError(
                    f"double free / foreign page {p} x{c} "
                    f"(refs: {self._ref})"
                )
        for p, c in counts.items():
            self._ref[p] -= c
            if self._ref[p] == 0:
                del self._ref[p]
                del self._owner[p]
                if p not in self._indexed:
                    self._free.append(p)

    # -- prefix-index registration -------------------------------------------
    def mark_indexed(self, pages: List[int]) -> None:
        """Register pages as prefix-index residents.

        Indexed pages survive their last :meth:`free` as dormant pages
        instead of returning to the free list.

        Parameters
        ----------
        pages : list of int
            Live page ids being inserted into the radix index.

        Raises
        ------
        ValueError
            If any page is not live — indexing a free page would pin
            dead content.
        """
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"cannot index non-live page {p}")
        if self.sanitizer is not None:
            self.sanitizer.on_mark_indexed(pages)
        self._indexed.update(pages)

    def unmark_indexed(self, pages: List[int]) -> None:
        """Deregister index-evicted pages; dormant ones become free.

        Called by the engine after the radix index evicts entries (LRU,
        under memory pressure).  Pages still live (refcount ≥ 1) merely
        lose their indexed mark and will free normally later.

        Parameters
        ----------
        pages : list of int
            Page ids the index just evicted.

        Raises
        ------
        ValueError
            If any page was not index-registered.
        """
        for p in pages:
            if p not in self._indexed:
                raise ValueError(f"page {p} is not indexed")
        if self.sanitizer is not None:
            self.sanitizer.on_unmark_indexed(pages)
        for p in pages:
            self._indexed.discard(p)
            if p not in self._ref:
                self._free.append(p)

    def owned_by(self, owner: int) -> List[int]:
        """List the pages held under an owner tag.

        With sharing, only the *first* live owner's tag is recorded —
        exclusive pages behave exactly as before; shared pages report
        under whichever owner acquired them first.

        Parameters
        ----------
        owner : int
            The tag passed to :meth:`alloc` / :meth:`adopt`.

        Returns
        -------
        list of int
            Sorted page ids currently tagged with ``owner``.
        """
        return sorted(p for p, o in self._owner.items() if o == owner)

    def check_no_leaks(self, allow_indexed: bool = True) -> None:
        """Assert that every ownership reference has been returned.

        Call when the engine is idle (e.g. at the end of a test or
        after a migration handoff); a failure names the leaked pages.

        Parameters
        ----------
        allow_indexed : bool, optional
            When True (default), dormant prefix-cache pages are not
            leaks — they are accounted (free + dormant must cover the
            pool).  Pass False to additionally require an empty index
            (e.g. after an explicit cache drop).

        Raises
        ------
        AssertionError
            If any page is still live, the accounting does not cover
            the pool, or (with ``allow_indexed=False``) dormant pages
            remain.
        """
        if self.sanitizer is not None:
            self.sanitizer.crosscheck(self)
        if self._ref:
            raise AssertionError(f"leaked pages: {sorted(self._ref)}")
        dormant = self.dormant_pages
        if not allow_indexed and dormant:
            raise AssertionError(
                f"dormant indexed pages remain: "
                f"{sorted(p for p in self._indexed if p not in self._ref)}"
            )
        assert len(self._free) + dormant == self.num_pages - 1

    # -- defrag --------------------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact content-bearing pages (live + dormant) onto low ids.

        The caller must apply the mapping to the physical pools
        (permute page rows), every live block table, and the prefix
        index before the next kernel call.

        Returns
        -------
        dict of int to int
            ``{old_id: new_id}`` for every page that moved (identity
            entries are omitted; empty when already compact).
        """
        live = sorted(set(self._ref) | self._indexed)
        mapping = {old: new for new, old in enumerate(live, start=1)}
        self._owner = {mapping[p]: o for p, o in self._owner.items()}
        self._ref = {mapping[p]: r for p, r in self._ref.items()}
        self._indexed = {mapping[p] for p in self._indexed}
        self._free = list(
            range(self.num_pages - 1, len(live), -1)
        )
        moved = {o: n for o, n in mapping.items() if o != n}
        if self.sanitizer is not None:
            self.sanitizer.on_defrag(moved)
        return moved
