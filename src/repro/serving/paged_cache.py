"""Block-table page allocator for the paged KV cache.

Pages are position-independent fixed-size chunks of KV storage; the
allocator hands out physical page ids and enforces the two invariants
the engine's correctness rests on:

- a page is owned by at most one request at a time (no aliasing);
- every alloc is balanced by exactly one free (no leaks, no double
  frees) — violations raise immediately instead of corrupting caches.

Page 0 is reserved as the *trash page*: padding rows in a decode batch
point their block tables at it, so their (discarded) writes can never
land in a live request's pages.

``defrag`` compacts the allocated set onto the lowest physical page ids
(improving DMA locality after heavy churn) and returns the old→new
mapping so the engine can permute pools and patch block tables.

Live migration composes from these primitives: the source engine
``free``\\ s a request's pages after gathering their contents into a
:class:`~repro.serving.paged_engine.MigrationTicket`, and the
destination ``alloc``\\ s fresh pages to scatter the KV back in — the
invariants above guarantee the handoff can neither leak nor alias.
"""

from __future__ import annotations

from typing import Dict, List, Optional


TRASH_PAGE = 0


class PageAllocator:
    """Free-list allocator over ``num_pages`` pages of ``page_size`` tokens.

    Parameters
    ----------
    num_pages : int
        Total physical pages including the reserved trash page 0;
        must be at least 2.
    page_size : int
        Tokens of KV per page.

    Raises
    ------
    ValueError
        If ``num_pages < 2`` (there would be no allocatable page).
    """

    def __init__(self, num_pages: int, page_size: int) -> None:
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> low ids first
        self._owner: Dict[int, int] = {}  # page id -> owner tag (request id)

    # -- capacity ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Number of pages currently available for allocation.

        Returns
        -------
        int
            Free-list length (the trash page is never counted).
        """
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Number of pages currently owned by requests.

        Returns
        -------
        int
            Allocated page count.
        """
        return len(self._owner)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to store ``n_tokens`` tokens of KV.

        Parameters
        ----------
        n_tokens : int
            Token count (negative values are treated as 0).

        Returns
        -------
        int
            ``ceil(n_tokens / page_size)``.
        """
        return -(-max(0, n_tokens) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        """Check whether ``n`` pages can be allocated atomically.

        Parameters
        ----------
        n : int
            Requested page count.

        Returns
        -------
        bool
            True when the free list holds at least ``n`` pages.
        """
        return n <= len(self._free)

    # -- alloc/free ----------------------------------------------------------
    def alloc(self, n: int, owner: int = -1) -> Optional[List[int]]:
        """Atomically allocate ``n`` pages.

        Parameters
        ----------
        n : int
            Page count; the request is all-or-nothing (no partial
            allocation is ever observable).
        owner : int, optional
            Opaque owner tag recorded per page (typically the sequence
            row); queried via :meth:`owned_by` and reported in error
            messages.

        Returns
        -------
        list of int or None
            The allocated physical page ids (lowest-id-first), or
            ``None`` when the pool cannot satisfy the request.
        """
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: List[int]) -> None:
        """Return pages to the free list.

        Parameters
        ----------
        pages : list of int
            Page ids previously handed out by :meth:`alloc`.

        Raises
        ------
        ValueError
            On a double free or a page this allocator never allocated —
            the error fires *before* any state is corrupted.
        """
        for p in pages:
            if p not in self._owner:
                raise ValueError(
                    f"double free / foreign page {p} (owners: {self._owner})"
                )
            del self._owner[p]
            self._free.append(p)

    def owned_by(self, owner: int) -> List[int]:
        """List the pages held under an owner tag.

        Parameters
        ----------
        owner : int
            The tag passed to :meth:`alloc`.

        Returns
        -------
        list of int
            Sorted page ids currently owned by ``owner``.
        """
        return sorted(p for p, o in self._owner.items() if o == owner)

    def check_no_leaks(self) -> None:
        """Assert that every page has been returned.

        Call when the engine is idle (e.g. at the end of a test or
        after a migration handoff); a failure names the leaked pages.

        Raises
        ------
        AssertionError
            If any page is still owned.
        """
        if self._owner:
            raise AssertionError(f"leaked pages: {sorted(self._owner)}")
        assert len(self._free) == self.num_pages - 1

    # -- defrag --------------------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact allocated pages onto the lowest ids.

        The caller must apply the mapping to both the physical pools
        (permute page rows) and every live block table before the next
        kernel call.

        Returns
        -------
        dict of int to int
            ``{old_id: new_id}`` for every page that moved (identity
            entries are omitted; empty when already compact).
        """
        live = sorted(self._owner)
        mapping = {old: new for new, old in enumerate(live, start=1)}
        self._owner = {mapping[p]: o for p, o in self._owner.items()}
        self._free = list(
            range(self.num_pages - 1, len(live), -1)
        )
        return {o: n for o, n in mapping.items() if o != n}
