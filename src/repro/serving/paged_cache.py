"""Block-table page allocator for the paged KV cache.

Pages are position-independent fixed-size chunks of KV storage; the
allocator hands out physical page ids and enforces the two invariants
the engine's correctness rests on:

- a page is owned by at most one request at a time (no aliasing);
- every alloc is balanced by exactly one free (no leaks, no double
  frees) — violations raise immediately instead of corrupting caches.

Page 0 is reserved as the *trash page*: padding rows in a decode batch
point their block tables at it, so their (discarded) writes can never
land in a live request's pages.

``defrag`` compacts the allocated set onto the lowest physical page ids
(improving DMA locality after heavy churn) and returns the old→new
mapping so the engine can permute pools and patch block tables.
"""

from __future__ import annotations

from typing import Dict, List, Optional


TRASH_PAGE = 0


class PageAllocator:
    """Free-list allocator over ``num_pages`` pages of ``page_size`` tokens."""

    def __init__(self, num_pages: int, page_size: int) -> None:
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> low ids first
        self._owner: Dict[int, int] = {}  # page id -> owner tag (request id)

    # -- capacity ------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._owner)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(0, n_tokens) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc/free ----------------------------------------------------------
    def alloc(self, n: int, owner: int = -1) -> Optional[List[int]]:
        """Atomically allocate ``n`` pages; None if the pool can't satisfy."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._owner:
                raise ValueError(
                    f"double free / foreign page {p} (owners: {self._owner})"
                )
            del self._owner[p]
            self._free.append(p)

    def owned_by(self, owner: int) -> List[int]:
        return sorted(p for p, o in self._owner.items() if o == owner)

    def check_no_leaks(self) -> None:
        """All pages free (call when the engine is idle)."""
        if self._owner:
            raise AssertionError(f"leaked pages: {sorted(self._owner)}")
        assert len(self._free) == self.num_pages - 1

    # -- defrag --------------------------------------------------------------
    def defrag(self) -> Dict[int, int]:
        """Compact allocated pages onto the lowest ids; returns {old: new}.

        The caller must apply the mapping to both the physical pools
        (permute page rows) and every live block table before the next
        kernel call.
        """
        live = sorted(self._owner)
        mapping = {old: new for new, old in enumerate(live, start=1)}
        self._owner = {mapping[p]: o for p, o in self._owner.items()}
        self._free = list(
            range(self.num_pages - 1, len(live), -1)
        )
        return {o: n for o, n in mapping.items() if o != n}
