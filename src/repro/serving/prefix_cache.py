"""Radix index over shared KV-cache prompt prefixes (SGLang-style).

Compound LLM applications re-feed identical prefixes constantly: every
stage of a job shares the application's system prompt, sibling tasks of
one stage share the stage prompt, and repeated jobs of one app template
share everything but a small suffix.  Re-prefilling those tokens wastes
both compute (the prefill FLOPs) and memory (duplicate KV pages).

:class:`RadixPrefixIndex` maps **token blocks** — page-sized runs of
prompt tokens — to the physical KV pages that already hold their K/V.
It is a radix tree with one page per node: a child edge is keyed by the
tuple of ``page_size`` tokens the page stores, so a root-to-node path
spells out a prompt prefix in whole pages.  Only *full* prompt pages
are ever indexed (a partially-filled page's content would change as its
owner decodes, invalidating the key).

The index stores page **ids**, never refcounts — ownership lives in the
:class:`~repro.serving.paged_cache.PageAllocator`.  The contract with
the engine:

- ``match(prompt)`` returns the longest chain of indexed pages whose
  token blocks prefix the prompt; the engine ``adopt``\\ s them
  (refcount +1) and skips their tokens during chunked prefill;
- ``insert(prompt, pages)`` registers a finished prefill's full prompt
  pages; already-present blocks keep their existing page (first writer
  wins), and the engine ``mark_indexed``\\ s only the newly registered
  ones;
- ``evict(...)`` pops least-recently-used **leaf** entries whose pages
  have no live owner (refcount 0 — dormant), so eviction can never pull
  a page out from under a running request, and interior prefixes stay
  connected;
- ``remap(mapping)`` renumbers pages after an allocator ``defrag``.
"""

from __future__ import annotations

import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class _Node:
    """One indexed page: a token-block edge in the radix tree."""

    __slots__ = ("block", "page", "children", "parent", "last_use", "seq")

    def __init__(
        self,
        block: Optional[Tuple[int, ...]],
        page: int,
        parent: Optional["_Node"],
        seq: int = 0,
    ) -> None:
        self.block = block          # page_size-token key (None at the root)
        self.page = page            # physical page id (-1 at the root)
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_use = 0
        self.seq = seq              # creation order: deterministic LRU ties


class RadixPrefixIndex:
    """Token-block radix tree mapping prompt prefixes to KV page lists.

    Parameters
    ----------
    page_size : int
        Tokens per KV page; blocks are keyed at this granularity.
    """

    def __init__(self, page_size: int) -> None:
        self.page_size = int(page_size)
        self._root = _Node(None, -1, None)
        self._clock = 0                      # logical LRU time
        self._seq = 0                        # node-creation counter
        self._n_pages = 0
        self.hits = 0                        # match() calls that found >=1 page
        self.hit_tokens = 0                  # cumulative tokens matched
        self.evictions = 0                   # pages evicted under pressure

    # -- capacity ------------------------------------------------------------
    @property
    def cached_pages(self) -> int:
        """Number of pages currently registered in the index.

        Returns
        -------
        int
            Indexed page count (live + dormant alike).
        """
        return self._n_pages

    @property
    def cached_tokens(self) -> int:
        """Tokens of reusable prefix KV currently resident.

        This is the per-replica "prefix-hit estimate" the scheduler's
        cache-aware placement term consumes.

        Returns
        -------
        int
            ``cached_pages × page_size``.
        """
        return self._n_pages * self.page_size

    # -- blocks --------------------------------------------------------------
    def _blocks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        ps = self.page_size
        n = len(tokens) // ps                # full blocks only
        return [tuple(tokens[i * ps:(i + 1) * ps]) for i in range(n)]

    # -- match ---------------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest-prefix match of ``tokens`` against indexed blocks.

        Parameters
        ----------
        tokens : sequence of int
            The prompt; only its full page-sized blocks participate.

        Returns
        -------
        list of int
            Physical page ids of the matched prefix, outermost first
            (possibly empty).  Matched nodes' LRU stamps are refreshed
            root-to-leaf so a match protects the whole chain.  The
            ``hits``/``hit_tokens`` statistics are *not* bumped here —
            an admission that later fails would inflate them once per
            retry; the engine calls :meth:`record_hit` only when the
            matched pages are actually adopted.
        """
        self._clock += 1
        node = self._root
        pages: List[int] = []
        for block in self._blocks(tokens):
            child = node.children.get(block)
            if child is None:
                break
            child.last_use = self._clock
            pages.append(child.page)
            node = child
        return pages

    def record_hit(self, n_pages: int) -> None:
        """Count one successful prefix adoption of ``n_pages`` pages.

        Parameters
        ----------
        n_pages : int
            Pages adopted (0 is ignored).
        """
        if n_pages > 0:
            self.hits += 1
            self.hit_tokens += n_pages * self.page_size

    # -- insert --------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> List[int]:
        """Register a prefilled prompt's full pages under their blocks.

        Parameters
        ----------
        tokens : sequence of int
            The full prompt whose prefill just completed.
        pages : sequence of int
            The request's physical pages for the prompt's full blocks,
            in order (``len(tokens) // page_size`` entries; extra
            entries are ignored).

        Returns
        -------
        list of int
            Page ids *newly* registered by this call — the engine must
            ``mark_indexed`` exactly these.  Blocks already present
            keep their existing page (first writer wins), which is
            loss-free because identical tokens at identical positions
            produce identical KV.
        """
        self._clock += 1
        node = self._root
        fresh: List[int] = []
        for block, page in zip(self._blocks(tokens), pages):
            child = node.children.get(block)
            if child is None:
                self._seq += 1
                child = _Node(block, int(page), node, seq=self._seq)
                node.children[block] = child
                self._n_pages += 1
                fresh.append(int(page))
            child.last_use = self._clock
            node = child
        return fresh

    # -- evict ---------------------------------------------------------------
    def evict(
        self,
        max_pages: int,
        evictable: Callable[[int], bool],
    ) -> List[int]:
        """Pop up to ``max_pages`` LRU leaf entries with dormant pages.

        Parameters
        ----------
        max_pages : int
            Upper bound on pages to evict this call.
        evictable : callable
            ``page_id -> bool``; typically
            ``lambda p: allocator.refcount(p) == 0`` so pages still
            owned by a live request are never pulled.

        Returns
        -------
        list of int
            Evicted page ids, LRU-first.  The engine must
            ``unmark_indexed`` them to return them to the free list.
        """
        # one tree walk builds the leaf frontier; evicting a leaf may
        # promote its parent into the frontier, so the whole call is
        # O(nodes + evicted·log leaves) instead of a rescan per page
        heap = [
            (n.last_use, n.seq, n)
            for n in self._iter_nodes()
            if not n.children
        ]
        heapq.heapify(heap)
        out: List[int] = []
        while heap and len(out) < max_pages:
            _, _, victim = heapq.heappop(heap)
            if not evictable(victim.page):
                continue  # pinned by a live owner; blocks its ancestors
            del victim.parent.children[victim.block]
            self._n_pages -= 1
            out.append(victim.page)
            parent = victim.parent
            if parent is not self._root and not parent.children:
                heapq.heappush(heap, (parent.last_use, parent.seq, parent))
        self.evictions += len(out)
        return out

    def _iter_nodes(self):
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    # -- maintenance ---------------------------------------------------------
    def remap(self, mapping: Dict[int, int]) -> None:
        """Renumber pages after an allocator defrag.

        Parameters
        ----------
        mapping : dict of int to int
            ``{old_id: new_id}`` as returned by
            :meth:`~repro.serving.paged_cache.PageAllocator.defrag`;
            pages absent from the mapping kept their id.
        """
        for n in self._iter_nodes():
            n.page = mapping.get(n.page, n.page)

    def drop(self) -> List[int]:
        """Clear the whole index (e.g. before a weight swap).

        Returns
        -------
        list of int
            Every page id that was registered; the engine must
            ``unmark_indexed`` them all.
        """
        pages = [n.page for n in self._iter_nodes()]
        self._root.children.clear()
        self._n_pages = 0
        return pages
