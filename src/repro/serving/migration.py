"""Cross-replica live-migration policy (Llumnix-style rebalancing).

The mechanism lives in :class:`~repro.serving.paged_engine.PagedLLMEngine`
(:meth:`export_request` / :meth:`import_request`, a lossless KV handoff
through each engine's :class:`~repro.serving.paged_cache.PageAllocator`);
this module supplies the *policy*: when to move which request where.

:class:`Rebalancer` watches a fleet of paged replicas and migrates the
youngest decoding request away from replicas that are KV-starved —
evicted requests stuck in ``waiting``, or free pages below a watermark —
onto the peer with the most headroom.  Moving the youngest request
mirrors the engines' LIFO eviction order: the request the source would
sacrifice next is exactly the one worth relocating, turning a would-be
recompute restart (lost tokens, repeated prefill) into a zero-loss move.

A hysteresis margin keeps a migrated request from ping-ponging back: the
destination must be strictly healthier than the source *after* paying
for the incoming pages.
"""

from __future__ import annotations

from typing import List, Sequence

from .paged_engine import PagedLLMEngine


def migrate_request(
    src: PagedLLMEngine, dst: PagedLLMEngine, row: int
) -> bool:
    """Move one decoding request from ``src`` to ``dst``.

    Exports the request's KV pages from ``src`` (dropping its
    references there) and imports them into ``dst``; a successful move
    is lossless — the greedy continuation is token-for-token identical.
    If the destination refuses at the last moment, the ticket is
    re-imported into the source; should even that fail (with prefix
    sharing, pages the export released may have stayed alive for
    co-owners, so the free list did not necessarily regrow by
    ``n_pages``), the request falls back to a recompute-style restart
    in the source's ``waiting`` queue — decode progress is lost in that
    corner, but the request never is, and no allocator leaks either
    way.

    Parameters
    ----------
    src : PagedLLMEngine
        Source replica; ``row`` must be decoding there.
    dst : PagedLLMEngine
        Destination replica; must share ``page_size``, model config,
        and weights with ``src``.
    row : int
        The sequence row to move.

    Returns
    -------
    bool
        True when the request now runs on ``dst``; False when the
        destination is incompatible/full or the move was rolled back
        onto ``src``.
    """
    # geometry/model compatibility first: export only once the ticket is
    # guaranteed importable somewhere, so a request can never be stranded
    if (
        dst.page_size != src.page_size
        or dst.cfg.name != src.cfg.name
        or dst.max_len < src.max_len
    ):
        return False
    need = len(src.seq_pages[row])
    if not dst.can_accept_migration(need):
        return False
    ticket = src.export_request(row)
    if dst.import_request(ticket):
        return True
    # Destination raced out of capacity between check and import: put the
    # request back where it came from.  With prefix sharing the pages it
    # freed may have stayed alive for co-owners (refcount > 1 entries in
    # ticket.page_refcounts), so the rollback import can itself fail — in
    # that case fall back to a recompute-style restart on the source: the
    # decode progress is lost but the request never is.
    if src.import_request(ticket):
        src.migrations_in -= 1   # a rollback is not a real migration
        src.migrations_out -= 1
        return False
    ticket.req.out_tokens.clear()
    ticket.req.started_at = -1.0
    src.waiting.appendleft(ticket.req)
    src.preemptions += 1
    src.migrations_out -= 1
    return False


class Rebalancer:
    """Detect overloaded replicas and live-migrate requests off them.

    Parameters
    ----------
    engines : sequence of PagedLLMEngine
        The replica fleet.  Non-paged engines (no allocator) are
        ignored — slot engines cannot hand their KV over.
    low_watermark : float, optional
        A replica is *pressured* when its free-page fraction drops to
        this level or below (or when evicted requests sit in its
        ``waiting`` queue — the strongest starvation signal).
    hysteresis_pages : int, optional
        The destination must keep this many pages free *after*
        absorbing the migrated request and still be better off than the
        source, preventing ping-pong.
    max_moves_per_step : int, optional
        Migration budget per :meth:`step` call (migration gathers KV to
        host memory; bounding it keeps the decode loop responsive).
    """

    def __init__(
        self,
        engines: Sequence[PagedLLMEngine],
        low_watermark: float = 0.25,
        hysteresis_pages: int = 2,
        max_moves_per_step: int = 1,
    ) -> None:
        self.engines: List[PagedLLMEngine] = [
            e for e in engines if hasattr(e, "allocator")
        ]
        self.low_watermark = float(low_watermark)
        self.hysteresis_pages = int(hysteresis_pages)
        self.max_moves_per_step = int(max_moves_per_step)
        self.migrations = 0

    def pressured(self, eng: PagedLLMEngine) -> bool:
        """Check whether a replica needs relief.

        Parameters
        ----------
        eng : PagedLLMEngine
            The replica to inspect.

        Returns
        -------
        bool
            True when evicted requests are queued on it, or its free
            pages are at/below the low watermark of its pool.
        """
        if eng.waiting:
            return True
        total = max(1, eng.num_pages - 1)
        # dormant prefix pages are reclaimable headroom, not pressure
        free = eng.allocator.free_pages + eng.allocator.dormant_pages
        return free <= self.low_watermark * total

    def step(self) -> int:
        """Run one rebalancing pass over the fleet.

        For each pressured replica (most-starved first), try to move
        its youngest decoding request to the peer with the most free
        pages, subject to the hysteresis margin.

        Returns
        -------
        int
            Number of migrations performed this pass (also accumulated
            into :attr:`migrations`).
        """
        if len(self.engines) < 2:
            return 0
        moves = 0
        order = sorted(self.engines, key=lambda e: e.allocator.free_pages)
        for src in order:
            if moves >= self.max_moves_per_step:
                break
            if not self.pressured(src):
                continue
            row = src.youngest_active_row()
            if row is None:
                continue
            # +1 page: the request will grow on arrival; do not migrate
            # onto a destination that would immediately evict it.
            need = len(src.seq_pages[row]) + 1
            best = None
            src_free = (
                src.allocator.free_pages + src.allocator.dormant_pages
            )
            for dst in self.engines:
                if dst is src or not dst.can_accept_migration(need):
                    continue
                # dormant prefix pages are reclaimable headroom on both
                # sides of the comparison (0 without a prefix cache), so
                # a cache-warm destination is not scored as full
                after = (
                    dst.allocator.free_pages
                    + dst.allocator.dormant_pages
                    - need
                )
                if after < self.hysteresis_pages:
                    continue
                if after <= src_free:
                    continue  # destination would end up no healthier
                if best is None or after > best[0]:
                    best = (after, dst)
            if best is None:
                continue
            if migrate_request(src, best[1], row):
                moves += 1
        self.migrations += moves
        return moves
