"""Paged KV-cache continuous-batching engine (the vLLM-style executor).

Replaces the slot engine's ``max_batch`` pre-allocated dense caches with
a pooled page store + per-request block tables:

- **admission** is capacity-based: a request is admitted when a sequence
  row is free AND the page pool can hold its prompt plus one decode
  token — not when a whole ``max_len`` slot is free, so the realistic
  concurrency is bounded by *actual* KV usage, not worst-case reservation;
- **chunked prefill**: prompts are processed ``prefill_chunk`` tokens per
  engine step, interleaved with decode, so a long prompt never stalls
  every running decode stream;
- **decode** batches all running rows each step (padded to a power-of-two
  bucket so JIT shapes stay stable; padding rows write to the reserved
  trash page) through the Pallas paged-attention kernel;
- **preemption-by-eviction**: when decode needs a fresh page and the pool
  is dry, the youngest request is evicted — its pages freed, its request
  requeued for recompute-style restart — so older requests always run to
  completion (no livelock, matching vLLM's LIFO recompute policy);
- the measured per-batch-size step latency keeps feeding the Eq. 2
  batching-aware calibration profile exactly like the slot engine.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import init_params
from ..models.config import ModelConfig
from ..models.paged import (
    init_paged_pools,
    paged_decode_step,
    paged_prefill_chunk,
    supports_paged,
)
from .engine import LatencyProfileMixin, Request
from .paged_cache import PageAllocator, TRASH_PAGE


def _bucket(b: int, cap: int) -> int:
    """Smallest power of two >= b (capped): stable JIT decode shapes."""
    out = 1
    while out < b:
        out *= 2
    return min(out, cap)


class PagedLLMEngine(LatencyProfileMixin):
    """One LLM executor with continuous batching over paged KV."""

    def __init__(
        self,
        cfg: ModelConfig,
        max_seqs: int = 32,
        max_len: int = 256,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        seed: int = 0,
        params: Optional[Any] = None,
        greedy: bool = True,
        prefill_chunk: int = 64,
    ) -> None:
        if not supports_paged(cfg):
            raise ValueError(
                f"config {cfg.name!r} is not paged-KV compatible; "
                "use the slot LLMEngine"
            )
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = -(-max_len // page_size)
        if num_pages is None:
            num_pages = 1 + max_seqs * self.pages_per_seq  # no oversubscription
        if num_pages - 1 < self.pages_per_seq:
            raise ValueError(
                "page pool smaller than one max_len sequence: "
                f"{num_pages - 1} < {self.pages_per_seq} pages"
            )
        self.num_pages = num_pages
        self.greedy = greedy
        self.prefill_chunk = prefill_chunk
        key = jax.random.key(seed)
        self.params = params if params is not None else init_params(cfg, key)[0]

        self.allocator = PageAllocator(num_pages, page_size)
        self.pools = init_paged_pools(cfg, num_pages, page_size)
        self.block_tables = np.full(
            (max_seqs, self.pages_per_seq), TRASH_PAGE, np.int32
        )
        self.lengths = np.zeros((max_seqs,), np.int64)
        self._tokens = np.zeros((max_seqs,), np.int32)
        self.seq_pages: Dict[int, List[int]] = {}
        self.free_rows: List[int] = list(range(max_seqs))
        self.active: Dict[int, Request] = {}       # row -> decoding request
        self.prefilling: Dict[int, Tuple[Request, int]] = {}  # row -> (req, pos)
        self.waiting: Deque[Request] = deque()     # evicted, awaiting re-admit
        self.preemptions = 0
        self._admit_seq = 0
        self._row_seq: Dict[int, int] = {}
        self._init_latency()

        # donate the pools so each step updates KV in place instead of
        # copying the whole pool (CPU ignores donation and would warn)
        self._donate = (1,) if jax.default_backend() != "cpu" else ()
        self._decode = jax.jit(
            lambda p, pools, toks, bt, lens: paged_decode_step(
                p, cfg, pools, toks, bt, lens
            ),
            donate_argnums=self._donate,
        )
        self._prefill_cache: Dict[int, Callable] = {}

    # -- admission ----------------------------------------------------------
    @property
    def batch_size(self) -> int:
        return len(self.active) + len(self.prefilling)

    @property
    def max_batch(self) -> int:
        return self.max_seqs

    @property
    def free_token_capacity(self) -> int:
        """Tokens of KV the pool can still hold (drives placement)."""
        return self.allocator.free_pages * self.page_size

    def can_admit(self) -> bool:
        return (
            not self.waiting
            and bool(self.free_rows)
            and self.allocator.can_alloc(1)
        )

    def admit(self, req: Request) -> bool:
        """Capacity-based admission: refuse when the page pool is exhausted."""
        if self.waiting:  # evicted requests re-enter first
            return False
        return self._place(req)

    def _place(self, req: Request) -> bool:
        plen = len(req.prompt)
        if plen + 1 > self.pages_per_seq * self.page_size:
            raise ValueError(f"prompt of {plen} tokens exceeds max_len")
        need = self.allocator.pages_for(plen + 1)
        if not self.free_rows or not self.allocator.can_alloc(need):
            return False
        row = self.free_rows.pop(0)
        pages = self.allocator.alloc(need, owner=row)
        assert pages is not None
        self.seq_pages[row] = pages
        self.block_tables[row] = TRASH_PAGE
        self.block_tables[row, : len(pages)] = pages
        self.lengths[row] = 0
        self.prefilling[row] = (req, 0)
        self._admit_seq += 1
        self._row_seq[row] = self._admit_seq
        return True

    # -- eviction -----------------------------------------------------------
    def _evict_row(self, row: int) -> None:
        req = self.active.pop(row, None)
        if req is None:
            req, _ = self.prefilling.pop(row)
        # recompute-style restart: generated tokens are discarded
        req.out_tokens.clear()
        req.started_at = -1.0
        self.waiting.appendleft(req)
        self._release_row(row)
        self.preemptions += 1

    def _evict_for(self, row: int) -> bool:
        """Make room for ``row``: evict the youngest row *younger than*
        ``row``; if none exists, ``row`` itself is evicted (it is the
        youngest).  Strict age order means the oldest request always
        makes progress — mutual-eviction livelock is impossible.
        Returns False when ``row`` itself was evicted."""
        younger = [
            r for r in self._row_seq
            if r != row and self._row_seq[r] > self._row_seq[row]
        ]
        victim = max(younger, key=lambda r: self._row_seq[r]) if younger else row
        self._evict_row(victim)
        return victim != row

    def _release_row(self, row: int) -> None:
        self.allocator.free(self.seq_pages.pop(row))
        self.block_tables[row] = TRASH_PAGE
        self.lengths[row] = 0
        del self._row_seq[row]
        self.free_rows.append(row)

    def _grow(self, row: int) -> bool:
        """Ensure the page holding position ``lengths[row]`` exists.
        Returns False when ``row`` itself had to be evicted (it was the
        youngest and the pool is dry); a lone row can always grow
        because the pool holds at least one full max_len sequence."""
        pi = int(self.lengths[row]) // self.page_size
        while pi >= len(self.seq_pages[row]):
            pages = self.allocator.alloc(1, owner=row)
            if pages is None:
                if not self._evict_for(row):
                    return False
                continue
            self.seq_pages[row].append(pages[0])
            self.block_tables[row, len(self.seq_pages[row]) - 1] = pages[0]
        return True

    # -- prefill ------------------------------------------------------------
    def _prefill_fn(self, past: int) -> Callable:
        fn = self._prefill_cache.get(past)
        if fn is None:
            fn = jax.jit(
                lambda p, pools, toks, bt: paged_prefill_chunk(
                    p, self.cfg, pools, toks, bt, past
                ),
                donate_argnums=self._donate,
            )
            self._prefill_cache[past] = fn
        return fn

    def _run_prefill(self, budget: int) -> None:
        """Advance prompt processing by up to ``budget`` tokens.

        A row's chunk is never truncated by leftover budget — chunks are
        either full ``prefill_chunk`` or a prompt's final remainder, so
        ``past`` offsets stay multiples of ``prefill_chunk`` and the jit
        specializations stay bounded (per chunk index + per distinct
        final-remainder length) instead of one per arbitrary offset.
        """
        for row in sorted(self.prefilling, key=lambda r: self._row_seq[r]):
            if budget <= 0:
                break
            req, pos = self.prefilling[row]
            plen = len(req.prompt)
            chunk = min(self.prefill_chunk, plen - pos)
            if chunk > budget:
                break
            toks = jnp.asarray([req.prompt[pos : pos + chunk]], jnp.int32)
            bt = jnp.asarray(self.block_tables[row], jnp.int32)
            logits, self.pools = self._prefill_fn(pos)(
                self.params, self.pools, toks, bt
            )
            pos += chunk
            budget -= chunk
            if pos == plen:
                first = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(first)
                req.started_at = time.perf_counter()
                self._tokens[row] = first
                self.lengths[row] = plen
                del self.prefilling[row]
                self.active[row] = req
            else:
                self.prefilling[row] = (req, pos)

    # -- decode loop --------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine iteration: admit ← waiting, prefill a chunk, decode
        one token for every running request.  Returns finished requests."""
        while self.waiting and self.free_rows:
            req = self.waiting[0]
            if not self._place(req):
                break
            self.waiting.popleft()
        if self.prefilling:
            self._run_prefill(self.prefill_chunk)
        if not self.active:
            return []

        # page growth (may evict); iterate oldest-first so eviction of a
        # younger row cannot starve an older one
        for row in sorted(self.active, key=lambda r: self._row_seq[r]):
            if row in self.active:  # may have been evicted by a prior grow
                self._grow(row)
        if not self.active:
            return []

        rows = sorted(self.active, key=lambda r: self._row_seq[r])
        b = len(rows)
        bucket = _bucket(b, self.max_seqs)
        idx = rows + [rows[0]] * (bucket - b)   # pad shape; padding masked below
        toks = np.asarray(self._tokens[idx], np.int32)
        bt = np.asarray(self.block_tables[idx], np.int32)
        lens = np.asarray(self.lengths[idx], np.int32)
        # padding rows: length 0, trash block table — writes land in page 0
        if bucket > b:
            toks[b:] = 0
            bt[b:] = TRASH_PAGE
            lens[b:] = 0

        t0 = time.perf_counter()
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(toks), jnp.asarray(bt),
            jnp.asarray(lens),
        )
        logits = np.asarray(jax.device_get(logits))
        self.record_latency(b, time.perf_counter() - t0)

        finished: List[Request] = []
        for i, row in enumerate(rows):
            req = self.active[row]
            nxt = int(np.argmax(logits[i]))
            req.out_tokens.append(nxt)
            self._tokens[row] = nxt
            self.lengths[row] += 1
            limit = (
                len(req.out_tokens) >= req.max_new_tokens
                or (req.stop_token is not None and nxt == req.stop_token)
                or int(self.lengths[row]) >= self.max_len - 2
            )
            if limit:
                req.finished_at = time.perf_counter()
                finished.append(req)
                del self.active[row]
                self._release_row(row)
                if req.on_finish:
                    req.on_finish(req)
        return finished

    # -- maintenance --------------------------------------------------------
    def defrag(self) -> int:
        """Compact live pages onto low ids; returns #pages moved."""
        mapping = self.allocator.defrag()
        if not mapping:
            return 0
        perm = np.arange(self.num_pages)
        for old, new in mapping.items():
            perm[new] = old
        perm_j = jnp.asarray(perm)
        self.pools = jax.tree.map(
            lambda pool: pool[:, perm_j], self.pools["blocks"], is_leaf=None
        )
        self.pools = {"blocks": self.pools}
        for row, pages in self.seq_pages.items():
            self.seq_pages[row] = [mapping.get(p, p) for p in pages]
            self.block_tables[row] = TRASH_PAGE
            self.block_tables[row, : len(self.seq_pages[row])] = self.seq_pages[row]
        return len(mapping)
