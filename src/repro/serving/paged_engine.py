"""Paged KV-cache continuous-batching engine (the vLLM-style executor).

Replaces the slot engine's ``max_batch`` pre-allocated dense caches with
a pooled page store + per-request block tables:

- **admission** is capacity-based: a request is admitted when a sequence
  row is free AND the page pool can hold its prompt plus one decode
  token — not when a whole ``max_len`` slot is free, so the realistic
  concurrency is bounded by *actual* KV usage, not worst-case reservation;
- **chunked prefill**: prompts are processed ``prefill_chunk`` tokens per
  engine step, interleaved with decode, so a long prompt never stalls
  every running decode stream;
- **decode** batches all running rows each step (padded to a power-of-two
  bucket so JIT shapes stay stable; padding rows write to the reserved
  trash page) through the Pallas paged-attention kernel;
- **preemption-by-eviction**: when decode needs a fresh page and the pool
  is dry, the youngest request is evicted — its pages freed, its request
  requeued for recompute-style restart — so older requests always run to
  completion (no livelock, matching vLLM's LIFO recompute policy);
- **shared-prefix KV reuse** (``prefix_cache=True``): finished prefills
  register their full prompt pages in a
  :class:`~repro.serving.prefix_cache.RadixPrefixIndex`; admission
  longest-prefix-matches the incoming prompt, ``adopt``\\ s the cached
  pages (refcount +1, zero copies) and starts chunked prefill after
  them, so shared system prompts and repeated compound-app stages pay
  prefill once per replica instead of once per request.  Writes into a
  shared or indexed page copy-on-write first, refcount-0 prefix pages
  are evicted LRU under memory pressure (before any live request is
  preempted), and greedy decode output is token-for-token identical to
  the cacheless engine;
- **live migration** (Llumnix-style): a *decoding* request can be packed
  into a :class:`MigrationTicket` — its KV pages gathered to host memory,
  freed on the source — and resumed on a peer engine that allocates fresh
  pages and scatters the KV back in.  Because the KV content is moved
  bit-for-bit and greedy decode is deterministic, the migrated request
  continues token-for-token as if it had never moved (no recompute, no
  lost progress);
- the measured per-batch-size step latency keeps feeding the Eq. 2
  batching-aware calibration profile exactly like the slot engine.
"""

from __future__ import annotations

import itertools
import math
import os
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.paged_attention import (
    check_block_table_bounds,
    check_scale_pool_finite,
)
from ..models import init_params
from ..models.config import ModelConfig
from ..models.paged import (
    KV_DTYPES,
    init_paged_pools,
    paged_decode_step,
    paged_prefill_chunk,
    supports_paged,
)
from .engine import LatencyProfileMixin, Request
from .paged_cache import PageAllocator, TRASH_PAGE
from .prefix_cache import RadixPrefixIndex


# Fleet-global admission stamp: comparable across engines so migrated
# requests keep their original age in any waiting queue they land in.
_ARRIVAL_SEQ = itertools.count()


def _bucket(b: int, cap: int) -> int:
    """Smallest power of two >= b (capped): stable JIT decode shapes."""
    out = 1
    while out < b:
        out *= 2
    return min(out, cap)


@dataclass
class MigrationTicket:
    """Self-contained handoff state of one mid-decode request.

    Produced by :meth:`PagedLLMEngine.export_request` and consumed by
    :meth:`PagedLLMEngine.import_request`.  Holding a ticket means
    holding the *only* copy of the request's KV: the source engine has
    already returned its pages to its allocator, so a dropped ticket
    loses decode progress (the request itself can still be restarted
    recompute-style).

    Attributes
    ----------
    req : Request
        The in-flight request, including tokens generated so far.
    last_token : int
        The most recent greedy token — the next decode step's input.
    length : int
        Tokens currently materialized in the KV cache (prompt + decoded).
    kv : dict
        ``{layer_pattern_pos: {"k"|"v": ndarray}}`` — per-layer KV of
        the owned pages, shape ``(n_sb, n_pages, page_size, K, hd)``,
        gathered to host memory in block-table order.  On int8-KV
        engines the dict additionally carries the per-page scale pools
        (``"k_s"``/``"v_s"``, ``(n_sb, n_pages, page_size, K)``
        float32) — the int8 payload is meaningless without them.
    kv_dtype : str
        Source engine's page storage dtype (``"fp32"`` or ``"int8"``);
        source and destination must agree, else the importer would
        reinterpret the payload bytes.
    n_pages : int
        Number of pages in :attr:`kv` (and to allocate on import).
    page_size : int
        Tokens per page; source and destination must agree.
    max_len : int
        Source engine's per-sequence token limit; the destination's
        must be at least as large, else the continuation could hit the
        destination's length cutoff early and silently truncate.
    model : str
        Source engine's model-config name; replicas must match (live
        migration assumes identical weights on both ends).
    page_refcounts : list of int, optional
        Refcount of each exported page *at export time*, block-table
        order.  Entries > 1 mean the page was a shared prefix page:
        the source kept it alive for its co-owners (or its radix
        index) and the ticket carries a private copy of its content.
        ``None`` on tickets from engines without prefix caching.
    """

    req: Request
    last_token: int
    length: int
    kv: Dict[str, Dict[str, np.ndarray]]
    n_pages: int
    page_size: int
    max_len: int
    model: str
    page_refcounts: Optional[List[int]] = None
    kv_dtype: str = "fp32"


class PagedLLMEngine(LatencyProfileMixin):
    """One LLM executor with continuous batching over paged KV.

    Parameters
    ----------
    cfg : ModelConfig
        Model architecture; must satisfy :func:`supports_paged`.
    max_seqs : int, optional
        Maximum concurrent sequence rows (decode batch bound).
    max_len : int, optional
        Maximum tokens per sequence (prompt + generated).
    page_size : int, optional
        Tokens per KV page.
    num_pages : int, optional
        Physical page-pool size (page 0 is the reserved trash page).
        Defaults to no oversubscription: every row can reach
        ``max_len``.  Smaller pools trade capacity for eviction churn —
        this is the knob heterogeneous replicas differ in.
    seed : int, optional
        Parameter-init seed (ignored when ``params`` is given).
    params : pytree, optional
        Pre-built model weights.  Replicas that participate in live
        migration must share identical weights.
    greedy : bool, optional
        Greedy decoding (the only mode the engines currently use).
    prefill_chunk : int, optional
        Prompt tokens processed per engine step (chunked prefill).
    prefix_cache : bool, optional
        Enable shared-prefix KV reuse: a radix index over full prompt
        pages, adopted copy-free at admission, with copy-on-write on
        divergence and LRU eviction of dormant prefix pages under
        pressure.  Off by default — the cacheless engine is the
        byte-exact historical behaviour.
    kv_dtype : str, optional
        Page storage dtype: ``"fp32"`` (the model's compute dtype —
        byte-identical to the historical engine) or ``"int8"``
        (quantized pages with per-page scale pools dequantized inside
        the kernels — ~4× the KV tokens per byte, tolerance-level
        numerics).  Defaults to the ``REPRO_KV_DTYPE`` environment
        variable, else ``"fp32"``.
    sanitize : bool, optional
        Run the KV-page sanitizer: the allocator mirrors every page
        transition in shadow state, every kernel-bound write and block
        table is ownership-checked (use-after-free, CoW bypass,
        aliasing), decode block tables are bounds-checked against the
        pool, and migration tickets are validated at export.
        Observation-only — clean runs are byte-identical either way.
        Defaults to the ``REPRO_SANITIZE`` environment variable.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        max_seqs: int = 32,
        max_len: int = 256,
        page_size: int = 16,
        num_pages: Optional[int] = None,
        seed: int = 0,
        params: Optional[Any] = None,
        greedy: bool = True,
        prefill_chunk: int = 64,
        prefix_cache: bool = False,
        sanitize: Optional[bool] = None,
        kv_dtype: Optional[str] = None,
    ) -> None:
        if not supports_paged(cfg):
            raise ValueError(
                f"config {cfg.name!r} is not paged-KV compatible; "
                "use the slot LLMEngine"
            )
        if kv_dtype is None:
            kv_dtype = os.environ.get("REPRO_KV_DTYPE", "fp32")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}"
            )
        self.kv_dtype = kv_dtype
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_seq = -(-max_len // page_size)
        if num_pages is None:
            num_pages = 1 + max_seqs * self.pages_per_seq  # no oversubscription
        if num_pages - 1 < self.pages_per_seq:
            raise ValueError(
                "page pool smaller than one max_len sequence: "
                f"{num_pages - 1} < {self.pages_per_seq} pages"
            )
        self.num_pages = num_pages
        self.greedy = greedy
        self.prefill_chunk = prefill_chunk
        key = jax.random.key(seed)
        self.params = params if params is not None else init_params(cfg, key)[0]

        self.allocator = PageAllocator(num_pages, page_size, sanitize=sanitize)
        self._san = self.allocator.sanitizer
        self.pools = init_paged_pools(cfg, num_pages, page_size, kv_dtype)
        self.block_tables = np.full(
            (max_seqs, self.pages_per_seq), TRASH_PAGE, np.int32
        )
        self.lengths = np.zeros((max_seqs,), np.int64)
        self._tokens = np.zeros((max_seqs,), np.int32)
        self.seq_pages: Dict[int, List[int]] = {}
        self.free_rows: List[int] = list(range(max_seqs))
        self.active: Dict[int, Request] = {}       # row -> decoding request
        self.prefilling: Dict[int, Tuple[Request, int]] = {}  # row -> (req, pos)
        self.waiting: Deque[Request] = deque()     # evicted, awaiting re-admit
        self.preemptions = 0
        self.migrations_in = 0                     # requests imported live
        self.migrations_out = 0                    # requests exported live
        self.prefix_index: Optional[RadixPrefixIndex] = (
            RadixPrefixIndex(page_size) if prefix_cache else None
        )
        self.prefill_skipped_tokens = 0            # prompt tokens never re-run
        self.cow_copies = 0                        # copy-on-write page copies
        self._admit_seq = 0
        self._row_seq: Dict[int, int] = {}
        self._init_latency()

        # donate the pools so each step updates KV in place instead of
        # copying the whole pool (CPU ignores donation and would warn)
        self._donate = (1,) if jax.default_backend() != "cpu" else ()
        self._decode = jax.jit(
            lambda p, pools, toks, bt, lens: paged_decode_step(
                p, cfg, pools, toks, bt, lens
            ),
            donate_argnums=self._donate,
        )
        self._prefill_cache: Dict[int, Callable] = {}
        # copy-on-write page duplication (src/dst traced: one compile)
        self._copy_page_jit = jax.jit(
            lambda blocks, src, dst: jax.tree.map(
                lambda arr: arr.at[:, dst].set(arr[:, src]), blocks
            ),
            donate_argnums=(0,) if self._donate else (),
        )

    # -- admission ----------------------------------------------------------
    @property
    def batch_size(self) -> int:
        """Number of requests currently held (decoding + prefilling).

        Returns
        -------
        int
            Active plus prefilling rows; excludes the evicted ``waiting``
            queue.
        """
        return len(self.active) + len(self.prefilling)

    @property
    def max_batch(self) -> int:
        """Maximum concurrent requests (interface parity with the slot engine).

        Returns
        -------
        int
            ``max_seqs``.
        """
        return self.max_seqs

    @property
    def free_token_capacity(self) -> int:
        """Tokens of KV the pool can still hold (drives placement).

        Returns
        -------
        int
            ``free_pages × page_size`` — the per-replica headroom the
            scheduler's placement score and the rebalancer both consult.
        """
        return self.allocator.free_pages * self.page_size

    @property
    def page_bytes(self) -> int:
        """Bytes of pool storage one physical page costs across all layers.

        Counts every pool leaf — K and V for each layer position and
        superblock, plus the per-page scale pools on int8 engines — so
        ``num_pages × page_bytes`` is the engine's true KV footprint.
        This is the unit equal-*byte*-budget comparisons (fig11) and
        ``ServeConfig.kv_budget_bytes`` sizing are denominated in.

        Returns
        -------
        int
            Per-page bytes (pools are shaped ``(n_sb, P, ...)``; the
            page axis is axis 1).
        """
        total = 0
        for pool in self.pools["blocks"].values():
            for arr in pool.values():
                total += arr.nbytes // arr.shape[1]
        return total

    @classmethod
    def pages_for_byte_budget(
        cls,
        cfg: ModelConfig,
        page_size: int,
        budget_bytes: int,
        kv_dtype: str = "fp32",
    ) -> int:
        """Pool size (pages, incl. the trash page) fitting a byte budget.

        The equal-byte-budget counterpart of picking ``num_pages``
        directly: int8 pages cost ~4× fewer bytes each (1-byte payload
        plus 4-byte-per-(slot, kv-head) scales versus the compute
        dtype), so the same budget buys proportionally more pages.

        Parameters
        ----------
        cfg : ModelConfig
            Model architecture (KV geometry and compute dtype).
        page_size : int
            Tokens per page.
        budget_bytes : int
            Total pool storage allowed, in bytes.
        kv_dtype : str, optional
            ``"fp32"`` or ``"int8"``.

        Returns
        -------
        int
            ``budget_bytes // page_bytes`` — how many physical pages
            (trash page included) the budget holds.
        """
        from ..models.transformer import _scan_layout

        _, pat, n_sb = _scan_layout(cfg)
        K, hd = cfg.n_kv_heads, cfg.hd
        if kv_dtype == "int8":
            per_token = K * (hd * 1 + 4) * 2          # int8 k+v, f32 scales
        else:
            per_token = K * hd * jnp.dtype(cfg.jdtype).itemsize * 2
        per_page = pat * n_sb * page_size * per_token
        return int(budget_bytes // per_page)

    @property
    def reclaimable_token_capacity(self) -> int:
        """Tokens of KV held by evictable (dormant) prefix pages.

        Returns
        -------
        int
            ``dormant_pages × page_size`` — headroom recoverable by LRU
            prefix eviction before any live request must be preempted.
        """
        return self.allocator.dormant_pages * self.page_size

    @property
    def prefix_cached_tokens(self) -> Optional[int]:
        """Reusable prefix tokens resident in the radix index.

        This is the per-replica prefix-hit estimate surfaced to the
        scheduler's cache-aware placement term.

        Returns
        -------
        int or None
            ``RadixPrefixIndex.cached_tokens``, or ``None`` when prefix
            caching is disabled (so fleets without caches report no
            cache signal at all and placement degenerates exactly).
        """
        if self.prefix_index is None:
            return None
        return self.prefix_index.cached_tokens

    def can_admit(self) -> bool:
        """Cheap admission pre-filter.

        Returns
        -------
        bool
            True when a row is free, at least one page is free or
            reclaimable from the prefix cache, and no evicted request
            is waiting to re-enter.  :meth:`admit` may still refuse a
            multi-page prompt — callers must handle that.
        """
        return (
            not self.waiting
            and bool(self.free_rows)
            and (
                self.allocator.can_alloc(1)
                or self.allocator.dormant_pages > 0
            )
        )

    def admit(self, req: Request) -> bool:
        """Admit a request if the page pool can hold prompt + 1 token.

        Parameters
        ----------
        req : Request
            The request to admit; its prompt must fit ``max_len``.

        Returns
        -------
        bool
            False when the pool or rows are exhausted, or when evicted
            requests are waiting (they re-enter first — FIFO fairness
            after preemption).  The caller keeps the task pending and
            retries later.
        """
        if self.waiting:  # evicted requests re-enter first
            return False
        return self._place(req)

    def _place(self, req: Request) -> bool:
        plen = len(req.prompt)
        if plen + 1 > self.pages_per_seq * self.page_size:
            raise ValueError(f"prompt of {plen} tokens exceeds max_len")
        if not self.free_rows:
            return False
        need = self.allocator.pages_for(plen + 1)
        row = self.free_rows[0]
        cached: List[int] = []
        if self.prefix_index is not None:
            cached = self.prefix_index.match(req.prompt)
            if cached:
                cached = self.allocator.adopt(cached, owner=row)
        fresh = self._alloc(need - len(cached), owner=row)
        if fresh is None:
            # refusal must leave no partial state behind
            if cached:
                self.allocator.free(cached)
            return False
        self.free_rows.pop(0)
        if req.arrival_seq < 0:  # first placement anywhere in the fleet
            req.arrival_seq = next(_ARRIVAL_SEQ)
        if self.prefix_index is not None:
            self.prefix_index.record_hit(len(cached))
        pages = cached + fresh
        self.seq_pages[row] = pages
        self.block_tables[row] = TRASH_PAGE
        self.block_tables[row, : len(pages)] = pages
        if self._san is not None:
            self._san.note_table(row, pages)
        self.lengths[row] = 0
        # skip prefill over adopted pages, but always re-run at least the
        # last prompt token: its logits seed the first decode step
        start = min(len(cached) * self.page_size, plen - 1)
        self.prefill_skipped_tokens += start
        self.prefilling[row] = (req, start)
        self._admit_seq += 1
        self._row_seq[row] = self._admit_seq
        return True

    # -- page acquisition (prefix-cache aware) -------------------------------
    def _alloc(self, n: int, owner: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh pages, reclaiming LRU prefix pages first.

        Dormant (refcount-0, index-retained) pages are strictly cheaper
        to sacrifice than any live request, so pressure always drains
        the prefix cache before :meth:`_evict_for` considers victims.

        Parameters
        ----------
        n : int
            Page count (0 returns an empty list).
        owner : int
            Owner tag for the allocator.

        Returns
        -------
        list of int or None
            Fresh pages, or ``None`` when even reclaiming cannot
            satisfy the request.
        """
        if n <= 0:
            return []
        pages = self.allocator.alloc(n, owner=owner)
        if pages is None and self._reclaim_prefix(n):
            pages = self.allocator.alloc(n, owner=owner)
        return pages

    def _reclaim_prefix(self, need_free: int) -> bool:
        """Evict LRU dormant prefix pages until ``need_free`` are free.

        Parameters
        ----------
        need_free : int
            Target free-list size.

        Returns
        -------
        bool
            True when any page was reclaimed.
        """
        if self.prefix_index is None:
            return False
        want = need_free - self.allocator.free_pages
        if want <= 0:
            return False
        evicted = self.prefix_index.evict(
            want, lambda p: self.allocator.refcount(p) == 0
        )
        if not evicted:
            return False
        self.allocator.unmark_indexed(evicted)
        return True

    def _copy_page(self, src: int, dst: int) -> None:
        """Copy one physical page's K/V across every layer pool.

        Runs through a jitted, pool-donating updater (page ids are
        traced scalars, so one compilation serves every copy) — an
        O(page) in-place scatter rather than O(pool) host-side array
        rebuilds.
        """
        self.pools = {
            "blocks": self._copy_page_jit(
                self.pools["blocks"], jnp.int32(src), jnp.int32(dst)
            )
        }
        if self._san is not None and self.kv_dtype == "int8":
            self._san.note_scale_copy(src, dst)
        self.cow_copies += 1

    def _ensure_exclusive(self, row: int, pi: int) -> bool:
        """Copy-on-write: make ``seq_pages[row][pi]`` safe to write.

        A page is writable only when this row is its sole owner AND it
        is not index-registered (an indexed page's content must keep
        matching its token-block key).  Otherwise a fresh page is
        allocated — evicting younger rows if the pool is dry — the
        content copied, and this row's reference moved over.

        Parameters
        ----------
        row : int
            The writing sequence row.
        pi : int
            Logical page index within the row's block table.

        Returns
        -------
        bool
            False when ``row`` itself had to be evicted to find room.
        """
        pages = self.seq_pages[row]
        if pi >= len(pages):
            return True                     # not materialized yet (grow's job)
        p = pages[pi]
        a = self.allocator
        if a.refcount(p) == 1 and not a.is_indexed(p):
            return True
        fresh = self._alloc(1, owner=row)
        while fresh is None:
            if not self._evict_for(row):
                return False
            fresh = self._alloc(1, owner=row)
        q = fresh[0]
        self._copy_page(p, q)
        pages[pi] = q
        self.block_tables[row, pi] = q
        if self._san is not None:
            self._san.note_table(row, pages)
        a.free([p])                          # drop our ref on the shared copy
        return True

    # -- eviction -----------------------------------------------------------
    def _evict_row(self, row: int) -> None:
        req = self.active.pop(row, None)
        if req is None:
            req, _ = self.prefilling.pop(row)
        # recompute-style restart: generated tokens are discarded
        req.out_tokens.clear()
        req.started_at = -1.0
        self.waiting.appendleft(req)
        self._release_row(row)
        self.preemptions += 1

    def _evict_for(self, row: int) -> bool:
        """Make room for ``row``: evict the youngest row *younger than*
        ``row``; if none exists, ``row`` itself is evicted (it is the
        youngest).  Strict age order means the oldest request always
        makes progress — mutual-eviction livelock is impossible.
        Returns False when ``row`` itself was evicted."""
        younger = [
            r for r in self._row_seq
            if r != row and self._row_seq[r] > self._row_seq[row]
        ]
        victim = max(younger, key=lambda r: self._row_seq[r]) if younger else row
        self._evict_row(victim)
        return victim != row

    def _release_row(self, row: int) -> None:
        if self._san is not None:
            self._san.drop_table(row)
        self.allocator.free(self.seq_pages.pop(row))
        self.block_tables[row] = TRASH_PAGE
        self.lengths[row] = 0
        del self._row_seq[row]
        self.free_rows.append(row)

    def _grow(self, row: int) -> bool:
        """Ensure the page holding position ``lengths[row]`` exists.
        Returns False when ``row`` itself had to be evicted (it was the
        youngest and the pool is dry); a lone row can always grow
        because the pool holds at least one full max_len sequence."""
        pi = int(self.lengths[row]) // self.page_size
        while pi >= len(self.seq_pages[row]):
            pages = self._alloc(1, owner=row)
            if pages is None:
                if not self._evict_for(row):
                    return False
                continue
            self.seq_pages[row].append(pages[0])
            self.block_tables[row, len(self.seq_pages[row]) - 1] = pages[0]
            if self._san is not None:
                self._san.note_table(row, self.seq_pages[row])
        # the write target must be exclusively ours (a page-aligned shared
        # prompt can leave the boundary page adopted from the index)
        return self._ensure_exclusive(row, pi)

    # -- prefill ------------------------------------------------------------
    def _prefill_fn(self, past: int) -> Callable:
        fn = self._prefill_cache.get(past)
        if fn is None:
            fn = jax.jit(
                lambda p, pools, toks, bt: paged_prefill_chunk(
                    p, self.cfg, pools, toks, bt, past
                ),
                donate_argnums=self._donate,
            )
            self._prefill_cache[past] = fn
        return fn

    def _run_prefill(self, budget: int) -> None:
        """Advance prompt processing by up to ``budget`` tokens.

        A row's chunk is never truncated by leftover budget, and a row
        resuming after a prefix-cache skip realigns to the chunk grid
        with one short first chunk — so ``past`` offsets stay on the
        same boundaries the cacheless engine uses (multiples of
        ``prefill_chunk``, plus one page-aligned resume point per
        distinct cached-prefix length).  That keeps jit specializations
        bounded *and* makes the final chunk of a partially-cached
        prompt bit-identical to the cacheless engine's final chunk,
        which is what the token-for-token differential guarantee
        rests on.
        """
        ps = self.page_size
        for row in sorted(self.prefilling, key=lambda r: self._row_seq[r]):
            if budget <= 0:
                break
            if row not in self.prefilling:
                continue  # evicted while an earlier row made room (CoW)
            req, pos = self.prefilling[row]
            plen = len(req.prompt)
            chunk = min(
                self.prefill_chunk - pos % self.prefill_chunk, plen - pos
            )
            if chunk > budget:
                break
            # copy-on-write before touching any shared/indexed page the
            # chunk will scatter into (adopted page-aligned prefixes)
            ok = True
            for pi in range(pos // ps, (pos + chunk - 1) // ps + 1):
                if not self._ensure_exclusive(row, pi):
                    ok = False
                    break
            if not ok:
                continue  # this row was evicted to make room; retry later
            if self._san is not None:
                for pi in range(pos // ps, (pos + chunk - 1) // ps + 1):
                    self._san.note_write(
                        row, self.seq_pages[row][pi],
                        quantized=self.kv_dtype == "int8",
                    )
            toks = jnp.asarray([req.prompt[pos : pos + chunk]], jnp.int32)
            bt = jnp.asarray(self.block_tables[row], jnp.int32)
            logits, self.pools = self._prefill_fn(pos)(
                self.params, self.pools, toks, bt
            )
            req.prefill_tokens += chunk
            pos += chunk
            budget -= chunk
            if pos == plen:
                first = int(jnp.argmax(logits[0, -1]))
                req.out_tokens.append(first)
                req.started_at = time.perf_counter()
                self._tokens[row] = first
                self.lengths[row] = plen
                del self.prefilling[row]
                self.active[row] = req
                self._register_prefix(row, req)
            else:
                self.prefilling[row] = (req, pos)

    def _register_prefix(self, row: int, req: Request) -> None:
        """Insert a finished prefill's full prompt pages into the index.

        Only pages fully covered by prompt tokens are registered —
        their content is immutable from here on (decode writes land in
        later pages) — and only pages not already present under the
        same token blocks (first writer wins).

        Parameters
        ----------
        row : int
            The row whose prefill just completed.
        req : Request
            Its request (source of the prompt tokens).
        """
        if self.prefix_index is None:
            return
        n_full = len(req.prompt) // self.page_size
        if n_full == 0:
            return
        fresh = self.prefix_index.insert(
            req.prompt, self.seq_pages[row][:n_full]
        )
        if fresh:
            self.allocator.mark_indexed(fresh)

    # -- decode loop --------------------------------------------------------
    def step(self) -> List[Request]:
        """Run one engine iteration.

        Re-admits evicted requests from ``waiting``, advances chunked
        prefill by one chunk budget, grows pages (evicting youngest-first
        when the pool is dry), then decodes one token for every running
        request through the paged-attention kernel.

        Returns
        -------
        list of Request
            Requests that finished this step (budget reached, stop
            token, or ``max_len``); their pages are already freed and
            ``on_finish`` callbacks already fired.
        """
        # deadline-aware re-admission: drain the waiting queue lowest
        # priority-value first, ties broken by *fleet arrival order*
        # (``arrival_seq``), NOT deque position — the deque reflects
        # eviction order (``appendleft``), and after a live migration a
        # younger-arrival request evicted late sits at the head, so a
        # positional tie-break would re-admit it ahead of an older
        # equal-deadline waiter.  With no SLOs anywhere (all priorities
        # inf) single-engine eviction preserves arrival order, so this
        # still degenerates to the historical FIFO ``popleft``
        # byte-for-byte.  Head-of-line blocking on a failed place is
        # intentional: admitting a lower-priority request past a stuck
        # urgent one would hand it the very pages the urgent one needs.
        while self.waiting and self.free_rows:
            req = min(
                self.waiting,
                key=lambda r: (
                    getattr(r, "priority", math.inf),
                    getattr(r, "arrival_seq", -1),
                ),
            )
            if not self._place(req):
                break
            self.waiting.remove(req)
            if self._san is not None:
                self._san.check_edf_drain(
                    getattr(req, "priority", math.inf),
                    [getattr(r, "priority", math.inf) for r in self.waiting],
                )
        if self.prefilling:
            self._run_prefill(self.prefill_chunk)
        if not self.active:
            return []

        # page growth (may evict); iterate oldest-first so eviction of a
        # younger row cannot starve an older one
        for row in sorted(self.active, key=lambda r: self._row_seq[r]):
            if row in self.active:  # may have been evicted by a prior grow
                self._grow(row)
        if not self.active:
            return []

        rows = sorted(self.active, key=lambda r: self._row_seq[r])
        b = len(rows)
        bucket = _bucket(b, self.max_seqs)
        idx = rows + [rows[0]] * (bucket - b)   # pad shape; padding masked below
        toks = np.asarray(self._tokens[idx], np.int32)
        bt = np.asarray(self.block_tables[idx], np.int32)
        lens = np.asarray(self.lengths[idx], np.int32)
        # padding rows: length 0, trash block table — writes land in page 0
        if bucket > b:
            toks[b:] = 0
            bt[b:] = TRASH_PAGE
            lens[b:] = 0

        if self._san is not None:
            # the incoming token writes at position lengths[row]: that
            # page must be exclusively owned, and the whole table must
            # stay inside the pool before the kernel DMAs from it
            check_block_table_bounds(
                bt, lens, self.num_pages, self.page_size, TRASH_PAGE
            )
            if self.kv_dtype == "int8":
                # spot-check one layer's scale pools: a NaN/non-positive
                # scale would multiply *valid* history, not masked slots
                pool0 = self.pools["blocks"]["0"]
                check_scale_pool_finite(
                    np.asarray(jax.device_get(pool0["k_s"][0])),
                    np.asarray(jax.device_get(pool0["v_s"][0])),
                    bt, lens, self.page_size,
                )
            for row in rows:
                pi = int(self.lengths[row]) // self.page_size
                self._san.note_write(
                    row, self.seq_pages[row][pi],
                    quantized=self.kv_dtype == "int8",
                )

        t0 = time.perf_counter()
        logits, self.pools = self._decode(
            self.params, self.pools, jnp.asarray(toks), jnp.asarray(bt),
            jnp.asarray(lens),
        )
        logits = np.asarray(jax.device_get(logits))
        self.record_latency(b, time.perf_counter() - t0)

        finished: List[Request] = []
        for i, row in enumerate(rows):
            req = self.active[row]
            nxt = int(np.argmax(logits[i]))
            req.out_tokens.append(nxt)
            self._tokens[row] = nxt
            self.lengths[row] += 1
            limit = (
                len(req.out_tokens) >= req.max_new_tokens
                or (req.stop_token is not None and nxt == req.stop_token)
                or int(self.lengths[row]) >= self.max_len - 2
            )
            if limit:
                req.finished_at = time.perf_counter()
                finished.append(req)
                del self.active[row]
                self._release_row(row)
                if req.on_finish:
                    req.on_finish(req)
        return finished

    # -- live migration -----------------------------------------------------
    def youngest_active_row(self) -> Optional[int]:
        """Return the most recently admitted *decoding* row.

        The youngest row is the canonical migration candidate: it is the
        row the LIFO eviction policy would sacrifice next, so moving it
        to a peer replica converts a would-be recompute restart into a
        lossless handoff.

        Returns
        -------
        int or None
            Row index, or ``None`` when nothing is decoding.
        """
        if not self.active:
            return None
        return max(self.active, key=lambda r: self._row_seq[r])

    def can_accept_migration(self, n_pages: int) -> bool:
        """Check whether an incoming ticket of ``n_pages`` pages fits.

        Parameters
        ----------
        n_pages : int
            Page count of the candidate :class:`MigrationTicket`.

        Returns
        -------
        bool
            True when a sequence row is free, the pool can hand out
            ``n_pages`` at once (counting LRU-reclaimable dormant
            prefix pages), and the page count fits this engine's
            ``pages_per_seq`` geometry.
        """
        return (
            bool(self.free_rows)
            and n_pages <= self.pages_per_seq
            and n_pages
            <= self.allocator.free_pages + self.allocator.dormant_pages
        )

    def export_request(self, row: int) -> MigrationTicket:
        """Detach a decoding request: gather its KV, free its pages.

        The half of the Llumnix-style handoff that runs on the source
        replica.  After this returns, the engine holds no trace of the
        request — its pages are back in the allocator's free list (leak
        checked) and its row is reusable.  The caller owns the ticket
        and must either :meth:`import_request` it somewhere or accept
        losing the decode progress.

        Parameters
        ----------
        row : int
            An *active* (decoding) row.  Prefilling rows are not
            migratable — their KV is cheaper to recompute than to move.

        Returns
        -------
        MigrationTicket
            Host-side copy of the request state and KV pages.

        Raises
        ------
        ValueError
            If ``row`` is not currently decoding.
        """
        if row not in self.active:
            raise ValueError(f"row {row} is not decoding; cannot export")
        req = self.active.pop(row)
        pages = list(self.seq_pages[row])
        idx = jnp.asarray(np.asarray(pages, np.int32))
        # every pool leaf travels: K/V payload plus, on int8 engines,
        # the per-page scale pools the payload dequantizes through
        kv: Dict[str, Dict[str, np.ndarray]] = {
            j: {
                name: np.asarray(jax.device_get(arr[:, idx]))
                for name, arr in pool.items()
            }
            for j, pool in self.pools["blocks"].items()
        }
        ticket = MigrationTicket(
            req=req,
            last_token=int(self._tokens[row]),
            length=int(self.lengths[row]),
            kv=kv,
            n_pages=len(pages),
            page_size=self.page_size,
            max_len=self.max_len,
            model=self.cfg.name,
            # shared-page accounting: refcounts at export time (a value
            # > 1 means the page stays alive on the source for its
            # co-owners / prefix index; the ticket carries a copy)
            page_refcounts=[self.allocator.refcount(p) for p in pages],
            kv_dtype=self.kv_dtype,
        )
        if self._san is not None:
            self._san.validate_ticket(pages, ticket.page_refcounts)
            if self.kv_dtype == "int8":
                self._san.validate_scale_export(pages)
        self._release_row(row)
        self.migrations_out += 1
        return ticket

    def import_request(self, ticket: MigrationTicket) -> bool:
        """Resume an exported request on this replica.

        Allocates ``ticket.n_pages`` fresh pages from this engine's
        allocator, scatters the ticket's KV into the local pools at the
        new physical ids, rebuilds the block table, and resumes decode
        from ``ticket.last_token``.  Under greedy decoding with shared
        weights the continuation is token-for-token identical to an
        unmigrated run.

        Parameters
        ----------
        ticket : MigrationTicket
            State produced by a peer's :meth:`export_request`.  Must
            match this engine's ``page_size`` and model config.

        Returns
        -------
        bool
            False when no row/pages are available (the ticket remains
            valid — callers typically re-import into the source).

        Raises
        ------
        ValueError
            On a page-size, model, or max_len mismatch (an incompatible
            destination would corrupt the KV layout or silently
            truncate the continuation at its shorter length cutoff).
        """
        if ticket.page_size != self.page_size:
            raise ValueError(
                f"page_size mismatch: ticket {ticket.page_size} "
                f"vs engine {self.page_size}"
            )
        if ticket.model != self.cfg.name:
            raise ValueError(
                f"model mismatch: ticket {ticket.model!r} vs {self.cfg.name!r}"
            )
        if ticket.kv_dtype != self.kv_dtype:
            raise ValueError(
                f"kv_dtype mismatch: ticket {ticket.kv_dtype!r} vs engine "
                f"{self.kv_dtype!r} — the page payload bytes are not "
                "interchangeable"
            )
        if ticket.max_len > self.max_len:
            raise ValueError(
                f"max_len mismatch: ticket from a max_len={ticket.max_len} "
                f"engine cannot resume on max_len={self.max_len} without "
                "risking early truncation"
            )
        if ticket.n_pages > self.pages_per_seq or not self.free_rows:
            return False
        row = self.free_rows[0]
        pages = self._alloc(ticket.n_pages, owner=row)
        if pages is None:
            return False
        self.free_rows.pop(0)
        idx = jnp.asarray(np.asarray(pages, np.int32))
        blocks = {
            j: {
                name: arr.at[:, idx].set(
                    jnp.asarray(ticket.kv[j][name], arr.dtype)
                )
                for name, arr in pool.items()
            }
            for j, pool in self.pools["blocks"].items()
        }
        self.pools = {"blocks": blocks}
        self.seq_pages[row] = pages
        self.block_tables[row] = TRASH_PAGE
        self.block_tables[row, : len(pages)] = pages
        if self._san is not None:
            self._san.note_table(row, pages)
            for p in pages:  # ticket KV scatters into every fresh page
                self._san.note_write(
                    row, p, quantized=self.kv_dtype == "int8"
                )
        self.lengths[row] = ticket.length
        self._tokens[row] = ticket.last_token
        self.active[row] = ticket.req
        self._admit_seq += 1
        self._row_seq[row] = self._admit_seq
        self.migrations_in += 1
        # the imported KV's prompt pages are as reusable as a local
        # prefill's: register them so peers of this replica hit too
        self._register_prefix(row, ticket.req)
        return True

    # -- maintenance --------------------------------------------------------
    def defrag(self) -> int:
        """Compact content-bearing pages onto the lowest physical ids.

        Permutes the KV pools and patches every live block table *and*
        the prefix index with the allocator's old→new mapping (dormant
        cached pages move too — their KV stays reusable), improving
        DMA locality after heavy admission/eviction churn.

        Returns
        -------
        int
            Number of pages moved (0 when already compact).
        """
        mapping = self.allocator.defrag()
        if not mapping:
            return 0
        perm = np.arange(self.num_pages)
        for old, new in mapping.items():
            perm[new] = old
        perm_j = jnp.asarray(perm)
        self.pools = jax.tree.map(
            lambda pool: pool[:, perm_j], self.pools["blocks"], is_leaf=None
        )
        self.pools = {"blocks": self.pools}
        for row, pages in self.seq_pages.items():
            self.seq_pages[row] = [mapping.get(p, p) for p in pages]
            self.block_tables[row] = TRASH_PAGE
            self.block_tables[row, : len(self.seq_pages[row])] = self.seq_pages[row]
        if self.prefix_index is not None:
            self.prefix_index.remap(mapping)
        return len(mapping)
